"""Cancellation and SLA-aware preemption across the model families.

The open-loop lifecycle (DESIGN.md §8) must be output-invariant: requests
that are NOT preempted decode token-identically whether preemption is armed
or not, and a preempted-then-resumed victim — evicted mid-decode, its pages
freed, re-admitted later with prompt+generated as its effective prompt —
must match its uninterrupted fused output exactly under greedy decode.
Covered per family because eviction stresses family-specific slot state:
lm (dense KV), gemma2 (sliding-window ring buffers), hymba (mixed
mamba/attn), rwkv (pure recurrent state, nothing pages), and the
split-brain paged engine where resume should be near-free via the radix
prefix cache (published at eviction)."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler, Request
from repro.serve.splitbrain_engine import SplitBrainEngine

MAX_NEW = 6
FAMILIES = ["stablelm-1.6b", "gemma2-27b", "hymba-1.5b", "rwkv6-7b",
            "splitbrain"]


def _build(arch):
    """Returns (cfg, engine, prefill_chunk).  The split-brain build is
    paged + prefix-armed with 4-token pages (a briefly-decoding victim has
    a COMPLETED full page to publish at eviction) and chunked prefill (a
    partial prefix match computes only the unmatched tail, which needs the
    chunk path — without it admission correctly degrades to a full
    re-prefill and the resume would show cached_tokens == 0)."""
    name = "tinyllama-1.1b" if arch == "splitbrain" else arch
    cfg = get_config(name).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    if arch == "splitbrain":
        eng = SplitBrainEngine(cfg, params, max_len=32, quantize=False,
                               page_size=4, num_pages=17, prefix_cache="on")
        return cfg, eng, 4
    return cfg, ServeEngine(cfg, params, max_len=32), None


def _fused(eng, prompt, max_new=MAX_NEW):
    return np.asarray(eng.generate(prompt[None, :], max_new=max_new)
                      ["tokens"][0])


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
            for t in lens]


@pytest.mark.parametrize("arch", FAMILIES)
def test_preempted_and_resumed_matches_uninterrupted(arch):
    """Force an eviction with the open-loop api: one slot, a low-priority
    victim mid-decode, then a high-priority arrival.  The victim's resumed
    output must equal its uninterrupted fused output, and the preemptor
    must be untouched by having preempted."""
    cfg, eng, chunk = _build(arch)
    p0, p1 = _prompts(cfg, (5, 6))
    base0, base1 = _fused(eng, p0), _fused(eng, p1)

    sched = ContinuousBatchingScheduler(eng, max_slots=1, preemption=True,
                                        backoff_steps=1,
                                        prefill_chunk=chunk)
    sched.begin()
    sched.submit(Request(uid=0, prompt=p0, max_new=MAX_NEW, priority=0))
    for _ in range(3):
        sched.step()
    assert sched.decoding_uids() == [0]      # victim is mid-decode
    sched.submit(Request(uid=1, prompt=p1, max_new=MAX_NEW, priority=5))
    for _ in range(200):
        sched.step()
        if not sched.has_work():
            break
    res = {r.uid: r for r in sched.poll()}
    assert not sched.poll_rejected()
    assert res[0].preemptions >= 1 and res[0].state == "DONE"
    assert res[1].preemptions == 0 and res[1].state == "DONE"
    np.testing.assert_array_equal(res[0].tokens, base0)
    np.testing.assert_array_equal(res[1].tokens, base1)
    if arch == "splitbrain":
        # eviction published the victim's full pages: the resume admission
        # radix-matched them instead of re-prefilling from scratch
        assert res[0].cached_tokens > 0


@pytest.mark.parametrize("arch", FAMILIES)
def test_non_preempted_identical_with_preemption_on_vs_off(arch):
    """Same closed workload served with preemption armed and disarmed:
    when nothing triggers an eviction the flag must be a pure no-op, and
    with mixed priorities the non-preempted requests must still be
    token-identical to their fused baselines."""
    cfg, eng, chunk = _build(arch)
    prompts = _prompts(cfg, (4, 6, 3, 5), seed=1)
    base = [_fused(eng, p) for p in prompts]

    def serve(preemption):
        sched = ContinuousBatchingScheduler(eng, max_slots=2,
                                            preemption=preemption,
                                            prefill_chunk=chunk)
        reqs = [Request(uid=i, prompt=p, max_new=MAX_NEW,
                        priority=i % 2)
                for i, p in enumerate(prompts)]
        out = sched.run(reqs)
        assert not out["rejected"]
        return out

    off = serve(False)
    on = serve(True)
    for r_off, r_on, b in zip(off["results"], on["results"], base):
        np.testing.assert_array_equal(r_off.tokens, b)
        np.testing.assert_array_equal(r_on.tokens, b)
        assert r_on.state == "DONE" and r_off.state == "DONE"


@pytest.mark.parametrize("arch", FAMILIES)
def test_mid_decode_cancellation_leaves_others_token_identical(arch):
    """Cancel one stream mid-decode: it terminates CANCELLED within one
    iteration with a greedy-consistent partial output, the other streams
    finish token-identical to their fused baselines, and (paged engines)
    its pages are back in the pool the same iteration."""
    cfg, eng, chunk = _build(arch)
    prompts = _prompts(cfg, (5, 4, 6), seed=2)
    base = [_fused(eng, p) for p in prompts]

    sched = ContinuousBatchingScheduler(eng, max_slots=3,
                                        prefill_chunk=chunk)
    sched.begin()
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
    for _ in range(20):
        sched.step()
        if 1 in sched.decoding_uids():
            break
    assert 1 in sched.decoding_uids()
    stats_mid = eng.cache_stats(sched.cache)
    sched.cancel(1)
    fin = sched.step()                      # ONE iteration
    cancelled = [r for r in fin if r.uid == 1]
    assert len(cancelled) == 1 and cancelled[0].state == "CANCELLED"
    if "pages_in_use" in stats_mid:
        assert (eng.cache_stats(sched.cache)["pages_in_use"]
                < stats_mid["pages_in_use"])
    for _ in range(200):
        sched.step()
        if not sched.has_work():
            break
    res = {r.uid: r for r in sched.poll()}
    res[1] = cancelled[0]
    np.testing.assert_array_equal(res[0].tokens, base[0])
    np.testing.assert_array_equal(res[2].tokens, base[2])
    # the cancelled stream's partial output is a greedy prefix
    g = res[1].gen_len
    assert 1 <= g < MAX_NEW
    np.testing.assert_array_equal(res[1].tokens, base[1][:g])
