"""Jit-fused decode paths: scan-over-stacked-layers SplitBrainEngine and the
fused ServeEngine prefill/generate must match their eager/stepwise references
token-for-token, with byte-identical TrafficMeter accounting."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import ServeEngine
from repro.serve.splitbrain_engine import SplitBrainEngine, traffic_model_for


def _lm(arch, **overrides):
    cfg = get_config(arch).reduced(vocab_size=128, **overrides)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "llama2-7b"])
def test_jit_scan_matches_eager_loop(arch):
    """The stacked-layer lax.scan decode must produce the same tokens and the
    same measured interface bytes as the pre-refactor per-layer loop."""
    cfg, params = _lm(arch)
    eng_e = SplitBrainEngine(cfg, params, max_len=16, quantize=False, jit=False)
    eng_j = SplitBrainEngine(cfg, params, max_len=16, quantize=False, jit=True)
    tok = jnp.asarray([3, 5], jnp.int32)
    cache_e, cache_j = eng_e.init_cache(2), eng_j.init_cache(2)
    for _ in range(4):
        te, le, cache_e = eng_e.decode_token(cache_e, tok)
        tj, lj, cache_j = eng_j.decode_token(cache_j, tok)
        np.testing.assert_array_equal(np.asarray(te), np.asarray(tj))
        np.testing.assert_allclose(np.asarray(le, np.float32),
                                   np.asarray(lj, np.float32),
                                   rtol=2e-2, atol=2e-2)
        tok = tj
    # byte-identical accounting: trace-time replay == runtime log
    assert eng_e.measured_bytes_per_token(2) == eng_j.measured_bytes_per_token(2)
    assert [e for e in eng_e.meter.log] == [e for e in eng_j.meter.log]
    assert eng_j.measured_bytes_per_token(2)["total"] == \
        4 * traffic_model_for(cfg).bytes_per_token()


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "llama2-7b"])
def test_fused_generate_matches_stepwise(arch):
    """One-dispatch generate == token-at-a-time eager generation."""
    cfg, params = _lm(arch)
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (2, 4)).astype(np.int32)
    eng_j = SplitBrainEngine(cfg, params, max_len=32, quantize=False, jit=True)
    eng_e = SplitBrainEngine(cfg, params, max_len=32, quantize=False, jit=False)
    out_f = eng_j.generate(prompts, max_new=6)
    out_s = eng_e.generate(prompts, max_new=6)
    np.testing.assert_array_equal(out_f["tokens"], out_s["tokens"])
    assert eng_j.measured_bytes_per_token(2) == eng_e.measured_bytes_per_token(2)


def test_pallas_device_ops_match_reference():
    """use_pallas=True routes the quantized device projections through the
    w4a8 Pallas kernel (interpret mode on CPU) — integer path bit-exact."""
    cfg, params = _lm("llama2-7b")
    eng_r = SplitBrainEngine(cfg, params, max_len=16, quantize=True)
    eng_p = SplitBrainEngine(cfg, params, max_len=16, quantize=True,
                             use_pallas=True)
    tok = jnp.asarray([3, 5], jnp.int32)
    tr, lr, _ = eng_r.decode_token(eng_r.init_cache(2), tok)
    tp, lp, _ = eng_p.decode_token(eng_p.init_cache(2), tok)
    np.testing.assert_array_equal(np.asarray(tr), np.asarray(tp))
    np.testing.assert_allclose(np.asarray(lr, np.float32),
                               np.asarray(lp, np.float32), rtol=1e-3, atol=1e-3)


def test_bucketed_generate_returns_exact_cache():
    """The fused scan may run a bucketed step count past the request, but
    the returned cache must be EXACTLY the prompt+max_new state (no len
    overrun, no clamp-writes past max_len): continuing to decode from it
    matches a longer generate."""
    cfg, params = _lm("tinyllama-1.1b")
    eng = SplitBrainEngine(cfg, params, max_len=8, quantize=False)
    prompts = np.random.default_rng(5).integers(
        1, cfg.vocab_size, (1, 3)).astype(np.int32)
    out = eng.generate(prompts, max_new=5)   # step bucket 16 > max_len 8
    assert int(out["cache"]["len"][0]) == 2 + 5
    nxt, _, _ = eng.decode_token(out["cache"],
                                 jnp.asarray(out["tokens"][:, -1]))
    ref = eng.generate(prompts, max_new=6)
    assert int(nxt[0]) == int(ref["tokens"][0, 5])


def test_decode_token_donates_cache():
    """The jitted path donates the KV buffers: the returned cache is live,
    the input cache is consumed (on backends implementing donation)."""
    cfg, params = _lm("tinyllama-1.1b")
    eng = SplitBrainEngine(cfg, params, max_len=8, quantize=False)
    cache = eng.init_cache(1)
    _, _, new_cache = eng.decode_token(cache, jnp.zeros((1,), jnp.int32))
    assert new_cache["k"].shape == (cfg.num_layers, 1, cfg.num_kv_heads, 8,
                                    cfg.resolved_head_dim)
    assert int(new_cache["len"][0]) == 1


@pytest.mark.parametrize("arch", ["granite-8b", "stablelm-1.6b", "rwkv6-7b"])
def test_serve_fused_prefill_matches_stepwise(arch):
    """ServeEngine: fused prefill + one-dispatch decode loop == the legacy
    per-token loop, across the lm fast path and the scan-of-decode fallback
    (rwkv)."""
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=24)
    prompts = np.random.default_rng(1).integers(
        1, cfg.vocab_size, (3, 4)).astype(np.int32)
    out_f = eng.generate(prompts, max_new=5, fused=True)
    out_s = eng.generate(prompts, max_new=5, fused=False)
    np.testing.assert_array_equal(out_f["tokens"], out_s["tokens"])


def test_serve_prefill_single_token_prompt():
    """T0=1 prompts skip prefill entirely and still decode."""
    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=16)
    out = eng.generate(np.full((2, 1), 7, np.int32), max_new=4)
    assert out["tokens"].shape == (2, 4)
