"""Per-architecture smoke tests (assignment requirement): a REDUCED config of
each family runs one forward + one train step + one decode step on CPU,
asserting output shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.train import optimizer as opt_mod
from repro.train import step as step_mod

B, T = 2, 24


def _inputs(cfg, key):
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens,
             "mask": jnp.ones((B, T), jnp.float32)}
    if cfg.frontend_tokens:
        batch["frontend"] = jnp.ones((B, cfg.frontend_tokens, cfg.d_model),
                                     jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED + ["tinyllama-1.1b", "llama2-7b"])
def test_smoke_forward_shapes_no_nans(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    batch = _inputs(cfg, jax.random.PRNGKey(1))
    logits, aux = api.forward(params, batch["tokens"], cfg,
                              frontend=batch.get("frontend"))
    assert logits.shape == (B, T, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()


# the enc-dec/vision train-step smokes compile the heaviest graphs (~10s
# each); their forward and decode smokes keep covering those archs in
# tier-1, the grad-step variant rides in the slow job
_HEAVY_TRAIN_SMOKE = {"llama-3.2-vision-11b", "seamless-m4t-medium"}


@pytest.mark.parametrize(
    "arch", [pytest.param(a, marks=pytest.mark.slow)
             if a in _HEAVY_TRAIN_SMOKE else a for a in ASSIGNED])
def test_smoke_train_step(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    mesh = make_test_mesh()
    optcfg = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    with mesh:
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = opt_mod.init_state(params, optcfg)
        step = step_mod.make_train_step(cfg, optcfg, mesh, params, opt_state,
                                        donate=False)
        batch = _inputs(cfg, jax.random.PRNGKey(1))
        p2, o2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                                        - b.astype(jnp.float32)))),
                     params, p2))
    assert delta > 0
    assert int(o2["step"]) == 1


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    fe = (jnp.ones((B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
          if cfg.frontend_tokens else None)
    cache = api.init_cache(cfg, B, 16, frontend=fe, params=params)
    tok = jnp.zeros((B,), jnp.int32)
    for _ in range(3):
        logits, cache = api.decode_step(params, cache, tok, cfg)
        assert logits.shape == (B, cfg.vocab_size)
        assert not np.isnan(np.asarray(logits)).any()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert int(np.asarray(cache["len"])[0]) == 3


def test_decode_matches_forward_prefix():
    """Incremental decode must reproduce teacher-forced forward logits."""
    cfg = get_config("granite-8b").reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, 6), 0, cfg.vocab_size)
    full_logits, _ = api.forward(params, toks, cfg)
    cache = api.init_cache(cfg, B, 8)
    for t in range(6):
        step_logits, cache = api.decode_step(params, cache, toks[:, t], cfg)
        np.testing.assert_allclose(np.asarray(step_logits),
                                   np.asarray(full_logits[:, t]),
                                   rtol=3e-2, atol=3e-2)


def test_decode_matches_forward_prefix_gemma_pattern():
    """Same equivalence through the local/global alternating + softcap path
    (ring-buffer cache correctness)."""
    cfg = get_config("gemma2-27b").reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n = 20  # exceeds the reduced 16-wide window -> exercises the ring buffer
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, n), 0, cfg.vocab_size)
    full_logits, _ = api.forward(params, toks, cfg)
    cache = api.init_cache(cfg, B, n)
    for t in range(n):
        step_logits, cache = api.decode_step(params, cache, toks[:, t], cfg)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, -1]),
                               rtol=4e-2, atol=4e-2)


def test_decode_matches_forward_rwkv():
    cfg = get_config("rwkv6-7b").reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, 6), 0, cfg.vocab_size)
    full_logits, _ = api.forward(params, toks, cfg)
    cache = api.init_cache(cfg, B, 8)
    for t in range(6):
        step_logits, cache = api.decode_step(params, cache, toks[:, t], cfg)
        np.testing.assert_allclose(np.asarray(step_logits),
                                   np.asarray(full_logits[:, t]),
                                   rtol=3e-2, atol=3e-2)
