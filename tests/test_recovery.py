"""Crash-tolerant serving: device-loss recovery, NaN quarantine, watchdog.

The split-brain contract (PAPER.md §Split-Brain) makes the device stateless
— every byte of dynamic state has a host-authoritative copy — so a device
failure mid-decode must be fully recoverable from host state alone, and the
recovered output must be BITWISE token-identical to the uninterrupted
greedy run.  This suite drives the three device-level injection points of
serve/faults.py against the real scheduler + engines:

  device_loss   — wholesale array invalidation: scheduler.recover() rebuilds
                  params/pool/slot cache from host state; in-flight requests
                  re-admit (through the prefix cache where armed) and resume
                  token-identically — tested per family, composed with
                  preemption (the recovery×preemption satellite).
  step_error    — the decode dispatch raises: recovery runs and the pool
                  returns to baseline after EVERY injected error.
  step_corrupt  — per-slot NaN logits: the in-step finite-logits sentinel
                  quarantines exactly the poisoned slots (batchmates keep
                  decoding untouched); a transient window retries to DONE
                  token-identically, a persistent corruption degrades to the
                  terminal FAILED state after max_strikes.
  step_stall    — a wedged dispatch: the OnlineServer heartbeat watchdog
                  trips, recovery runs on the loop thread, and the requests
                  still finish token-identically.

Like tests/test_faults.py this file is swept by the CI chaos-smoke seed
matrix (CHAOS_SEED): same (plan, seed) -> same fault sequence.
"""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import ServeEngine
from repro.serve.errors import (DeviceError, DeviceLost, SchedulerError,
                                StepCorruption, StepError)
from repro.serve.faults import FaultInjector, FaultPlan
from repro.serve.scheduler import ContinuousBatchingScheduler, Request
from repro.serve.server import OnlineServer
from repro.serve.splitbrain_engine import SplitBrainEngine

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))
MAX_NEW = 6
FAMILIES = ["stablelm-1.6b", "gemma2-27b", "hymba-1.5b", "rwkv6-7b",
            "splitbrain"]


def _build(arch):
    """(cfg, engine, prefill_chunk) — mirrors tests/test_preemption.py: the
    split-brain build is paged + prefix-armed so recovery exercises the
    pool rebuild and prefix re-publication; the others are dense."""
    name = "tinyllama-1.1b" if arch == "splitbrain" else arch
    cfg = get_config(name).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    if arch == "splitbrain":
        eng = SplitBrainEngine(cfg, params, max_len=32, quantize=False,
                               page_size=4, num_pages=17, prefix_cache="on")
        return cfg, eng, 4
    return cfg, ServeEngine(cfg, params, max_len=32), None


@pytest.fixture(scope="module")
def paged_setup():
    """One shared paged + prefix-armed ServeEngine (the pool-occupancy
    assertions need a real page pool)."""
    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=32, page_size=4, num_pages=33,
                      prefix_cache="on")
    rng = np.random.default_rng(CHAOS_SEED)
    prompts = [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
               for t in (5, 9, 4, 7)]
    base = [np.asarray(eng.generate(p[None, :], max_new=MAX_NEW)
                       ["tokens"][0]) for p in prompts]
    return cfg, eng, prompts, base


def _fused(eng, prompt, max_new=MAX_NEW):
    return np.asarray(eng.generate(prompt[None, :], max_new=max_new)
                      ["tokens"][0])


def _prompts(cfg, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
            for t in lens]


def _pool_baseline(eng):
    pool = eng._pager.pool
    return (pool.pages_in_use, pool.total_reserved, pool.total_drawn)


def _drain(sched, limit=500):
    for _ in range(limit):
        sched.step()
        if not sched.has_work():
            return
    raise AssertionError("scheduler did not drain")


def test_device_error_hierarchy():
    """The typed recovery errors: device failures are SchedulerErrors (the
    loop may catch them) under one DeviceError base (the recovery path
    catches exactly that)."""
    for exc in (StepError, StepCorruption, DeviceLost):
        assert issubclass(exc, DeviceError)
        assert issubclass(exc, SchedulerError)


@pytest.mark.parametrize("arch", FAMILIES)
def test_preempted_then_device_loss_token_identical(arch):
    """The recovery×preemption satellite: a victim that is preempted AND
    then survives a wholesale device loss must still resume bitwise
    token-identical to the uninterrupted greedy run — prompts, generated
    tails and page tables are host-authoritative, so neither event can
    lose a token."""
    cfg, eng, chunk = _build(arch)
    p0, p1 = _prompts(cfg, (5, 6))
    base0, base1 = _fused(eng, p0), _fused(eng, p1)

    inj = FaultInjector(FaultPlan(device_loss_at=8), seed=CHAOS_SEED)
    sched = ContinuousBatchingScheduler(eng, max_slots=1, preemption=True,
                                        backoff_steps=1, prefill_chunk=chunk,
                                        faults=inj)
    sched.begin()
    sched.submit(Request(uid=0, prompt=p0, max_new=MAX_NEW, priority=0))
    for _ in range(3):
        sched.step()
    assert sched.decoding_uids() == [0]      # victim is mid-decode
    sched.submit(Request(uid=1, prompt=p1, max_new=MAX_NEW, priority=5))
    _drain(sched)
    assert inj.fired("device_loss") == 1
    assert sched._recoveries == 1
    assert any(e["event"] == "recover" for e in sched.recovery_log)
    res = {r.uid: r for r in sched.poll()}
    assert not sched.poll_rejected()
    assert res[0].preemptions >= 1
    for uid, b in ((0, base0), (1, base1)):
        assert res[uid].state == "DONE"
        np.testing.assert_array_equal(res[uid].tokens, b)
    if getattr(eng, "_pager", None) is not None:
        assert _pool_baseline(eng) == (0, 0, 0)


def test_pool_returns_to_baseline_after_every_step_error(paged_setup):
    """Persistent step errors (two consecutive raising iterations): each
    one triggers a recovery whose pool rebuild must leave ZERO occupancy
    the instant the recovering iteration ends — reserved pages and radix
    refcounts died with the pool, not stranded — and the drained run still
    serves everything token-identically."""
    cfg, eng, prompts, base = paged_setup
    inj = FaultInjector(FaultPlan(step_error_at=3, step_error_count=2),
                        seed=CHAOS_SEED)
    sched = ContinuousBatchingScheduler(eng, max_slots=2, prefill_chunk=4,
                                        faults=inj)
    sched.begin()
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
    seen = 0
    for _ in range(500):
        sched.step()
        if sched._recoveries > seen:
            seen = sched._recoveries
            # the recovery just ran: the rebuilt pool must be EMPTY now
            assert _pool_baseline(eng) == (0, 0, 0), \
                "pages survived the pool rebuild"
        if not sched.has_work():
            break
    assert inj.fired("step_error") == 2
    assert sched._recoveries == 2
    res = {r.uid: r for r in sched.poll()}
    assert not sched.poll_rejected()
    for i, b in enumerate(base):
        assert res[i].state == "DONE"
        np.testing.assert_array_equal(res[i].tokens, b)
    assert _pool_baseline(eng) == (0, 0, 0)


def test_transient_corruption_quarantines_and_retries(paged_setup):
    """A two-iteration NaN window over a seeded half of the decode batch:
    the sentinel quarantines the poisoned slots (their garbage token is
    never appended), the retry outlives the window, and EVERY request —
    quarantined or batchmate — finishes DONE and token-identical."""
    cfg, eng, prompts, base = paged_setup
    inj = FaultInjector(
        FaultPlan(step_corrupt_at=4, step_corrupt_iters=2,
                  step_corrupt_frac=0.5), seed=CHAOS_SEED)
    sched = ContinuousBatchingScheduler(eng, max_slots=4, faults=inj)
    sched.begin()
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
    _drain(sched)
    assert inj.fired("step_corrupt") > 0
    assert sched._quarantines > 0
    assert sched._failed_count == 0          # transient: nobody degrades
    res = {r.uid: r for r in sched.poll()}
    assert not sched.poll_rejected()
    for i, b in enumerate(base):
        assert res[i].state == "DONE"
        np.testing.assert_array_equal(res[i].tokens, b)
    assert _pool_baseline(eng) == (0, 0, 0)


def test_persistent_corruption_fails_after_max_strikes(paged_setup):
    """A request whose logits are ALWAYS non-finite must not retry forever:
    after max_strikes quarantines it degrades to the terminal FAILED state,
    while its batchmates decode token-identically throughout — the whole
    point of quarantine is that one sick stream cannot poison the batch."""
    cfg, eng, prompts, base = paged_setup
    inj = FaultInjector(
        FaultPlan(step_corrupt_at=0, step_corrupt_iters=10 ** 9,
                  step_corrupt_uids=(1,)), seed=CHAOS_SEED)
    sched = ContinuousBatchingScheduler(eng, max_slots=4, max_strikes=3,
                                        faults=inj)
    sched.begin()
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
    _drain(sched)
    assert sched._quarantines == 3 and sched._failed_count == 1
    assert any(e["event"] == "failed" and e["uid"] == 1
               for e in sched.recovery_log)
    res = {r.uid: r for r in sched.poll()}
    assert res[1].state == "FAILED" and res[1].gen_len == 0
    for i in (0, 2, 3):
        assert res[i].state == "DONE"
        np.testing.assert_array_equal(res[i].tokens, base[i])
    assert _pool_baseline(eng) == (0, 0, 0)


def test_watchdog_detects_wedged_step_and_recovers(paged_setup):
    """A decode dispatch that wedges for ~1s: the heartbeat watchdog
    (0.2s window) trips while the loop thread is stuck, the recovery runs
    at the loop's next safe point, and the requests still finish DONE and
    token-identical.  stats() exposes the whole incident."""
    cfg, eng, prompts, base = paged_setup
    inj = FaultInjector(FaultPlan(step_stall_at=2, step_stall_s=1.0),
                        seed=CHAOS_SEED)
    sched = ContinuousBatchingScheduler(eng, max_slots=2, faults=inj)
    srv = OnlineServer(sched, watchdog_s=0.2)
    with srv:
        handles = [srv.submit(p, max_new=MAX_NEW) for p in prompts[:2]]
        results = [h.result(timeout=120.0) for h in handles]
    assert inj.fired("step_stall") == 1
    stats = srv.stats()
    assert stats["watchdog_trips"] >= 1
    assert stats["recoveries"] >= 1
    assert stats["last_recovery_s"] >= 0.0
    for r, b in zip(results, base[:2]):
        assert r.state == "DONE"
        np.testing.assert_array_equal(r.tokens, b)
    assert _pool_baseline(eng) == (0, 0, 0)


def test_recovery_resumes_through_prefix_cache(paged_setup):
    """After a device loss the radix index is empty (its device bytes are
    gone) — but recovered requests republish as they re-prefill, so a
    recovered request whose prefix was re-published by an earlier
    re-admission seeds from the pool instead of recomputing (the PR 5
    re-admission path, exercised under recovery).

    The reference is a no-fault run of the SAME scheduler configuration,
    not the fused generate: the reduced random-weight models produce exact
    argmax ties at some positions (seed-dependent), and chunked-prefill
    numerics may break a tie differently than the fused forward — the
    recovery contract is "identical to the uninterrupted run of the same
    pipeline", which is what this compares."""
    cfg, eng, prompts, base = paged_setup
    shared = np.concatenate([prompts[0], prompts[0]])[:8]   # page-aligned
    p_a = shared.copy()
    p_b = np.concatenate([shared, prompts[1][:3]])

    def _run(faults):
        sched = ContinuousBatchingScheduler(eng, max_slots=2,
                                            prefill_chunk=4,
                                            max_prefill_jobs=1,
                                            faults=faults)
        sched.begin()
        sched.submit(Request(uid=0, prompt=p_a, max_new=MAX_NEW))
        sched.submit(Request(uid=1, prompt=p_b, max_new=MAX_NEW))
        _drain(sched)
        assert not sched.poll_rejected()
        return sched, {r.uid: r for r in sched.poll()}

    _, ref = _run(None)                                     # uninterrupted
    inj = FaultInjector(FaultPlan(device_loss_at=6), seed=CHAOS_SEED)
    sched, res = _run(inj)
    assert sched._recoveries == 1
    np.testing.assert_array_equal(res[0].tokens, ref[0].tokens)
    np.testing.assert_array_equal(res[1].tokens, ref[1].tokens)
    # the re-admissions after the loss went through the prefix cache: the
    # faulted run accumulates strictly more reused tokens than the single
    # admission of the uninterrupted run
    assert res[1].cached_tokens > ref[1].cached_tokens
    assert _pool_baseline(eng) == (0, 0, 0)
