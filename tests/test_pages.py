"""Paged KV-cache plumbing (serve/pages.py): host allocator invariants,
sequence-axis discovery, and the gather/scatter page-table ops that the
paged slot protocol is built from."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve import pages


# ----------------------------------------------------------------- PagePool
def test_page_pool_alloc_free_and_peak():
    pool = pages.PagePool(num_pages=9, page_size=4, n_slots=3, slot_pages=4)
    assert pool.capacity == 8                     # page 0 is scratch
    assert pool.try_reserve(0, 10)                # 3 pages worst case
    assert pool.try_reserve(1, 16)                # 4 pages
    pool.ensure(0, 5)                             # 2 pages resident
    pool.ensure(1, 16)                            # 4 pages resident
    assert pool.pages_in_use == 6
    assert pool.peak_pages_in_use == 6
    # scratch page never handed out, tables point at real pages
    assert all(p != pages.SCRATCH_PAGE for p in pool.table[0][:2])
    assert all(p == pages.SCRATCH_PAGE for p in pool.table[0][2:])
    pool.free_slot(1)
    assert pool.pages_in_use == 2
    assert pool.peak_pages_in_use == 6            # peak is sticky
    assert pool.total_reserved == 3
    # freed pages are reusable; a request longer than one slot's page
    # table is refused outright
    assert not pool.try_reserve(1, 20)            # 5 pages > slot_pages
    assert pool.try_reserve(1, 14)                # 4 pages fit again
    pool.ensure(1, 14)
    assert pool.pages_in_use == 6


def test_page_pool_reservation_admission_control():
    pool = pages.PagePool(num_pages=5, page_size=4, n_slots=2, slot_pages=4)
    assert pool.try_reserve(0, 12)                # 3 of 4 pages
    assert not pool.try_reserve(1, 8)             # 2 more would overcommit
    assert pool.try_reserve(1, 4)                 # 1 fits exactly
    pool.free_slot(0)
    assert pool.try_reserve(0, 12)                # reservation returned


def test_page_pool_rejects_degenerate_sizes():
    with pytest.raises(ValueError):
        pages.PagePool(num_pages=1, page_size=4, n_slots=1, slot_pages=1)
    with pytest.raises(ValueError):
        pages.PagePool(num_pages=5, page_size=4, n_slots=1, slot_pages=4,
                       double_free="maybe")


def test_page_pool_double_free_policy():
    """free-after-free is detected explicitly: ValueError under the default
    'raise' policy, a silent no-op under 'ignore' — and the no-op must not
    corrupt the free list (pages are returned exactly once).  Reserve-after-
    free of the SAME slot is the normal lifecycle and succeeds; a second
    reserve without a free between raises."""
    pool = pages.PagePool(num_pages=5, page_size=4, n_slots=2, slot_pages=4)
    assert pool.try_reserve(0, 8)
    pool.ensure(0, 8)
    pool.free_slot(0)
    with pytest.raises(ValueError, match="double free"):
        pool.free_slot(0)
    assert pool.try_reserve(0, 8)               # reserve-after-free: fine
    with pytest.raises(ValueError, match="already reserved"):
        pool.try_reserve(0, 4)                  # reserve-after-reserve: bug
    pool.free_slot(0)

    lax = pages.PagePool(num_pages=5, page_size=4, n_slots=2, slot_pages=4,
                         double_free="ignore")
    assert lax.try_reserve(1, 8)
    lax.ensure(1, 8)
    lax.free_slot(1)
    free_before = len(lax._free)
    lax.free_slot(1)                            # no-op by policy
    assert len(lax._free) == free_before
    assert lax.pages_in_use == 0


def test_host_pager_double_free_raises():
    pager = pages.HostPager(page_size=4, num_pages=None, max_len=16)
    pager.reset(2)
    assert pager.try_reserve(0, prompt_len=3, max_new=4)
    pager.note_insert(0, 2)
    pager.free(0)
    with pytest.raises(ValueError, match="double free"):
        pager.free(0)


def test_page_size_one_pool_boundaries():
    """ps=1 degenerate geometry: every token is its own page; worst-case
    math, ensure and free must stay exact."""
    pool = pages.PagePool(num_pages=9, page_size=1, n_slots=2, slot_pages=8)
    assert pool.pages_for(5) == 5
    assert pool.try_reserve(0, 8)               # exactly fills the pool
    pool.ensure(0, 8)
    assert pool.pages_in_use == 8
    assert not pool.try_reserve(1, 1)           # full occupancy
    pool.free_slot(0)
    assert pool.try_reserve(1, 1)
    pool.ensure(1, 1)
    assert pool.pages_in_use == 1


def test_prompt_exactly_filling_the_pool_is_admitted():
    """A request whose worst case lands EXACTLY on pool capacity (and on
    the slot's page-table length) is admitted and can grow to the last
    token; one page more is refused."""
    pager = pages.HostPager(page_size=4, num_pages=5, max_len=16)
    pager.reset(n_slots=2)                      # capacity 4 == slot_pages
    # prompt_len - 1 + max_new = 16 tokens = 4 pages = capacity
    assert pager.can_ever_admit(prompt_len=9, max_new=8)
    assert pager.try_reserve(0, prompt_len=9, max_new=8)
    pager.note_insert(0, 8)
    for _ in range(8):                          # decode to position 16
        pager.pre_decode(np.asarray([True, False]))
        pager.post_decode(np.asarray([True, False]))
    assert pager.pool.pages_in_use == 4
    assert not pager.try_reserve(1, prompt_len=2, max_new=1)
    # 17 tokens needs 5 pages: impossible even in an idle pool
    assert not pager.can_ever_admit(prompt_len=10, max_new=8)
    pager.free(0)
    assert pager.try_reserve(1, prompt_len=2, max_new=1)


def test_can_ever_admit_agrees_with_idle_try_reserve():
    """Contract under full occupancy: can_ever_admit(x) False implies
    try_reserve(x) False in EVERY pool state, and True implies try_reserve
    succeeds once the pool is idle again — the scheduler relies on exactly
    this to decide reject-now vs wait-for-frees."""
    pager = pages.HostPager(page_size=4, num_pages=7, max_len=16)
    pager.reset(n_slots=3)
    # occupy the pool fully: 16 tokens worst case across slot 0 + slot 1
    assert pager.try_reserve(0, prompt_len=9, max_new=4)   # 3 pages
    assert pager.try_reserve(1, prompt_len=9, max_new=4)   # 3 pages
    cases = [(1, 1), (2, 3), (5, 4), (9, 8), (13, 4), (2, 16), (17, 1)]
    for prompt_len, max_new in cases:
        ever = pager.can_ever_admit(prompt_len, max_new)
        now = pager.try_reserve(2, prompt_len, max_new)
        if now:
            pager.pool.free_slot(2)
        assert ever or not now, (prompt_len, max_new)   # ¬ever ⇒ ¬now
    pager.free(0)
    pager.free(1)
    for prompt_len, max_new in cases:
        ever = pager.can_ever_admit(prompt_len, max_new)
        now = pager.try_reserve(2, prompt_len, max_new)
        if now:
            pager.pool.free_slot(2)
        assert ever == now, (prompt_len, max_new)       # idle: equivalent


# ---------------------------------------------------- layout discovery
def test_seq_axes_discovery_lm_vs_recurrent():
    """KV leaves page (their S axis scales with max_len); recurrent state,
    ring buffers and ``len`` stay dense — the no-op page table."""
    lm = get_config("stablelm-1.6b").reduced()
    a = jax.eval_shape(lambda: api.init_cache(lm, 2, 16))
    b = jax.eval_shape(lambda: api.init_cache(lm, 2, 24))
    sa = pages.seq_axes(a, b, 8)
    assert all(ax == 4 for ax in jax.tree.leaves(sa["k"]))
    assert all(ax == 4 for ax in jax.tree.leaves(sa["v"]))
    assert sa["len"] == -1

    rwkv = get_config("rwkv6-7b").reduced()
    a = jax.eval_shape(lambda: api.init_cache(rwkv, 2, 16))
    b = jax.eval_shape(lambda: api.init_cache(rwkv, 2, 24))
    assert all(ax == -1 for ax in jax.tree.leaves(
        pages.seq_axes(a, b, 8)))


# ------------------------------------------------- gather / scatter ops
def _toy_pool(B=3, S=8, ps=4, extra=2, num_pages=2 * 3 * 2 + 1):
    """One leaf shaped like a small stacked KV cache: (L, B, Hkv, S, hd)
    pattern collapsed to (extra, B, S) with ba=1, sa=2.  The pool uses the
    kernel-friendly layout — page axes sit where the batch axis sat, so the
    leading (layer-like) axis stays leading: (extra, num_pages, ps)."""
    rng = np.random.default_rng(0)
    dense = rng.standard_normal((extra, B, S)).astype(np.float32)
    pool = np.zeros((extra, num_pages, ps), np.float32)
    return dense, pool


def test_insert_gather_roundtrip_and_scratch_isolation():
    ba, sa, ps = 1, 2, 4
    dense, pool = _toy_pool()
    extra, B, S = dense.shape
    P = S // ps
    host = pages.PagePool(pool.shape[1], ps, n_slots=B, slot_pages=P)
    pool = jnp.asarray(pool)
    # insert each row as a B=1 single cache with a full page table
    for b in range(B):
        assert host.try_reserve(b, S)
        host.ensure(b, S)
        single = jnp.asarray(dense[:, b:b + 1, :])
        pool = pages.insert_tree(pool, single, jnp.asarray(host.table[b]),
                                 jnp.int32(b), ba, sa)
    table = jnp.asarray(host.table)
    view = pages.gather_tree(pool, table, ba, sa)
    np.testing.assert_array_equal(np.asarray(view), dense)

    # scatter one token per slot at ragged positions; only active slots
    # may touch real pages — the inactive write lands on scratch
    pos = jnp.asarray([1, 5, 7], jnp.int32)
    write = jnp.asarray([True, False, True])
    new = jnp.asarray(dense + 100.0)
    pool2 = pages.scatter_token_tree(pool, new, table, pos, write, ba, sa)
    view2 = np.asarray(pages.gather_tree(pool2, table, ba, sa))
    expect = dense.copy()
    expect[:, 0, 1] += 100.0
    expect[:, 2, 7] += 100.0                      # slot 1 frozen (inactive)
    np.testing.assert_array_equal(view2, expect)


def test_insert_excess_logical_pages_hit_scratch_only():
    """A short prompt's insert writes its full fixed page count, but the
    excess blocks must land on the scratch page, not on other slots."""
    ba, sa, ps = 1, 2, 4
    dense, pool = _toy_pool()
    extra, B, S = dense.shape
    P = S // ps
    host = pages.PagePool(pool.shape[1], ps, n_slots=B, slot_pages=P)
    pool = jnp.asarray(pool)
    # slot 0 owns all its pages and holds known data
    assert host.try_reserve(0, S)
    host.ensure(0, S)
    pool = pages.insert_tree(pool, jnp.asarray(dense[:, 0:1]),
                             jnp.asarray(host.table[0]), jnp.int32(0),
                             ba, sa)
    before = np.asarray(pages.gather_view(pool, jnp.asarray(host.table[0:1]),
                                          ba, sa))
    # slot 1 inserts a 3-token prompt: 1 real page, 1 scratch block
    assert host.try_reserve(1, 3)
    host.ensure(1, 3)
    pool = pages.insert_tree(pool, jnp.asarray(dense[:, 1:2]),
                             jnp.asarray(host.table[1]), jnp.int32(1),
                             ba, sa)
    after = np.asarray(pages.gather_view(pool, jnp.asarray(host.table[0:1]),
                                         ba, sa))
    np.testing.assert_array_equal(after, before)
    got = np.asarray(pages.gather_view(pool, jnp.asarray(host.table[1:2]),
                                       ba, sa))
    np.testing.assert_array_equal(got[:, :, :ps], dense[:, 1:2, :ps])


def test_pool_byte_accounting():
    dense, pool = _toy_pool()
    extra, num_pages, ps = pool.shape
    pool = jnp.asarray(pool)
    assert pages.pool_bytes(pool, 2) == pool.nbytes
    assert pages.pool_bytes(pool, -1) == 0
    # (extra, N, ps) pool: each token position carries `extra` floats
    assert pages.page_token_bytes(pool, 2, num_pages, ps) == extra * 4
    # dense-shape accounting agrees: same KV bytes per token per slot
    dense_shape = jax.eval_shape(lambda: jnp.asarray(dense))
    assert pages.kv_token_bytes(dense_shape, 1, 2) == extra * 4
    assert pages.kv_token_bytes(dense_shape, 1, -1) == 0


def test_make_pool_kernel_friendly_layout():
    """Page axes land where the batch axis sat; leading layer/group axes
    stay leading so depth scans sweep per-layer (N, ps, *tail) slices."""
    shape = {"k": jax.ShapeDtypeStruct((5, 3, 2, 8, 4), jnp.float32),
             "len": jax.ShapeDtypeStruct((3,), jnp.int32)}
    ba = {"k": 1, "len": 0}
    sa = {"k": 3, "len": -1}
    pool = pages.make_pool(shape, ba, sa, num_pages=7, page_size=4)
    assert pool["k"].shape == (5, 7, 4, 2, 4)     # (L, N, ps, Hkv, hd)
    assert pool["len"].shape == (3,)
    assert pages.page_axis(1, 3) == 1
    assert pages.page_axis(2, 0) == 1             # seq axis before batch
