"""Paged KV-cache plumbing (serve/pages.py): host allocator invariants,
sequence-axis discovery, and the gather/scatter page-table ops that the
paged slot protocol is built from."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve import pages


# ----------------------------------------------------------------- PagePool
def test_page_pool_alloc_free_and_peak():
    pool = pages.PagePool(num_pages=9, page_size=4, n_slots=3, slot_pages=4)
    assert pool.capacity == 8                     # page 0 is scratch
    assert pool.try_reserve(0, 10)                # 3 pages worst case
    assert pool.try_reserve(1, 16)                # 4 pages
    pool.ensure(0, 5)                             # 2 pages resident
    pool.ensure(1, 16)                            # 4 pages resident
    assert pool.pages_in_use == 6
    assert pool.peak_pages_in_use == 6
    # scratch page never handed out, tables point at real pages
    assert all(p != pages.SCRATCH_PAGE for p in pool.table[0][:2])
    assert all(p == pages.SCRATCH_PAGE for p in pool.table[0][2:])
    pool.free_slot(1)
    assert pool.pages_in_use == 2
    assert pool.peak_pages_in_use == 6            # peak is sticky
    assert pool.total_reserved == 3
    # freed pages are reusable; a request longer than one slot's page
    # table is refused outright
    assert not pool.try_reserve(1, 20)            # 5 pages > slot_pages
    assert pool.try_reserve(1, 14)                # 4 pages fit again
    pool.ensure(1, 14)
    assert pool.pages_in_use == 6


def test_page_pool_reservation_admission_control():
    pool = pages.PagePool(num_pages=5, page_size=4, n_slots=2, slot_pages=4)
    assert pool.try_reserve(0, 12)                # 3 of 4 pages
    assert not pool.try_reserve(1, 8)             # 2 more would overcommit
    assert pool.try_reserve(1, 4)                 # 1 fits exactly
    pool.free_slot(0)
    assert pool.try_reserve(0, 12)                # reservation returned


def test_page_pool_rejects_degenerate_sizes():
    with pytest.raises(ValueError):
        pages.PagePool(num_pages=1, page_size=4, n_slots=1, slot_pages=1)
    with pytest.raises(ValueError):
        pages.PagePool(num_pages=5, page_size=4, n_slots=1, slot_pages=4,
                       double_free="maybe")


def test_page_pool_double_free_policy():
    """free-after-free is detected explicitly: ValueError under the default
    'raise' policy, a silent no-op under 'ignore' — and the no-op must not
    corrupt the free list (pages are returned exactly once).  Reserve-after-
    free of the SAME slot is the normal lifecycle and succeeds; a second
    reserve without a free between raises."""
    pool = pages.PagePool(num_pages=5, page_size=4, n_slots=2, slot_pages=4)
    assert pool.try_reserve(0, 8)
    pool.ensure(0, 8)
    pool.free_slot(0)
    with pytest.raises(ValueError, match="double free"):
        pool.free_slot(0)
    assert pool.try_reserve(0, 8)               # reserve-after-free: fine
    with pytest.raises(ValueError, match="already reserved"):
        pool.try_reserve(0, 4)                  # reserve-after-reserve: bug
    pool.free_slot(0)

    lax = pages.PagePool(num_pages=5, page_size=4, n_slots=2, slot_pages=4,
                         double_free="ignore")
    assert lax.try_reserve(1, 8)
    lax.ensure(1, 8)
    lax.free_slot(1)
    free_before = len(lax._free)
    lax.free_slot(1)                            # no-op by policy
    assert len(lax._free) == free_before
    assert lax.pages_in_use == 0


def test_host_pager_double_free_raises():
    pager = pages.HostPager(page_size=4, num_pages=None, max_len=16)
    pager.reset(2)
    assert pager.try_reserve(0, prompt_len=3, max_new=4)
    pager.note_insert(0, 2)
    pager.free(0)
    with pytest.raises(ValueError, match="double free"):
        pager.free(0)


def test_page_size_one_pool_boundaries():
    """ps=1 degenerate geometry: every token is its own page; worst-case
    math, ensure and free must stay exact."""
    pool = pages.PagePool(num_pages=9, page_size=1, n_slots=2, slot_pages=8)
    assert pool.pages_for(5) == 5
    assert pool.try_reserve(0, 8)               # exactly fills the pool
    pool.ensure(0, 8)
    assert pool.pages_in_use == 8
    assert not pool.try_reserve(1, 1)           # full occupancy
    pool.free_slot(0)
    assert pool.try_reserve(1, 1)
    pool.ensure(1, 1)
    assert pool.pages_in_use == 1


def test_prompt_exactly_filling_the_pool_is_admitted():
    """A request whose worst case lands EXACTLY on pool capacity (and on
    the slot's page-table length) is admitted and can grow to the last
    token; one page more is refused."""
    pager = pages.HostPager(page_size=4, num_pages=5, max_len=16)
    pager.reset(n_slots=2)                      # capacity 4 == slot_pages
    # prompt_len - 1 + max_new = 16 tokens = 4 pages = capacity
    assert pager.can_ever_admit(prompt_len=9, max_new=8)
    assert pager.try_reserve(0, prompt_len=9, max_new=8)
    pager.note_insert(0, 8)
    for _ in range(8):                          # decode to position 16
        pager.pre_decode(np.asarray([True, False]))
        pager.post_decode(np.asarray([True, False]))
    assert pager.pool.pages_in_use == 4
    assert not pager.try_reserve(1, prompt_len=2, max_new=1)
    # 17 tokens needs 5 pages: impossible even in an idle pool
    assert not pager.can_ever_admit(prompt_len=10, max_new=8)
    pager.free(0)
    assert pager.try_reserve(1, prompt_len=2, max_new=1)


def test_can_ever_admit_agrees_with_idle_try_reserve():
    """Contract under full occupancy: can_ever_admit(x) False implies
    try_reserve(x) False in EVERY pool state, and True implies try_reserve
    succeeds once the pool is idle again — the scheduler relies on exactly
    this to decide reject-now vs wait-for-frees."""
    pager = pages.HostPager(page_size=4, num_pages=7, max_len=16)
    pager.reset(n_slots=3)
    # occupy the pool fully: 16 tokens worst case across slot 0 + slot 1
    assert pager.try_reserve(0, prompt_len=9, max_new=4)   # 3 pages
    assert pager.try_reserve(1, prompt_len=9, max_new=4)   # 3 pages
    cases = [(1, 1), (2, 3), (5, 4), (9, 8), (13, 4), (2, 16), (17, 1)]
    for prompt_len, max_new in cases:
        ever = pager.can_ever_admit(prompt_len, max_new)
        now = pager.try_reserve(2, prompt_len, max_new)
        if now:
            pager.pool.free_slot(2)
        assert ever or not now, (prompt_len, max_new)   # ¬ever ⇒ ¬now
    pager.free(0)
    pager.free(1)
    for prompt_len, max_new in cases:
        ever = pager.can_ever_admit(prompt_len, max_new)
        now = pager.try_reserve(2, prompt_len, max_new)
        if now:
            pager.pool.free_slot(2)
        assert ever == now, (prompt_len, max_new)       # idle: equivalent


# ---------------------------------------------------- layout discovery
def test_seq_axes_discovery_lm_vs_recurrent():
    """KV leaves page (their S axis scales with max_len); recurrent state,
    ring buffers and ``len`` stay dense — the no-op page table."""
    lm = get_config("stablelm-1.6b").reduced()
    a = jax.eval_shape(lambda: api.init_cache(lm, 2, 16))
    b = jax.eval_shape(lambda: api.init_cache(lm, 2, 24))
    sa = pages.seq_axes(a, b, 8)
    assert all(ax == 4 for ax in jax.tree.leaves(sa["k"]))
    assert all(ax == 4 for ax in jax.tree.leaves(sa["v"]))
    assert sa["len"] == -1

    rwkv = get_config("rwkv6-7b").reduced()
    a = jax.eval_shape(lambda: api.init_cache(rwkv, 2, 16))
    b = jax.eval_shape(lambda: api.init_cache(rwkv, 2, 24))
    assert all(ax == -1 for ax in jax.tree.leaves(
        pages.seq_axes(a, b, 8)))


# ------------------------------------------------- gather / scatter ops
def _toy_pool(B=3, S=8, ps=4, extra=2, num_pages=2 * 3 * 2 + 1):
    """One leaf shaped like a small stacked KV cache: (L, B, Hkv, S, hd)
    pattern collapsed to (extra, B, S) with ba=1, sa=2.  The pool uses the
    kernel-friendly layout — page axes sit where the batch axis sat, so the
    leading (layer-like) axis stays leading: (extra, num_pages, ps)."""
    rng = np.random.default_rng(0)
    dense = rng.standard_normal((extra, B, S)).astype(np.float32)
    pool = np.zeros((extra, num_pages, ps), np.float32)
    return dense, pool


def test_insert_gather_roundtrip_and_scratch_isolation():
    ba, sa, ps = 1, 2, 4
    dense, pool = _toy_pool()
    extra, B, S = dense.shape
    P = S // ps
    host = pages.PagePool(pool.shape[1], ps, n_slots=B, slot_pages=P)
    pool = jnp.asarray(pool)
    # insert each row as a B=1 single cache with a full page table
    for b in range(B):
        assert host.try_reserve(b, S)
        host.ensure(b, S)
        single = jnp.asarray(dense[:, b:b + 1, :])
        pool = pages.insert_tree(pool, single, jnp.asarray(host.table[b]),
                                 jnp.int32(b), ba, sa)
    table = jnp.asarray(host.table)
    view = pages.gather_tree(pool, table, ba, sa)
    np.testing.assert_array_equal(np.asarray(view), dense)

    # scatter one token per slot at ragged positions; only active slots
    # may touch real pages — the inactive write lands on scratch
    pos = jnp.asarray([1, 5, 7], jnp.int32)
    write = jnp.asarray([True, False, True])
    new = jnp.asarray(dense + 100.0)
    pool2 = pages.scatter_token_tree(pool, new, table, pos, write, ba, sa)
    view2 = np.asarray(pages.gather_tree(pool2, table, ba, sa))
    expect = dense.copy()
    expect[:, 0, 1] += 100.0
    expect[:, 2, 7] += 100.0                      # slot 1 frozen (inactive)
    np.testing.assert_array_equal(view2, expect)


def test_insert_excess_logical_pages_hit_scratch_only():
    """A short prompt's insert writes its full fixed page count, but the
    excess blocks must land on the scratch page, not on other slots."""
    ba, sa, ps = 1, 2, 4
    dense, pool = _toy_pool()
    extra, B, S = dense.shape
    P = S // ps
    host = pages.PagePool(pool.shape[1], ps, n_slots=B, slot_pages=P)
    pool = jnp.asarray(pool)
    # slot 0 owns all its pages and holds known data
    assert host.try_reserve(0, S)
    host.ensure(0, S)
    pool = pages.insert_tree(pool, jnp.asarray(dense[:, 0:1]),
                             jnp.asarray(host.table[0]), jnp.int32(0),
                             ba, sa)
    before = np.asarray(pages.gather_view(pool, jnp.asarray(host.table[0:1]),
                                          ba, sa))
    # slot 1 inserts a 3-token prompt: 1 real page, 1 scratch block
    assert host.try_reserve(1, 3)
    host.ensure(1, 3)
    pool = pages.insert_tree(pool, jnp.asarray(dense[:, 1:2]),
                             jnp.asarray(host.table[1]), jnp.int32(1),
                             ba, sa)
    after = np.asarray(pages.gather_view(pool, jnp.asarray(host.table[0:1]),
                                         ba, sa))
    np.testing.assert_array_equal(after, before)
    got = np.asarray(pages.gather_view(pool, jnp.asarray(host.table[1:2]),
                                       ba, sa))
    np.testing.assert_array_equal(got[:, :, :ps], dense[:, 1:2, :ps])


def test_pool_byte_accounting():
    dense, pool = _toy_pool()
    extra, num_pages, ps = pool.shape
    pool = jnp.asarray(pool)
    assert pages.pool_bytes(pool, 2) == pool.nbytes
    assert pages.pool_bytes(pool, -1) == 0
    # (extra, N, ps) pool: each token position carries `extra` floats
    assert pages.page_token_bytes(pool, 2, num_pages, ps) == extra * 4
    # dense-shape accounting agrees: same KV bytes per token per slot
    dense_shape = jax.eval_shape(lambda: jnp.asarray(dense))
    assert pages.kv_token_bytes(dense_shape, 1, 2) == extra * 4
    assert pages.kv_token_bytes(dense_shape, 1, -1) == 0


def test_make_pool_kernel_friendly_layout():
    """Page axes land where the batch axis sat; leading layer/group axes
    stay leading so depth scans sweep per-layer (N, ps, *tail) slices."""
    shape = {"k": jax.ShapeDtypeStruct((5, 3, 2, 8, 4), jnp.float32),
             "len": jax.ShapeDtypeStruct((3,), jnp.int32)}
    ba = {"k": 1, "len": 0}
    sa = {"k": 3, "len": -1}
    pool = pages.make_pool(shape, ba, sa, num_pages=7, page_size=4)
    assert pool["k"].shape == (5, 7, 4, 2, 4)     # (L, N, ps, Hkv, hd)
    assert pool["len"].shape == (3,)
    assert pages.page_axis(1, 3) == 1
    assert pages.page_axis(2, 0) == 1             # seq axis before batch


# ------------------------------------------------ quantized pools (§13)
def _quant_shape(B=1, Hkv=2, S=24, hd=16, L=3):
    """KV-like leaf (L, B, Hkv, S, hd) with ba=1, sa=3 plus a dense len."""
    shape = {"k": jax.ShapeDtypeStruct((L, B, Hkv, S, hd), jnp.bfloat16),
             "len": jax.ShapeDtypeStruct((B,), jnp.int32)}
    return shape, {"k": 1, "len": 0}, {"k": 3, "len": -1}


def test_quant_pool_scale_shape_per_page_and_kv_head():
    """The scale array drops exactly the within-page and head_dim axes:
    one f32 scale per (lead, page, kv-head), rest of the layout intact."""
    shape, ba, sa = _quant_shape()
    pool = pages.make_pool(shape, ba, sa, num_pages=7, page_size=4,
                           kv_dtype="int8")
    leaf = pool["k"]
    assert isinstance(leaf, pages.QuantizedLeaf)
    assert leaf.codes.shape == (3, 7, 4, 2, 16)   # (L, N, ps, Hkv, hd)
    assert leaf.codes.dtype == jnp.int8
    assert leaf.scales.shape == (3, 7, 2)         # (L, N, Hkv)
    assert leaf.scales.dtype == jnp.float32
    assert leaf.dtype == jnp.int8 and leaf.out_dtype == "bfloat16"
    assert pool["len"].shape == (1,)              # dense leaves untouched
    # dtype-aware byte accounting: codes + scales, not the dense figure
    assert pages.pool_bytes(pool, sa) == leaf.nbytes
    dense_bytes = pages.kv_token_bytes(shape, ba, sa)
    stored = pages.kv_token_bytes_quant(shape, ba, sa, 4, "int8")
    assert stored == 3 * 2 * (16 * 1 + 4.0 / 4)   # L*Hkv*(hd + scale/ps)
    assert dense_bytes / stored >= 1.8            # the capacity headroom


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quant_insert_reconstruction_error_bounded(kv_dtype):
    """insert + gather round-trips within half a quantization step of each
    page's own scale (int8: |err| <= scale/2 elementwise)."""
    shape, ba, sa = _quant_shape()
    pool = pages.make_pool(shape, ba, sa, num_pages=7, page_size=4,
                           kv_dtype=kv_dtype)
    rng = np.random.default_rng(0)
    cache = jnp.asarray(rng.standard_normal((3, 1, 2, 24, 16)), jnp.bfloat16)
    table = jnp.arange(1, 7, dtype=jnp.int32)
    pool_k = pages.insert_tree(pool["k"], cache, table, jnp.int32(0),
                               ba["k"], sa["k"], n_tokens=jnp.int32(24))
    view = pages.gather_view(pool_k, table[None, :], ba["k"], sa["k"])
    err = jnp.abs(view.astype(jnp.float32) - cache.astype(jnp.float32))
    # per-(L, page, Hkv) bound, broadcast back over (ps, hd)
    sc = pool_k.scales[:, table]                  # (L, P, Hkv)
    sc = jnp.repeat(sc, 4, axis=1)                # (L, S, Hkv)
    bound = jnp.moveaxis(sc, 1, 2)[:, None, :, :, None]     # (L, 1, Hkv, S, 1)
    # int8: half a quantization step.  fp8 e4m3: half-ulp <= |code|/16 with
    # |code| <= 448, so 28 scale-units bounds it uniformly.
    half = 0.5 if kv_dtype == "int8" else 28.0
    assert bool(jnp.all(err <= bound * half + 1e-6))


def test_quant_fresh_page_resets_stale_scale():
    """A freed/evicted page reused by a new sequence must NOT inherit the
    old tenant's coarse scale: the off==0 append zeroes the stale scale
    and re-encodes from the fresh content alone."""
    codes = jnp.zeros((3, 4, 2, 8), jnp.int8)     # (N, ps, Hkv, hd)
    scales = jnp.zeros((3, 2), jnp.float32)
    big = 512.0 * jnp.ones((1, 2, 8), jnp.bfloat16)
    from repro.models.layers import quant_page_append
    codes, scales = quant_page_append(codes, scales, big,
                                      jnp.array([1]), jnp.array([0]), "int8")
    coarse = float(scales[1, 0])
    assert coarse >= 512.0 / 127
    # page 1 is recycled: a small token appended at offset 0 starts over
    small = 0.25 * jnp.ones((1, 2, 8), jnp.bfloat16)
    codes, scales = quant_page_append(codes, scales, small,
                                      jnp.array([1]), jnp.array([0]), "int8")
    assert float(scales[1, 0]) < coarse / 100
    deq = codes[1, 0].astype(jnp.float32) * scales[1][:, None]
    np.testing.assert_allclose(np.asarray(deq), 0.25, atol=1e-3)
    # within a page lifetime the scale is monotone: a later, larger token
    # recoarsens, an offset>0 smaller one never shrinks it
    codes, scales = quant_page_append(codes, scales, big,
                                      jnp.array([1]), jnp.array([1]), "int8")
    grown = float(scales[1, 0])
    assert grown >= coarse
    codes, scales = quant_page_append(codes, scales, small,
                                      jnp.array([1]), jnp.array([2]), "int8")
    assert float(scales[1, 0]) == grown


class _QuantStub(pages.PagedEngineMixin):
    """Minimal Mixin host: just enough state for apply_cow_copies and the
    _kv_bytes accounting helper."""
    def __init__(self, pager, kv_quant_tok_bytes, kv_tok_bytes):
        from repro.core.splitbrain import TrafficMeter
        self._pager = pager
        self._paging_active = True
        self.meter = TrafficMeter()
        self._kv_quant_tok_bytes = kv_quant_tok_bytes
        self._kv_tok_bytes = kv_tok_bytes
        self._kv_dtype = "int8"


def test_quant_scales_follow_pages_through_cow_copy():
    """apply_cow_copies moves codes AND scales src -> dst: the private
    copy dequantizes to exactly the shared page's values, and the metered
    copy bytes are the quantized page figure."""
    shape, ba, sa = _quant_shape()
    pool = pages.make_pool(shape, ba, sa, num_pages=7, page_size=4,
                           kv_dtype="int8")
    rng = np.random.default_rng(1)
    cache = jnp.asarray(rng.standard_normal((3, 1, 2, 24, 16)), jnp.bfloat16)
    table = jnp.arange(1, 7, dtype=jnp.int32)
    pool = dict(pool, k=pages.insert_tree(pool["k"], cache, table,
                                          jnp.int32(0), ba["k"], sa["k"],
                                          n_tokens=jnp.int32(24)))
    stored = pages.kv_token_bytes_quant(shape, ba, sa, 4, "int8")
    pager = pages.HostPager(page_size=4, num_pages=7, max_len=24)
    eng = _QuantStub(pager, stored, pages.kv_token_bytes(shape, ba, sa))
    out = eng.apply_cow_copies(pool, [(2, 5)], ba, sa)
    np.testing.assert_array_equal(np.asarray(out["k"].codes[:, 5]),
                                  np.asarray(out["k"].codes[:, 2]))
    np.testing.assert_array_equal(np.asarray(out["k"].scales[:, 5]),
                                  np.asarray(out["k"].scales[:, 2]))
    # the copy is metered in STORAGE bytes (quantized), not dense bytes
    assert eng.meter.host_channel_bytes("page_cow_copy") == \
        int(round(4 * stored))


def test_check_kv_dtype_validation():
    assert pages.check_kv_dtype("bf16", None) == "bf16"
    assert pages.check_kv_dtype("int8", 8) == "int8"
    assert pages.check_kv_dtype("fp8", 8) == "fp8"
    with pytest.raises(ValueError, match="kv_dtype"):
        pages.check_kv_dtype("int4", 8)
    with pytest.raises(ValueError, match="page_size"):
        pages.check_kv_dtype("int8", None)
