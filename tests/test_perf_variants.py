"""Correctness of every §Perf optimization variant vs the baseline path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ref
from repro.models import api


def _no_remat(cfg):
    return dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))


def test_h1_chunked_wkv_equals_naive_in_model():
    cfg = _no_remat(get_config("rwkv6-7b").reduced())
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab_size)
    base, _ = api.forward(params, toks, cfg)
    opt, _ = api.forward(params, toks, dataclasses.replace(cfg, rwkv_chunk=16))
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt), atol=5e-2)


def test_h5_associative_ssm_equals_sequential_in_model():
    cfg = _no_remat(get_config("hymba-1.5b").reduced())
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0, cfg.vocab_size)
    base, _ = api.forward(params, toks, cfg)
    opt, _ = api.forward(params, toks,
                         dataclasses.replace(cfg, ssm_scan="associative"))
    np.testing.assert_allclose(np.asarray(base), np.asarray(opt), atol=5e-2)


@pytest.mark.parametrize("strong_decay", [False, True])
def test_h5_associative_oracle_sweep(strong_decay):
    rng = np.random.default_rng(3)
    B, T, D, N = 2, 48, 8, 4
    x = jnp.asarray(rng.normal(size=(B, T, D)).astype(np.float32))
    hi = 2.0 if strong_decay else 0.3
    delta = jnp.asarray(rng.uniform(0.01, hi, (B, T, D)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.5, 16.0, (D, N)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, T, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, T, N)).astype(np.float32))
    y0, s0 = ref.selective_scan(x, delta, A, Bm, Cm)
    y1, s1 = ref.selective_scan_assoc(x, delta, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-4)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_h1_chunked_oracle_sweep(chunk):
    rng = np.random.default_rng(4)
    B, H, T, D = 1, 2, 64, 8
    r, k, v = (jnp.asarray(rng.normal(size=(B, H, T, D)).astype(np.float32))
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.8, 0.9995, (B, H, T, D)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, D)).astype(np.float32))
    y0, s0 = ref.rwkv6_scan(r, k, v, w, u)
    y1, s1 = ref.rwkv6_scan_chunked(r, k, v, w, u, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-3)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-3)


def test_h2_cache_write_paths_agree():
    from repro.models.layers import cache_write
    rng = np.random.default_rng(5)
    cache = jnp.asarray(rng.normal(size=(3, 2, 16, 4)).astype(np.float32))
    new = jnp.asarray(rng.normal(size=(3, 2, 1, 4)).astype(np.float32))
    pos = jnp.asarray([5, 5, 5], jnp.int32)  # lockstep
    a = cache_write(cache, new, pos, aligned=True)
    b = cache_write(cache, new, pos, aligned=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    # ragged positions only supported by the masked path
    posr = jnp.asarray([1, 7, 3], jnp.int32)
    c = cache_write(cache, new, posr, aligned=False)
    for i, p in enumerate([1, 7, 3]):
        np.testing.assert_allclose(np.asarray(c[i, :, p]), np.asarray(new[i, :, 0]))


def test_h3_quantized_model_forward_close():
    cfg = _no_remat(get_config("granite-8b").reduced())
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    qparams = api.quantize_model(params, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 16), 0, cfg.vocab_size)
    f, _ = api.forward(params, toks, cfg)
    q, _ = api.forward(qparams, toks, cfg)
    cc = np.corrcoef(np.asarray(f, np.float32).ravel(),
                     np.asarray(q, np.float32).ravel())[0, 1]
    assert cc > 0.95, cc
