"""Continuous-batching scheduler: N requests served via slot-based masked
batched decode must be token-identical to one-at-a-time fused ``generate()``,
with identical per-active-token TrafficMeter bytes — across the lm, rwkv and
hymba families — and the steady state must not recompile."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve import slots
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler, Request
from repro.serve.splitbrain_engine import SplitBrainEngine, traffic_model_for

MAX_NEW = 6
PROMPT_LENS = (1, 3, 5, 6, 4)


def _engine(arch, max_len=32, **kw):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, max_len=max_len, **kw)


def _prompts(cfg, seed=0, lens=PROMPT_LENS):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
            for t in lens]


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "rwkv6-7b", "hymba-1.5b",
                                  "gemma2-27b"])
def test_scheduler_matches_sequential_fused(arch):
    # gemma2 adds the sliding-window ring buffers (local/global alternation)
    # to the slot mix: ragged positions must ring-write per slot.
    """Tokens AND boundary bytes: continuous batching == sequential fused,
    per request, with max_slots < N forcing mid-flight admission."""
    cfg, eng = _engine(arch)
    prompts = _prompts(cfg)
    base, base_bytes = [], 0
    for p in prompts:
        eng.meter.reset()
        out = eng.generate(p[None, :], max_new=MAX_NEW)
        base.append(out["tokens"][0])
        base_bytes += eng.measured_bytes()["total"]

    eng.meter.reset()
    sched = ContinuousBatchingScheduler(eng, max_slots=2)
    res = sched.run([Request(uid=i, prompt=p, max_new=MAX_NEW)
                     for i, p in enumerate(prompts)])
    assert len(res["results"]) == len(prompts)
    for i, r in enumerate(res["results"]):
        assert r.uid == i
        np.testing.assert_array_equal(r.tokens, base[i])
        assert r.gen_len == MAX_NEW
    # masked-traffic accounting rule: only ACTIVE slots cross the boundary
    assert eng.measured_bytes()["total"] == base_bytes
    # analytical exactness: (T0-1 + gen) tokens per request, eq. 7-10 bytes each
    n_tok = sum(len(p) - 1 + MAX_NEW for p in prompts)
    assert eng.measured_bytes()["total"] == \
        n_tok * traffic_model_for(cfg).bytes_per_token()


def test_scheduler_eos_frees_slots_early():
    """A request hitting its stop token frees the slot mid-flight and the
    per-request tokens/gen_len still match the fused baseline."""
    cfg, eng = _engine("stablelm-1.6b")
    prompts = _prompts(cfg, seed=1)
    probe = eng.generate(prompts[1][None, :], max_new=MAX_NEW)
    eos = int(probe["tokens"][0, 2])   # a token the model really emits
    base = []
    for p in prompts:
        out = eng.generate(p[None, :], max_new=MAX_NEW, eos_id=eos)
        g = int(out["gen_len"][0])
        base.append((out["tokens"][0, :g], g))
    assert any(g < MAX_NEW for _, g in base), "eos never fired; bad probe"

    sched = ContinuousBatchingScheduler(eng, max_slots=2, eos_id=eos)
    res = sched.run([Request(uid=i, prompt=p, max_new=MAX_NEW)
                     for i, p in enumerate(prompts)])
    for i, r in enumerate(res["results"]):
        np.testing.assert_array_equal(r.tokens, base[i][0])
        assert r.gen_len == base[i][1]
    # no wasted decode steps past EOS: exactly the generated tokens decoded
    assert res["decoded_tokens"] == sum(g for _, g in base)


def test_scheduler_zero_recompiles_in_steady_state():
    """After one warmup pass over the bucket set, serving a fresh workload
    with the same buckets compiles NOTHING new."""
    cfg, eng = _engine("stablelm-1.6b")
    sched = ContinuousBatchingScheduler(eng, max_slots=2)
    reqs = [Request(uid=i, prompt=p, max_new=MAX_NEW)
            for i, p in enumerate(_prompts(cfg))]
    sched.run(reqs)
    counter = slots.CompileCounter.instance()
    c0 = counter.count
    out = sched.run([Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new)
                     for r in reqs])
    assert len(out["results"]) == len(reqs)
    if counter.available:
        assert counter.count == c0, "steady-state serve loop recompiled"


def test_splitbrain_scheduler_parity_and_traffic():
    """The split-brain engine serves continuously too: token parity with its
    fused generate, and measured bytes == analytical eq. 7-10 per active
    token."""
    cfg = get_config("tinyllama-1.1b").reduced(vocab_size=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = SplitBrainEngine(cfg, params, max_len=32, quantize=False)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
               for t in (2, 5, 3, 6)]
    base, n_tok = [], 0
    for p in prompts:
        out = eng.generate(p[None, :], max_new=5)
        base.append(out["tokens"][0])
        n_tok += len(p) - 1 + 5

    eng.meter.reset()
    sched = ContinuousBatchingScheduler(eng, max_slots=2)
    res = sched.run([Request(uid=i, prompt=p, max_new=5)
                     for i, p in enumerate(prompts)])
    for i, r in enumerate(res["results"]):
        np.testing.assert_array_equal(r.tokens, base[i])
    assert eng.measured_bytes_per_token(batch=1)["total"] == \
        n_tok * traffic_model_for(cfg).bytes_per_token()


def test_scheduler_eos_parity_with_fused_generate():
    """EOS semantics pinned: a request stopping on ``eos_id`` yields
    IDENTICAL tokens and gen_len from the continuous-batching scheduler and
    from the engine's fused generate(), and the EOS token itself IS counted
    (it is the last generated token and gen_len includes it)."""
    cfg, eng = _engine("stablelm-1.6b")
    prompts = _prompts(cfg, seed=1)
    probe = eng.generate(prompts[1][None, :], max_new=MAX_NEW)
    eos = int(probe["tokens"][0, 2])   # a token the model really emits
    base = []
    for p in prompts:
        out = eng.generate(p[None, :], max_new=MAX_NEW, eos_id=eos)
        base.append((out["tokens"][0], int(out["gen_len"][0])))
    stopped = [i for i, (_, g) in enumerate(base) if g < MAX_NEW]
    assert stopped, "eos never fired; bad probe"

    sched = ContinuousBatchingScheduler(eng, max_slots=2, eos_id=eos)
    res = sched.run([Request(uid=i, prompt=p, max_new=MAX_NEW)
                     for i, p in enumerate(prompts)])
    for i, r in enumerate(res["results"]):
        toks, g = base[i]
        assert r.gen_len == g, (i, r.gen_len, g)
        np.testing.assert_array_equal(r.tokens, toks[:g])
        assert r.gen_len == len(r.tokens)
    for i in stopped:
        r = res["results"][i]
        # EOS-inclusive counting: the stop token is emitted AND counted
        assert r.tokens[-1] == eos
        assert int((r.tokens == eos).sum()) >= 1
        # and the fused path pads past the stop with eos
        assert all(int(t) == eos for t in base[i][0][r.gen_len:])


def test_scheduler_rejects_oversized_requests_per_request():
    """An oversized request is rejected individually with a readable
    reason; the rest of the batch is served normally (and `python -O`
    can't strip the check — it is not an assert)."""
    cfg, eng = _engine("stablelm-1.6b")
    prompts = _prompts(cfg)
    reqs = [Request(uid=i, prompt=p, max_new=MAX_NEW)
            for i, p in enumerate(prompts)]
    rng = np.random.default_rng(3)
    reqs.insert(2, Request(
        uid=90, prompt=rng.integers(1, cfg.vocab_size, (40,)).astype(np.int32),
        max_new=MAX_NEW))
    reqs.append(Request(uid=91, prompt=prompts[0], max_new=0))
    sched = ContinuousBatchingScheduler(eng, max_slots=2)
    res = sched.run(reqs)
    assert [r.uid for r in res["results"]] == list(range(len(prompts)))
    rej = {r.uid: r.reason for r in res["rejected"]}
    assert set(rej) == {90, 91}
    assert "does not fit" in rej[90] and "max_len" in rej[90]
    for r in res["results"]:
        assert r.gen_len == MAX_NEW


def test_scheduler_reports_busy_time_separately():
    """Realtime arrival sleeps inflate wall time, not busy time: both rates
    are reported so idle-heavy Poisson traces stay honest."""
    cfg, eng = _engine("stablelm-1.6b")
    prompts = _prompts(cfg)[:2]
    sched = ContinuousBatchingScheduler(eng, max_slots=2)
    sched.warmup()
    reqs = [Request(uid=i, prompt=p, max_new=MAX_NEW,
                    arrival_s=0.3 * i) for i, p in enumerate(prompts)]
    res = sched.run(reqs, realtime=True)
    assert res["wall_s"] >= res["busy_s"] > 0.0
    assert abs(res["wall_s"] - res["busy_s"] - res["slept_s"]) < 1e-9
    assert res["slept_s"] > 0.0        # the 0.3s gap was idle, not busy
    assert res["tokens_per_s_busy"] >= res["tokens_per_s"]
    assert res["requests_per_s_busy"] >= res["requests_per_s"]


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "rwkv6-7b", "hymba-1.5b"])
def test_paged_scheduler_matches_dense_and_traffic(arch):
    """The paged slot cache (shared page pool + per-slot page tables) is
    token-identical to fused generate and byte-exact on the meter.  The
    recurrent families keep dense state (no-op page table) and must degrade
    gracefully; lm actually pages."""
    cfg, eng = _engine(arch, page_size=8, num_pages=9)
    prompts = _prompts(cfg)
    base, base_bytes = [], 0
    for p in prompts:
        eng.meter.reset()
        out = eng.generate(p[None, :], max_new=MAX_NEW)
        base.append(out["tokens"][0])
        base_bytes += eng.measured_bytes()["total"]

    eng.meter.reset()
    sched = ContinuousBatchingScheduler(eng, max_slots=2)
    res = sched.run([Request(uid=i, prompt=p, max_new=MAX_NEW)
                     for i, p in enumerate(prompts)])
    assert len(res["results"]) == len(prompts)
    for i, r in enumerate(res["results"]):
        np.testing.assert_array_equal(r.tokens, base[i])
    assert eng.measured_bytes()["total"] == base_bytes
    n_tok = sum(len(p) - 1 + MAX_NEW for p in prompts)
    assert eng.measured_bytes()["total"] == \
        n_tok * traffic_model_for(cfg).bytes_per_token()
    stats = eng.cache_stats(sched.cache)
    if arch == "stablelm-1.6b":
        # lm pages: pool resident bytes track occupancy, pool << dense
        assert "num_pages" in stats and stats["pages_in_use"] == 0
        assert 0 < stats["peak_pages_in_use"] <= 8
    else:
        # recurrent state does not scale with max_len -> dense fallback
        assert "num_pages" not in stats


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "rwkv6-7b"])
def test_chunked_prefill_parity(arch):
    """Chunked prefill (fixed-width chunks interleaved with decode) is
    token-identical to the monolithic-prefill scheduler and to fused
    generate — for the lm block chunk path AND the recurrent masked-scan
    fallback — with byte-exact traffic."""
    cfg, eng = _engine(arch)
    prompts = _prompts(cfg, lens=(1, 3, 9, 6, 13))   # multi-chunk bodies
    base = [eng.generate(p[None, :], max_new=MAX_NEW)["tokens"][0]
            for p in prompts]
    eng.meter.reset()
    sched = ContinuousBatchingScheduler(eng, max_slots=2, prefill_chunk=4)
    res = sched.run([Request(uid=i, prompt=p, max_new=MAX_NEW)
                     for i, p in enumerate(prompts)])
    for i, r in enumerate(res["results"]):
        np.testing.assert_array_equal(r.tokens, base[i])
    n_tok = sum(len(p) - 1 + MAX_NEW for p in prompts)
    assert eng.measured_bytes()["total"] == \
        n_tok * traffic_model_for(cfg).bytes_per_token()
    # exactly ONE chunk program width compiled, regardless of prompt mix
    assert eng.jit_cache_sizes()["chunk_widths"] == 1


def test_paged_chunked_zero_recompiles_in_steady_state():
    """Paged decode + chunked prefill keep PR 2's invariant: after one
    warmup pass over the buckets, a fresh workload compiles NOTHING —
    page-table updates are traced indices, not compile keys."""
    cfg, eng = _engine("stablelm-1.6b", page_size=8, num_pages=9)
    sched = ContinuousBatchingScheduler(eng, max_slots=2, prefill_chunk=4)
    reqs = [Request(uid=i, prompt=p, max_new=MAX_NEW)
            for i, p in enumerate(_prompts(cfg, lens=(1, 3, 9, 6, 13)))]
    sched.run(reqs)
    counter = slots.CompileCounter.instance()
    c0 = counter.count
    out = sched.run([Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new)
                     for r in reqs])
    assert len(out["results"]) == len(reqs)
    if counter.available:
        assert counter.count == c0, "paged steady-state serve loop recompiled"


def test_splitbrain_paged_chunked_parity_and_traffic():
    """The split-brain engine serves from the page pool with chunked
    prefill too: token parity with its fused generate, measured bytes ==
    analytical eq. 7-10 per active token, and pages drain back to zero."""
    cfg = get_config("tinyllama-1.1b").reduced(vocab_size=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    ref = SplitBrainEngine(cfg, params, max_len=32, quantize=False)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
               for t in (2, 9, 3, 6)]
    base, n_tok = [], 0
    for p in prompts:
        out = ref.generate(p[None, :], max_new=5)
        base.append(out["tokens"][0])
        n_tok += len(p) - 1 + 5

    eng = SplitBrainEngine(cfg, params, max_len=32, quantize=False,
                           page_size=8, num_pages=9)
    sched = ContinuousBatchingScheduler(eng, max_slots=2, prefill_chunk=4)
    res = sched.run([Request(uid=i, prompt=p, max_new=5)
                     for i, p in enumerate(prompts)])
    for i, r in enumerate(res["results"]):
        np.testing.assert_array_equal(r.tokens, base[i])
    assert eng.measured_bytes_per_token(batch=1)["total"] == \
        n_tok * traffic_model_for(cfg).bytes_per_token()
    stats = eng.cache_stats(sched.cache)
    assert stats["pages_in_use"] == 0 and stats["peak_pages_in_use"] > 0


def test_paged_pool_admission_waits_and_rejects():
    """A request larger than the whole pool is rejected with a readable
    reason; requests that fit only sequentially are served by waiting for
    pages to free rather than deadlocking."""
    cfg, eng = _engine("stablelm-1.6b", page_size=8, num_pages=3)
    # pool capacity: 2 real pages = 16 token positions
    prompts = _prompts(cfg, lens=(5, 4, 6))
    # needs ceil((12-1+6)/8)=3 pages > capacity 2 -> statically impossible;
    # placed at the HEAD of the queue it must be rejected immediately, not
    # head-of-line-block the admittable requests behind it
    rng = np.random.default_rng(5)
    reqs = [Request(
        uid=77, prompt=rng.integers(1, cfg.vocab_size, (12,)).astype(np.int32),
        max_new=MAX_NEW)]
    reqs += [Request(uid=i, prompt=p, max_new=MAX_NEW)
             for i, p in enumerate(prompts)]
    sched = ContinuousBatchingScheduler(eng, max_slots=3)
    res = sched.run(reqs)
    assert [r.uid for r in res["results"]] == [0, 1, 2]
    assert [r.uid for r in res["rejected"]] == [77]
    assert "page pool" in res["rejected"][0].reason


def test_slot_insert_and_axes_discovery():
    """batch_axes finds the batch dim of every cache leaf across families;
    insert writes a B=1 cache into the right slot."""
    for arch in ["stablelm-1.6b", "rwkv6-7b", "hymba-1.5b"]:
        cfg, eng = _engine(arch, max_len=16)
        axes = eng._slot_axes()
        flat, _ = jax.tree.flatten(axes)
        assert all(isinstance(a, int) for a in flat)
        cache = eng.init_slot_cache(3)
        single, tok = eng.prefill_slot(np.asarray([5, 9, 11], np.int32))
        assert tok == 11
        cache = eng.insert_slot(cache, single, 1)
        lens = np.asarray(cache["len"])
        assert lens[1] == 2 and lens[0] == 0 and lens[2] == 0, (arch, lens)
