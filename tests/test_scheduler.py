"""Continuous-batching scheduler: N requests served via slot-based masked
batched decode must be token-identical to one-at-a-time fused ``generate()``,
with identical per-active-token TrafficMeter bytes — across the lm, rwkv and
hymba families — and the steady state must not recompile."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve import slots
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler, Request
from repro.serve.splitbrain_engine import SplitBrainEngine, traffic_model_for

MAX_NEW = 6
PROMPT_LENS = (1, 3, 5, 6, 4)


def _engine(arch, max_len=32):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, max_len=max_len)


def _prompts(cfg, seed=0, lens=PROMPT_LENS):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
            for t in lens]


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "rwkv6-7b", "hymba-1.5b",
                                  "gemma2-27b"])
def test_scheduler_matches_sequential_fused(arch):
    # gemma2 adds the sliding-window ring buffers (local/global alternation)
    # to the slot mix: ragged positions must ring-write per slot.
    """Tokens AND boundary bytes: continuous batching == sequential fused,
    per request, with max_slots < N forcing mid-flight admission."""
    cfg, eng = _engine(arch)
    prompts = _prompts(cfg)
    base, base_bytes = [], 0
    for p in prompts:
        eng.meter.reset()
        out = eng.generate(p[None, :], max_new=MAX_NEW)
        base.append(out["tokens"][0])
        base_bytes += eng.measured_bytes()["total"]

    eng.meter.reset()
    sched = ContinuousBatchingScheduler(eng, max_slots=2)
    res = sched.run([Request(uid=i, prompt=p, max_new=MAX_NEW)
                     for i, p in enumerate(prompts)])
    assert len(res["results"]) == len(prompts)
    for i, r in enumerate(res["results"]):
        assert r.uid == i
        np.testing.assert_array_equal(r.tokens, base[i])
        assert r.gen_len == MAX_NEW
    # masked-traffic accounting rule: only ACTIVE slots cross the boundary
    assert eng.measured_bytes()["total"] == base_bytes
    # analytical exactness: (T0-1 + gen) tokens per request, eq. 7-10 bytes each
    n_tok = sum(len(p) - 1 + MAX_NEW for p in prompts)
    assert eng.measured_bytes()["total"] == \
        n_tok * traffic_model_for(cfg).bytes_per_token()


def test_scheduler_eos_frees_slots_early():
    """A request hitting its stop token frees the slot mid-flight and the
    per-request tokens/gen_len still match the fused baseline."""
    cfg, eng = _engine("stablelm-1.6b")
    prompts = _prompts(cfg, seed=1)
    probe = eng.generate(prompts[1][None, :], max_new=MAX_NEW)
    eos = int(probe["tokens"][0, 2])   # a token the model really emits
    base = []
    for p in prompts:
        out = eng.generate(p[None, :], max_new=MAX_NEW, eos_id=eos)
        g = int(out["gen_len"][0])
        base.append((out["tokens"][0, :g], g))
    assert any(g < MAX_NEW for _, g in base), "eos never fired; bad probe"

    sched = ContinuousBatchingScheduler(eng, max_slots=2, eos_id=eos)
    res = sched.run([Request(uid=i, prompt=p, max_new=MAX_NEW)
                     for i, p in enumerate(prompts)])
    for i, r in enumerate(res["results"]):
        np.testing.assert_array_equal(r.tokens, base[i][0])
        assert r.gen_len == base[i][1]
    # no wasted decode steps past EOS: exactly the generated tokens decoded
    assert res["decoded_tokens"] == sum(g for _, g in base)


def test_scheduler_zero_recompiles_in_steady_state():
    """After one warmup pass over the bucket set, serving a fresh workload
    with the same buckets compiles NOTHING new."""
    cfg, eng = _engine("stablelm-1.6b")
    sched = ContinuousBatchingScheduler(eng, max_slots=2)
    reqs = [Request(uid=i, prompt=p, max_new=MAX_NEW)
            for i, p in enumerate(_prompts(cfg))]
    sched.run(reqs)
    counter = slots.CompileCounter.instance()
    c0 = counter.count
    out = sched.run([Request(uid=r.uid, prompt=r.prompt, max_new=r.max_new)
                     for r in reqs])
    assert len(out["results"]) == len(reqs)
    if counter.available:
        assert counter.count == c0, "steady-state serve loop recompiled"


def test_splitbrain_scheduler_parity_and_traffic():
    """The split-brain engine serves continuously too: token parity with its
    fused generate, and measured bytes == analytical eq. 7-10 per active
    token."""
    cfg = get_config("tinyllama-1.1b").reduced(vocab_size=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = SplitBrainEngine(cfg, params, max_len=32, quantize=False)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
               for t in (2, 5, 3, 6)]
    base, n_tok = [], 0
    for p in prompts:
        out = eng.generate(p[None, :], max_new=5)
        base.append(out["tokens"][0])
        n_tok += len(p) - 1 + 5

    eng.meter.reset()
    sched = ContinuousBatchingScheduler(eng, max_slots=2)
    res = sched.run([Request(uid=i, prompt=p, max_new=5)
                     for i, p in enumerate(prompts)])
    for i, r in enumerate(res["results"]):
        np.testing.assert_array_equal(r.tokens, base[i])
    assert eng.measured_bytes_per_token(batch=1)["total"] == \
        n_tok * traffic_model_for(cfg).bytes_per_token()


def test_slot_insert_and_axes_discovery():
    """batch_axes finds the batch dim of every cache leaf across families;
    insert writes a B=1 cache into the right slot."""
    for arch in ["stablelm-1.6b", "rwkv6-7b", "hymba-1.5b"]:
        cfg, eng = _engine(arch, max_len=16)
        axes = eng._slot_axes()
        flat, _ = jax.tree.flatten(axes)
        assert all(isinstance(a, int) for a in flat)
        cache = eng.init_slot_cache(3)
        single, tok = eng.prefill_slot(np.asarray([5, 9, 11], np.int32))
        assert tok == 11
        cache = eng.insert_slot(cache, single, 1)
        lens = np.asarray(cache["len"])
        assert lens[1] == 2 and lens[0] == 0 and lens[2] == 0, (arch, lens)
