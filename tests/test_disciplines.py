"""The serve-discipline registry (repro/serve/disciplines.py) is the ONE
source of truth: the README table is generated from it, the bench artifacts
must declare it, and the bench FAILs on partial coverage.  These pins make
"add a discipline" a one-entry change that cannot silently drift."""
from pathlib import Path

from repro.serve.disciplines import DISCIPLINES, NAMES, markdown_table

REPO = Path(__file__).resolve().parent.parent


def test_registry_shape():
    assert len(DISCIPLINES) == len(set(NAMES)), "duplicate discipline names"
    # the mesh-sharded serving PR's entry must exist and gate exactness
    tp = {d.name: d for d in DISCIPLINES}["tp"]
    assert "token identity" in tp.gate
    for d in DISCIPLINES:
        assert d.name and d.title and d.gate


def test_readme_table_is_generated_copy():
    """README's discipline table == markdown_table() verbatim; regenerate
    with `python -m repro.serve.disciplines`, don't hand-edit."""
    readme = (REPO / "README.md").read_text()
    assert markdown_table() in readme, (
        "README discipline table drifted from the registry — regenerate it "
        "with: PYTHONPATH=src python -m repro.serve.disciplines")


def test_checked_in_artifact_declares_registry():
    import json
    report = json.loads((REPO / "BENCH_serve.json").read_text())
    assert report.get("disciplines") == list(NAMES), (
        "BENCH_serve.json was generated against a different registry — "
        "regenerate with benchmarks/serve_bench.py")


def test_tables_csv_covers_registry():
    from benchmarks.tables import serve_disciplines
    rows = serve_disciplines()
    names = {r[0].split(".")[-1] for r in rows if r[0].count(".") == 2}
    assert names == set(NAMES)
