"""HLO roofline analyzer: trip-count weighting, dot flops, collective bytes,
fusion-boundary slice accounting — validated against hand-computable programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_trip_count_weighting():
    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), ()
        return jax.lax.scan(body, x, w)[0].sum()

    c = _compile(f, jax.ShapeDtypeStruct((6, 32, 32), jnp.float32),
                 jax.ShapeDtypeStruct((8, 32), jnp.float32))
    t = H.analyze(c.as_text())
    assert t.flops_per_chip == pytest.approx(6 * 2 * 8 * 32 * 32, rel=0.01)


def test_nested_scan_multiplies():
    def f(x):
        def outer(x, _):
            def inner(x, _):
                return jnp.tanh(x @ x), ()
            x, _ = jax.lax.scan(inner, x, None, length=3)
            return x, ()
        return jax.lax.scan(outer, x, None, length=5)[0].sum()

    c = _compile(f, jax.ShapeDtypeStruct((16, 16), jnp.float32))
    t = H.analyze(c.as_text())
    assert t.flops_per_chip == pytest.approx(15 * 2 * 16**3, rel=0.01)


def test_scan_weight_slice_not_overcounted():
    """Slicing per-layer weights from a stacked array must count ONE layer's
    bytes per iteration, not the whole stack (fusion-boundary rule)."""
    L, D = 10, 64
    def f(w, x):
        def body(x, wi):
            return x @ wi, ()
        return jax.lax.scan(body, x, w)[0].sum()

    c = _compile(f, jax.ShapeDtypeStruct((L, D, D), jnp.float32),
                 jax.ShapeDtypeStruct((4, D), jnp.float32))
    t = H.analyze(c.as_text())
    stack_bytes = L * D * D * 4
    # generous bound: well under touching the whole stack every iteration
    assert t.mem_bytes_per_chip < 4 * stack_bytes, (
        t.mem_bytes_per_chip, L * stack_bytes)


def test_roofline_term_math():
    r = H.Roofline(hlo_flops=197e12 * 256, hlo_bytes=819e9 * 256,
                   coll_bytes_per_chip=50e9, chips=256,
                   model_flops=197e12 * 128, model_bytes=0.0)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(1.0)
    assert r.useful_flops_frac == pytest.approx(0.5)
    assert r.roofline_frac == pytest.approx(0.5)


def test_decode_memory_floor_rules_roofline():
    # memory-floor-bound workload: ideal time set by bytes, not flops
    r = H.Roofline(hlo_flops=1e12, hlo_bytes=819e9 * 2, coll_bytes_per_chip=0,
                   chips=1, model_flops=1e9, model_bytes=819e9)
    assert r.t_ideal == pytest.approx(1.0)   # bytes floor dominates
    assert r.bottleneck == "memory"
    assert r.roofline_frac == pytest.approx(0.5)
