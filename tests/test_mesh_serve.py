"""Tensor-parallel mesh-sharded serving (DESIGN.md §11).

Subprocess multi-device tests (forced host devices, see conftest.run_multidev):
for each slot-servable family the masked decode step runs on a (1, tp) mesh
and must produce TOKEN-IDENTICAL greedy output to the 1-device engine, with
byte-identical traffic totals (per-shard entries sum exactly) and ZERO
steady-state recompiles.  Also exercises the TP paged-attention kernel
dispatch: the head-cut grid (Hkv % tp == 0, no collective) and the
page-split + LSE-merge fallback (Hkv < tp).
"""
import pytest

from conftest import run_multidev

_SCRIPT = """
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.configs import get_config
    from repro.launch.mesh import make_test_mesh
    from repro.models import api
    from repro.serve import slots as slots_mod
    from repro.serve.engine import ServeEngine
    from repro.serve.splitbrain_engine import SplitBrainEngine

    TP = {tp}
    STEPS = 8
    assert jax.device_count() == TP, jax.devices()
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 127, size=n).astype(np.int32) for n in (7, 12)]

    def slot_run(eng):
        # admit -> prefill -> insert -> masked decode loop (slot protocol)
        cache = eng.init_slot_cache(2)
        toks = np.zeros((2,), np.int32)
        for i, p in enumerate(prompts):
            assert eng.reserve_slot(i, len(p), STEPS + 2)
            c1, tok = eng.prefill_slot(p)
            cache = eng.insert_slot(cache, c1, i)
            toks[i] = tok
        active = np.array([True, True])
        outs, c0 = [], None
        for k in range(STEPS):
            if k == 2:   # steps 0-1 may compile; after that: never again
                c0 = slots_mod.CompileCounter.instance().count
            nxt, ok, cache = eng.decode_slots(cache, toks, active)
            assert bool(np.asarray(ok).all()), "finite-logits sentinel"
            eng.meter_tokens(2)
            toks = np.asarray(nxt)
            outs.append(toks.copy())
        recompiles = slots_mod.CompileCounter.instance().count - c0
        if hasattr(eng, "measured_bytes_per_token"):
            nbytes = eng.measured_bytes_per_token()
        else:
            nbytes = eng.measured_bytes()
        return np.stack(outs), nbytes, eng.cache_stats(cache), recompiles

    def check_family(name, ctor, kv_shards=None):
        base = ctor(make_test_mesh(devices=jax.devices()[:1]))
        o1, b1, _, r1 = slot_run(base)
        eng = ctor(make_test_mesh(shape=(1, TP)))
        o2, b2, stats, r2 = slot_run(eng)
        assert np.array_equal(o1, o2), (name, o1, o2)
        assert b1 == b2, (name, b1, b2)   # per-shard entries sum exactly
        assert r1 == 0 and r2 == 0, (name, r1, r2)
        if kv_shards is not None:
            assert stats["kv_shards"] == kv_shards, (name, stats)
        print("FAMILY_{{}}_OK kv_shards={{}} traffic_shards={{}}".format(
            name, stats.get("kv_shards"), eng.traffic_shards))

    def serve(cfg):
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        return lambda mesh: ServeEngine(cfg, params, mesh=mesh, max_len=48,
                                        page_size=8, paged_attn="inplace")

    # llama2 reduced: Hkv=4 — the pool head-cuts at every tested tp
    lm_cfg = get_config("llama2-7b").reduced(vocab_size=128)
    check_family("lm", serve(lm_cfg), kv_shards=TP)
    # gemma2 reduced: GQA Hkv=2 — replicates at tp=4 (fallback), parity holds
    check_family("gemma2", serve(get_config("gemma2-27b").reduced(
        vocab_size=128)))
    check_family("hymba", serve(get_config("hymba-1.5b").reduced(
        vocab_size=128)))
    check_family("rwkv", serve(get_config("rwkv6-7b").reduced(
        vocab_size=128)))

    sb_cfg = get_config("llama2-7b").reduced(vocab_size=128)
    sb_params = api.init_params(sb_cfg, jax.random.PRNGKey(1))
    check_family("splitbrain",
                 lambda mesh: SplitBrainEngine(sb_cfg, sb_params, max_len=48,
                                               page_size=8,
                                               paged_attn="inplace",
                                               mesh=mesh),
                 kv_shards=TP)

    # ---- TP paged-attention kernel dispatch (interpret-mode Pallas) --------
    from repro.kernels import ops
    from repro.kernels import paged_attention as _pa
    mesh = make_test_mesh(shape=(1, TP))

    def kernel_case(Hq, Hkv, name):
        B, D, ps, N, Pg = 3, 16, 8, 12, 4
        q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), jnp.float32)
        kp = jnp.asarray(rng.standard_normal((N, ps, Hkv, D)), jnp.float32)
        vp = jnp.asarray(rng.standard_normal((N, ps, Hkv, D)), jnp.float32)
        table = jnp.asarray(
            rng.permutation(N)[: B * Pg].reshape(B, Pg), jnp.int32)
        lens = jnp.asarray([1, 9, 30], jnp.int32)
        want = _pa.paged_decode_attention(q, kp, vp, table, lens, softcap=2.0)
        with mesh:
            got = ops.paged_decode_attention(q, kp, vp, table, lens,
                                             softcap=2.0, use_pallas=True,
                                             model_axis="model")
        err = float(jnp.max(jnp.abs(want - got)))
        assert err < 1e-5, (name, err)

    kernel_case(4, TP, "head_cut")   # Hkv % tp == 0: per-shard grid
    kernel_case(4, 1, "merge")       # Hkv < tp: page split + LSE merge
    print("KERNEL_TP_OK")
    print("MESH_SERVE_OK")
"""

FAMILY_MARKERS = [f"FAMILY_{n}_OK" for n in
                  ("lm", "gemma2", "hymba", "rwkv", "splitbrain")]


@pytest.mark.slow
@pytest.mark.parametrize("tp", [2, 4], ids=["tp2", "tp4"])
def test_mesh_serve_token_parity(tp):
    run_multidev(_SCRIPT.format(tp=tp), devices=tp,
                 markers=FAMILY_MARKERS + ["KERNEL_TP_OK", "MESH_SERVE_OK"],
                 timeout=1800)
