"""Production serving engine: batched generate over multiple families."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import ServeEngine


@pytest.mark.parametrize("arch", ["granite-8b", "rwkv6-7b"])
def test_generate_batched(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=24)
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (3, 4)).astype(np.int32)
    out = eng.generate(prompts, max_new=5)
    assert out["tokens"].shape == (3, 5)
    assert (out["tokens"] >= 0).all() and (out["tokens"] < cfg.vocab_size).all()
    assert out["tokens_per_s"] > 0


def test_generate_deterministic():
    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=16)
    prompts = np.full((2, 3), 7, np.int32)
    a = eng.generate(prompts, max_new=4)["tokens"]
    b = eng.generate(prompts, max_new=4)["tokens"]
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a[0], a[1])  # identical prompts, greedy
