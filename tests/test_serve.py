"""Production serving engine: batched generate over multiple families."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import ServeEngine


@pytest.mark.parametrize("arch", ["granite-8b", "rwkv6-7b"])
def test_generate_batched(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=24)
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (3, 4)).astype(np.int32)
    out = eng.generate(prompts, max_new=5)
    assert out["tokens"].shape == (3, 5)
    assert (out["tokens"] >= 0).all() and (out["tokens"] < cfg.vocab_size).all()
    assert out["tokens_per_s"] > 0


def test_generate_deterministic():
    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=16)
    prompts = np.full((2, 3), 7, np.int32)
    a = eng.generate(prompts, max_new=4)["tokens"]
    b = eng.generate(prompts, max_new=4)["tokens"]
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a[0], a[1])  # identical prompts, greedy


def _make_engine(arch="stablelm-1.6b", max_len=64):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, max_len=max_len)


def test_jit_caches_are_bucketed():
    """The prefill/loop jit caches are keyed by power-of-two buckets, not by
    exact prompt_len/steps: O(log max_len) compiled programs, not O(#shapes)."""
    cfg, eng = _make_engine()
    rng = np.random.default_rng(0)
    for T0 in (2, 3, 4, 5, 6, 7, 9, 12, 17):
        eng.generate(rng.integers(1, cfg.vocab_size, (1, T0)).astype(np.int32),
                     max_new=4)
    for max_new in (3, 5, 6, 9):
        eng.generate(rng.integers(1, cfg.vocab_size, (1, 4)).astype(np.int32),
                     max_new=max_new)
    sizes = eng.jit_cache_sizes()
    # prompt bodies 1..16 -> buckets {1,2,4,8,16}; steps {3,4,5,6,9} -> {4,8,16}
    assert sizes["prefill_buckets"] <= 5, sizes
    assert sizes["loop_buckets"] <= 3, sizes


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "rwkv6-7b"])
def test_bucketed_prompt_matches_exact(arch):
    """Right-padded bucketed prefill must not change a single token: prompt
    lengths landing mid-bucket equal an unpadded power-of-two prompt run."""
    cfg, eng = _make_engine(arch)
    rng = np.random.default_rng(2)
    full = rng.integers(1, cfg.vocab_size, (2, 9)).astype(np.int32)
    out_mid = eng.generate(full, max_new=5)             # body 8 -> bucket 8
    out_sub = eng.generate(full[:, :6], max_new=5)      # body 5 -> bucket 8
    # same engine, same bucket, different true_len: both must equal stepwise
    ref_mid = eng.generate(full, max_new=5, fused=False)
    ref_sub = eng.generate(full[:, :6], max_new=5, fused=False)
    np.testing.assert_array_equal(out_mid["tokens"], ref_mid["tokens"])
    np.testing.assert_array_equal(out_sub["tokens"], ref_sub["tokens"])


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "hymba-1.5b"])
@pytest.mark.parametrize("fused", [True, False])
def test_generate_eos_per_request(arch, fused):
    """Per-request stop tokens: rows pad with eos_id past their stop, gen_len
    reports exact generated length, pre-stop prefixes untouched.

    Each path is compared against its OWN no-eos probe: the fused and
    stepwise prefills have different f32 reduction orders, so their
    trajectories may split at argmax near-ties on long horizons (a property
    the max_new=5 cross-path parity tests bound) — eos must not change
    either trajectory before the stop.
    """
    cfg, eng = _make_engine(arch, max_len=32)
    rng = np.random.default_rng(3)
    prompts = rng.integers(1, cfg.vocab_size, (3, 4)).astype(np.int32)
    probe = eng.generate(prompts, max_new=8, fused=fused)
    eos = int(probe["tokens"][0, 1])   # token the model emits at step 2
    out = eng.generate(prompts, max_new=8, eos_id=eos, fused=fused)
    g0 = int(out["gen_len"][0])
    assert g0 <= 2   # the probe emits eos at step 2 (or step 1 on repeats)
    row = out["tokens"][0]
    assert row[g0 - 1] == eos and (row[g0:] == eos).all()
    assert int((out["gen_len"] < 8).sum()) >= 1
    for b in range(3):
        g = int(out["gen_len"][b])
        if g < 8:
            assert out["tokens"][b, g - 1] == eos
            assert (out["tokens"][b, g:] == eos).all()
        # the stop token must not perturb the pre-stop trajectory
        np.testing.assert_array_equal(
            out["tokens"][b, :g], probe["tokens"][b, :g])


def test_generate_batch_bucketing_pads_and_slices():
    """Odd batch sizes are padded to the next power of two internally and
    sliced back: outputs identical to the unpadded reference."""
    cfg, eng = _make_engine(max_len=16)
    rng = np.random.default_rng(4)
    prompts = rng.integers(1, cfg.vocab_size, (3, 4)).astype(np.int32)
    out = eng.generate(prompts, max_new=4)
    ref = eng.generate(prompts, max_new=4, fused=False)
    assert out["tokens"].shape == (3, 4)
    np.testing.assert_array_equal(out["tokens"], ref["tokens"])
