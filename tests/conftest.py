import os
import subprocess
import sys
import textwrap

# Tests must see exactly ONE device (the dry-run alone uses 512 placeholders);
# cap compilation parallelism for the single-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


def run_multidev(script, *, devices=8, markers=(), timeout=1200):
    """Run ``script`` in a subprocess with ``devices`` forced host devices.

    The main pytest process must keep exactly 1 device, so every multi-device
    test re-execs python with XLA_FLAGS=--xla_force_host_platform_device_count
    set *before* jax imports. ``script`` is dedented, must NOT import jax at
    top level itself before the flag (we prepend the env setup), and should
    print each marker in ``markers`` on success. Returns the CompletedProcess
    so callers can assert on extra stdout.
    """
    prologue = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={int(devices)} "
            + os.environ.get("XLA_FLAGS", ""))
    """)
    env = dict(os.environ)
    # pin the host platform: the forced-device-count flag applies to the CPU
    # backend, and letting jax probe for accelerators stalls the subprocess
    # on containers with a TPU runtime installed but no TPU attached
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run(
        [sys.executable, "-c", prologue + textwrap.dedent(script)],
        env=env, capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in markers:
        assert marker in r.stdout, (marker, r.stdout, r.stderr)
    return r
