import os

# Tests must see exactly ONE device (the dry-run alone uses 512 placeholders);
# cap compilation parallelism for the single-core container.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
