"""Split-Brain engine: measured interface traffic == analytical model, and
the partitioned (device/host) execution matches the monolithic decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.splitbrain import TrafficModel
from repro.models import api
from repro.serve.splitbrain_engine import SplitBrainEngine, traffic_model_for


@pytest.fixture(scope="module")
def small_lm():
    cfg = get_config("llama2-7b").reduced(vocab_size=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_measured_traffic_equals_analytical_model(small_lm):
    """The runtime byte meter must agree EXACTLY with eq. 7-10 for the
    engine's architecture (scaled-down llama config)."""
    cfg, params = small_lm
    eng = SplitBrainEngine(cfg, params, max_len=16, quantize=False)
    cache = eng.init_cache(batch=2)
    tok = jnp.zeros((2,), jnp.int32)
    eng.meter.reset()
    _, _, cache = eng.decode_token(cache, tok)
    measured = eng.measured_bytes_per_token(batch=2)
    tm = traffic_model_for(cfg)
    assert measured["total"] == tm.bytes_per_token()
    assert measured["d2h"] == (tm.device_to_host_kv_bytes_per_layer()
                               * cfg.num_layers + tm.logits_bytes())
    assert measured["h2d"] == (tm.host_to_device_attn_bytes_per_layer()
                               * cfg.num_layers)


def test_split_brain_equals_monolithic_decode(small_lm):
    """Partitioning must not change the math: unquantized split-brain decode
    == the production decode_step, token for token."""
    cfg, params = small_lm
    eng = SplitBrainEngine(cfg, params, max_len=16, quantize=False)
    cache_sb = eng.init_cache(batch=2)
    cache_mono = api.init_cache(cfg, 2, 16)
    toks = np.random.default_rng(0).integers(0, cfg.vocab_size, (2,))
    tok = jnp.asarray(toks, jnp.int32)
    for _ in range(4):
        nxt_sb, logits_sb, cache_sb = eng.decode_token(cache_sb, tok)
        logits_mono, cache_mono = api.decode_step(params, cache_mono, tok, cfg)
        np.testing.assert_allclose(np.asarray(logits_sb),
                                   np.asarray(logits_mono),
                                   rtol=2e-2, atol=2e-2)
        tok = nxt_sb


def test_quantized_decode_stays_close(small_lm):
    """LAQ W4A8 projections perturb logits only mildly (top-1 mostly stable
    on a random tiny model; the paper's accuracy claim §VII-G)."""
    cfg, params = small_lm
    eng_f = SplitBrainEngine(cfg, params, max_len=16, quantize=False)
    eng_q = SplitBrainEngine(cfg, params, max_len=16, quantize=True)
    tok = jnp.zeros((4,), jnp.int32)
    _, logits_f, _ = eng_f.decode_token(eng_f.init_cache(4), tok)
    _, logits_q, _ = eng_q.decode_token(eng_q.init_cache(4), tok)
    f = np.asarray(logits_f, np.float32)
    q = np.asarray(logits_q, np.float32)
    # correlation of logits stays high under W4A8
    cc = np.corrcoef(f.ravel(), q.ravel())[0, 1]
    assert cc > 0.95, cc


def test_bandwidth_requirement_all_archs_under_pcie():
    """Every assigned decoder backbone needs < 100 MB/s at 20 tok/s — far
    below PCIe 3.0 x4 (the paper's deployability argument, generalized)."""
    from repro.configs import ASSIGNED
    for name in ASSIGNED:
        cfg = get_config(name)
        tm = TrafficModel(num_layers=cfg.num_layers, d_model=cfg.d_model,
                          kv_dim=cfg.kv_dim, vocab_size=cfg.vocab_size)
        assert tm.bandwidth_bytes_per_s(20) < 100e6, name
