"""Gather-free paged decode attention (DESIGN.md §6).

Three layers of parity, each against the previous verified path:

  * the jnp scan-over-pages oracle (``ref.paged_decode_attention``) vs
    gathering the dense view and running dense ``ref.decode_attention`` —
    swept over page_size (1 / odd / 8), ragged cache lengths, GQA groups,
    sliding window, softcap  [tier-1],
  * the Pallas kernel (TPU interpreter on CPU) vs the oracle  [slow],
  * the in-place paged engines (``paged_attn="inplace"``) vs the PR-3
    gather discipline, token-identical through the continuous-batching
    scheduler across lm / mixed-window lm / hymba mixes and the
    split-brain engine  [tier-1],

plus the live-page KV-read accounting the in-place path exists for.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import LayerSpec
from repro.kernels import ref
from repro.models import api
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler, Request
from repro.serve.splitbrain_engine import SplitBrainEngine

MAX_NEW = 6
PROMPT_LENS = (1, 3, 5, 9, 4)


def _rand_paged(rng, B, Hq, Hkv, D, ps, P):
    """Random pool + per-slot tables (distinct pages, page 0 = scratch) +
    ragged lens, and the dense view gathered through the table."""
    N = B * P + 1
    q = jnp.asarray(rng.standard_normal((B, Hq, 1, D)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((N, ps, Hkv, D)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((N, ps, Hkv, D)), jnp.float32)
    # disjoint pages per slot, like the real allocator (page 0 = scratch)
    table = rng.permutation(np.arange(1, N))[:B * P].reshape(B, P)
    table = table.astype(np.int32)
    lens = rng.integers(1, P * ps + 1, (B,)).astype(np.int32)
    dense_k = jnp.asarray(np.asarray(kp)[table].reshape(B, P * ps, Hkv, D)
                          .transpose(0, 2, 1, 3))
    dense_v = jnp.asarray(np.asarray(vp)[table].reshape(B, P * ps, Hkv, D)
                          .transpose(0, 2, 1, 3))
    return q, kp, vp, jnp.asarray(table), jnp.asarray(lens), dense_k, dense_v


CASES = [
    # (B, Hq, Hkv, D, ps, P, window, softcap)
    (3, 4, 2, 16, 8, 4, None, None),     # GQA, the serve default page size
    (2, 4, 4, 8, 1, 7, None, None),      # page_size=1: one token per page
    (3, 6, 2, 16, 3, 5, None, None),     # odd page size
    (2, 4, 1, 16, 8, 3, None, None),     # MQA (group = Hq)
    (3, 4, 2, 16, 4, 4, 5, None),        # sliding window
    (2, 4, 2, 16, 3, 4, 7, 30.0),        # window + softcap together
    (2, 8, 2, 32, 8, 2, None, 50.0),     # softcap (gemma2 style)
]


@pytest.mark.parametrize("case", CASES)
def test_oracle_matches_gather_plus_dense(case):
    """scan-over-pages online softmax == gather_view + dense softmax."""
    B, Hq, Hkv, D, ps, P, window, softcap = case
    rng = np.random.default_rng(hash(case) % 2**32)
    q, kp, vp, table, lens, dk, dv = _rand_paged(rng, B, Hq, Hkv, D, ps, P)
    want = ref.decode_attention(q, dk, dv, lens, window=window,
                                softcap=softcap)
    got = ref.paged_decode_attention(q, kp, vp, table, lens, window=window,
                                     softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-5)


def test_oracle_never_reads_unallocated_pages():
    """Positions past ``cache_len`` are masked, so garbage on the scratch
    page (or stale freed pages) cannot leak into live slots' outputs."""
    rng = np.random.default_rng(0)
    q, kp, vp, table, lens, dk, dv = _rand_paged(rng, 2, 4, 2, 16, 4, 4)
    lens = jnp.asarray([3, 9], jnp.int32)
    base = ref.paged_decode_attention(q, kp, vp, table, lens)
    # poison every page beyond each slot's live prefix AND the scratch page
    poison = np.asarray(kp).copy()
    poison[0] = 1e9                                    # scratch page
    for b, ln in enumerate([3, 9]):
        for p in range(-(-ln // 4), 4):
            poison[int(table[b, p])] = 1e9
    got = ref.paged_decode_attention(q, jnp.asarray(poison), vp, table, lens)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


# -------------------------------------------- quantized pools (DESIGN.md §13)
def _quantize(pool, kv_dtype):
    """Reference whole-pool quantizer: per-page, per-kv-head pow2 scales."""
    from repro.core.quant import QuantizedLeaf
    from repro.models.layers import kv_pow2_scale, kv_quantize
    amax = jnp.max(jnp.abs(pool), axis=(1, 3))
    sc = kv_pow2_scale(amax, kv_dtype)
    codes = kv_quantize(pool, sc[:, None, :, None], kv_dtype)
    return QuantizedLeaf(codes, sc, kv_dtype, "float32")


@pytest.mark.parametrize("case", CASES)
def test_quant_oracle_matches_dequantized_dense(case):
    """ref oracle with k_scale/v_scale == dense softmax over the explicitly
    dequantized view: fused dequant changes where the multiply happens,
    not the math."""
    B, Hq, Hkv, D, ps, P, window, softcap = case
    rng = np.random.default_rng(hash(case) % 2**32)
    q, kp, vp, table, lens, _, _ = _rand_paged(rng, B, Hq, Hkv, D, ps, P)
    kq, vq = _quantize(kp, "int8"), _quantize(vp, "int8")
    deq = lambda z: (z.codes.astype(jnp.float32)
                     * z.scales[:, None, :, None])
    dk = jnp.asarray(np.asarray(deq(kq))[np.asarray(table)]
                     .reshape(B, P * ps, Hkv, D).transpose(0, 2, 1, 3))
    dv = jnp.asarray(np.asarray(deq(vq))[np.asarray(table)]
                     .reshape(B, P * ps, Hkv, D).transpose(0, 2, 1, 3))
    want = ref.decode_attention(q, dk, dv, lens, window=window,
                                softcap=softcap)
    got = ref.paged_decode_attention(q, kq.codes, vq.codes, table, lens,
                                     window=window, softcap=softcap,
                                     k_scale=kq.scales, v_scale=vq.scales)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-5)


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quant_ops_dispatch_unpacks_quantized_leaf(kv_dtype):
    """ops.paged_decode_attention accepts QuantizedLeaf pools directly and
    routes the scales to whichever backend runs."""
    from repro.kernels import ops
    rng = np.random.default_rng(7)
    q, kp, vp, table, lens, _, _ = _rand_paged(rng, 2, 4, 2, 16, 8, 3)
    kq, vq = _quantize(kp, kv_dtype), _quantize(vp, kv_dtype)
    want = ref.paged_decode_attention(q, kq.codes, vq.codes, table, lens,
                                      k_scale=kq.scales, v_scale=vq.scales)
    got = ops.paged_decode_attention(q, kq, vq, table, lens,
                                     use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.slow
@pytest.mark.parametrize("case", CASES)
def test_quant_pallas_kernel_matches_oracle(case):
    """The fused-dequant Pallas kernel (scales as scalar-prefetch operands
    3/4, per-page multiply at fetch) vs the scaled oracle — page sizes
    {1, odd, 8}, GQA/MQA, window, softcap, all on int8 pools."""
    from repro.kernels.paged_attention import paged_decode_attention
    B, Hq, Hkv, D, ps, P, window, softcap = case
    rng = np.random.default_rng(hash(case) % 2**32)
    q, kp, vp, table, lens, _, _ = _rand_paged(rng, B, Hq, Hkv, D, ps, P)
    kq, vq = _quantize(kp, "int8"), _quantize(vp, "int8")
    want = ref.paged_decode_attention(q, kq.codes, vq.codes, table, lens,
                                      window=window, softcap=softcap,
                                      k_scale=kq.scales, v_scale=vq.scales)
    got = paged_decode_attention(q, kq.codes, vq.codes, table, lens,
                                 window=window, softcap=softcap,
                                 k_scale=kq.scales, v_scale=vq.scales,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("case", CASES)
def test_pallas_kernel_matches_oracle(case):
    """The Pallas flash-decode kernel (TPU interpreter on CPU): scalar-
    prefetched page-table index maps, pl.when page skipping, online-softmax
    scratch accumulation — vs the jnp oracle."""
    from repro.kernels.paged_attention import paged_decode_attention
    B, Hq, Hkv, D, ps, P, window, softcap = case
    rng = np.random.default_rng(hash(case) % 2**32)
    q, kp, vp, table, lens, dk, dv = _rand_paged(rng, B, Hq, Hkv, D, ps, P)
    want = ref.paged_decode_attention(q, kp, vp, table, lens, window=window,
                                      softcap=softcap)
    got = paged_decode_attention(q, kp, vp, table, lens, window=window,
                                 softcap=softcap, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-6, rtol=2e-5)


# --------------------------------------------------------- engine parity
def _serve_engine(cfg, paged_attn, max_len=32, page_size=8, num_pages=None):
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return ServeEngine(cfg, params, max_len=max_len, page_size=page_size,
                       num_pages=num_pages, paged_attn=paged_attn)


def _mix_cfgs():
    lm = get_config("stablelm-1.6b").reduced()
    # gemma2: local ring slots stay dense, global slots page — the mixed
    # pattern exercises the "which leaves stay on the gather fallback" rule
    gemma = get_config("gemma2-27b").reduced()
    # hymba with global attention: paged K/V + dense SSM state in ONE step
    hymba = get_config("hymba-1.5b").reduced(
        layer_pattern=(LayerSpec(window=None),))
    out = []
    for cfg in (lm, gemma, hymba):
        out.append(dataclasses.replace(
            cfg, parallel=dataclasses.replace(cfg.parallel, remat="none")))
    return out


@pytest.mark.parametrize("cfg", _mix_cfgs(), ids=lambda c: c.name)
def test_inplace_matches_gather_through_scheduler(cfg):
    """paged_attn='inplace' (attention through the page table, no dense
    view) is token-identical to the PR-3 gather discipline under the
    continuous-batching scheduler, chunked prefill included."""
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
               for t in PROMPT_LENS]
    reqs = [Request(uid=i, prompt=p, max_new=MAX_NEW)
            for i, p in enumerate(prompts)]
    outs = {}
    for mode in ("gather", "inplace"):
        eng = _serve_engine(cfg, mode)
        sched = ContinuousBatchingScheduler(eng, max_slots=2,
                                            prefill_chunk=4)
        res = sched.run([dataclasses.replace(r) for r in reqs])
        assert not res["rejected"]
        assert eng._paging_active, "mix config was expected to page"
        outs[mode] = res["results"]
    for g, i in zip(outs["gather"], outs["inplace"]):
        assert g.uid == i.uid
        np.testing.assert_array_equal(g.tokens, i.tokens)
        assert g.gen_len == i.gen_len


def test_splitbrain_inplace_matches_gather():
    """Same parity for the split-brain engine's stacked (L, ...) pools."""
    cfg = get_config("tinyllama-1.1b").reduced(vocab_size=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
               for t in (2, 9, 3, 6)]
    outs = {}
    for mode in ("gather", "inplace"):
        eng = SplitBrainEngine(cfg, params, max_len=32, quantize=False,
                               page_size=8, num_pages=9, paged_attn=mode)
        sched = ContinuousBatchingScheduler(eng, max_slots=2,
                                            prefill_chunk=4)
        res = sched.run([Request(uid=i, prompt=p, max_new=5)
                         for i, p in enumerate(prompts)])
        outs[mode] = res["results"]
    for g, i in zip(outs["gather"], outs["inplace"]):
        np.testing.assert_array_equal(g.tokens, i.tokens)


def test_invalid_paged_attn_mode_rejected():
    cfg = get_config("stablelm-1.6b").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="paged_attn"):
        ServeEngine(cfg, params, max_len=32, page_size=8,
                    paged_attn="dense")


# --------------------------------------------- live-page KV-read accounting
def test_kv_read_accounting_counts_live_pages_only():
    """The meter's host_read channel: the gather discipline reads the full
    max_slots x max_len dense view every step; the in-place discipline
    reads only live pages of active slots — strictly fewer bytes on short
    sequences — and neither perturbs the eq. 7-10 boundary accounting."""
    cfg = _mix_cfgs()[0]
    rng = np.random.default_rng(2)
    prompts = [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
               for t in PROMPT_LENS]
    reqs = [Request(uid=i, prompt=p, max_new=MAX_NEW)
            for i, p in enumerate(prompts)]
    reads, boundary = {}, {}
    for mode in ("gather", "inplace"):
        eng = _serve_engine(cfg, mode)
        sched = ContinuousBatchingScheduler(eng, max_slots=2)
        res = sched.run([dataclasses.replace(r) for r in reqs])
        reads[mode] = (eng.meter.host_read_bytes, res["steps"],
                       eng._kv_tok_bytes)
        boundary[mode] = eng.measured_bytes()["total"]
    gb, steps, tok_bytes = reads["gather"]
    # gather: every step materializes (and reads) the whole dense view
    assert gb == steps * 2 * 32 * tok_bytes          # max_slots x max_len
    # in-place: strictly less — only live pages of active slots
    assert 0 < reads["inplace"][0] < gb
    # host reads live OUTSIDE the boundary log: eq. 7-10 bytes unchanged
    assert boundary["gather"] == boundary["inplace"] > 0


def test_gather_transient_metric():
    """gather_transient_bytes_per_step: the per-dispatch dense-view copy —
    nonzero for the gather discipline, ZERO for in-place (the serve_bench
    regression gate), zero for layouts that never page."""
    cfg = _mix_cfgs()[0]
    for mode, expect_zero in (("gather", False), ("inplace", True)):
        eng = _serve_engine(cfg, mode)
        eng.init_slot_cache(2)
        t = eng.gather_transient_bytes_per_step()
        assert (t == 0) == expect_zero, (mode, t)
        if not expect_zero:
            assert t == 2 * 32 * eng._kv_tok_bytes
    # rwkv: nothing pages, dense fallback, no transient in either mode
    rcfg = get_config("rwkv6-7b").reduced()
    params = api.init_params(rcfg, jax.random.PRNGKey(0))
    eng = ServeEngine(rcfg, params, max_len=32, page_size=8,
                      paged_attn="gather")
    eng.init_slot_cache(2)
    assert not eng._paging_active
    assert eng.gather_transient_bytes_per_step() == 0


def test_inplace_refuses_seq_sharded_decode():
    """ops.paged_decode_attention has no dist_axis variant: an in-place
    paged engine on a decode_attn='shard_map' config must refuse loudly
    WHEN PAGING ENGAGES instead of silently dropping the sharding (gather
    remains available, and never-paging families keep their dense
    fallback)."""
    def shard_map_cfg(name):
        cfg = get_config(name).reduced()
        return dataclasses.replace(
            cfg, parallel=dataclasses.replace(cfg.parallel,
                                              decode_attn="shard_map"))

    cfg = shard_map_cfg("stablelm-1.6b")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=32, page_size=8)
    with pytest.raises(ValueError, match="shard_map"):
        eng.init_slot_cache(2)
    gat = ServeEngine(cfg, params, max_len=32, page_size=8,
                      paged_attn="gather")
    gat.init_slot_cache(2)
    # a never-paging family with the same flags keeps its dense fallback
    rcfg = shard_map_cfg("rwkv6-7b")
    rparams = api.init_params(rcfg, jax.random.PRNGKey(0))
    reng = ServeEngine(rcfg, rparams, max_len=32, page_size=8)
    reng.init_slot_cache(2)
    assert not reng._paging_active


def test_zero_length_slot_returns_zeros():
    """cache_len == 0 masks every position: the oracle must return zeros
    (as the Pallas kernel's page-skip does), not an average of pool rows."""
    rng = np.random.default_rng(3)
    q, kp, vp, table, lens, dk, dv = _rand_paged(rng, 2, 4, 2, 16, 4, 4)
    lens = jnp.asarray([0, 9], jnp.int32)
    out = np.asarray(ref.paged_decode_attention(q, kp, vp, table, lens))
    np.testing.assert_array_equal(out[0], 0.0)
    assert np.abs(out[1]).max() > 0
