"""Serve-path sharding rules (DESIGN.md §11): head-cut KV slot caches and
page pools across the slot-servable families.

Pure PartitionSpec unit tests — TP degrees > 1 are exercised against an
``AbstractMesh`` (no extra devices needed), so this file is tier-1.  The
multi-device execution parity lives in tests/test_mesh_serve.py.
"""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.serve import pages as pages_mod
from repro.serve import slots as slots_mod

MAX_LEN, PS = 32, 8

# one config per slot-cache family: paged KV (lm), ring window (gemma2),
# KV + SSM recurrent mix (hymba), pure recurrent wkv state (rwkv)
FAMILIES = ["llama2-7b", "gemma2-27b", "hymba-1.5b", "rwkv6-7b"]


def tp_mesh(tp: int) -> AbstractMesh:
    return AbstractMesh((("data", 1), ("model", tp)))


@pytest.fixture(scope="module", params=FAMILIES)
def family(request):
    cfg = get_config(request.param).reduced(vocab_size=128)
    cache = jax.eval_shape(lambda: api.init_cache(cfg, 2, MAX_LEN))
    grown = jax.eval_shape(lambda: api.init_cache(cfg, 2, MAX_LEN + PS))
    b1 = jax.eval_shape(lambda: api.init_cache(cfg, 1, MAX_LEN))
    ba = slots_mod.batch_axes(b1, cache)
    sa = pages_mod.seq_axes(cache, grown, PS)
    return cfg, cache, ba, sa


def _leaves_with_paths(tree):
    return jax.tree_util.tree_flatten_with_path(tree)[0]


@pytest.mark.parametrize("tp", [2, 4])
def test_indivisible_dims_never_shard(family, tp):
    """_fit drops any axis whose size does not divide the dim: every
    'model' occurrence in a serve/pool spec must divide exactly."""
    cfg, cache, ba, sa = family
    mesh = tp_mesh(tp)
    specs = shd.serve_cache_pspecs(cache, cfg, mesh)
    pshape = pages_mod.pool_shape(cache, ba, sa, num_pages=16, page_size=PS)
    pool_specs = shd.pool_pspecs(pshape, cfg, mesh, sa)
    for tree, shapes in ((specs, cache), (pool_specs, pshape)):
        for (path, spec), (_, leaf) in zip(_leaves_with_paths(tree),
                                           _leaves_with_paths(shapes)):
            for i, axis in enumerate(tuple(spec)):
                if axis == "model":
                    assert leaf.shape[i] % tp == 0, (path, spec, leaf.shape)


def test_lm_kv_head_cut_and_fallback():
    """llama2 reduced has Hkv=2: tp=2 cuts the KV head axis, tp=4 (which
    does not divide it) replicates — the Hkv < tp fallback is the rules
    engine itself, not a special case."""
    cfg = get_config("llama2-7b").reduced(vocab_size=128, num_kv_heads=2)
    assert cfg.num_kv_heads == 2
    cache = jax.eval_shape(lambda: api.init_cache(cfg, 2, MAX_LEN))
    kv = [(p, s) for p, s in _leaves_with_paths(
        shd.serve_cache_pspecs(cfg=cfg, mesh=tp_mesh(2), cache=cache))
        if shd._path_str(p).split("/")[-1] in ("k", "v")
        or shd._path_str(p).split("/")[-2:-1] in (["k"], ["v"])]
    assert kv, "no KV leaves found"
    assert all("model" in tuple(s) for _, s in kv), kv
    kv4 = _leaves_with_paths(
        shd.serve_cache_pspecs(cfg=cfg, mesh=tp_mesh(4), cache=cache))
    assert all("model" not in tuple(s) for _, s in kv4)


@pytest.mark.parametrize("name,leaf_suffix,overrides", [
    # rwkv heads are d_model/64: widen so the head axis is tp-divisible
    ("rwkv6-7b", "wkv", {"d_model": 128}),   # (L, B, H, D, D): heads cut
    ("hymba-1.5b", "ssm", {}),               # (L, B, d, N): inner dim cut
])
def test_recurrent_state_cuts_on_model(name, leaf_suffix, overrides):
    cfg = get_config(name).reduced(vocab_size=128, **overrides)
    cache = jax.eval_shape(lambda: api.init_cache(cfg, 2, MAX_LEN))
    specs = shd.serve_cache_pspecs(cache, cfg, tp_mesh(2))
    hits = [(shd._path_str(p), s) for p, s in _leaves_with_paths(specs)
            if shd._path_str(p).endswith(leaf_suffix)]
    assert hits, f"no {leaf_suffix} leaves in {name} cache"
    for path, spec in hits:
        assert "model" in tuple(spec), (path, spec)


def test_pool_leaves_cut_on_kv_heads(family):
    """Paged leaves (s_ax >= 0) take the pool layout rule — 'model' lands
    on the Hkv axis (ndim-2) — while non-paging leaves keep serve rules."""
    cfg, cache, ba, sa = family
    mesh = tp_mesh(2)
    pshape = pages_mod.pool_shape(cache, ba, sa, num_pages=16, page_size=PS)
    specs = shd.pool_pspecs(pshape, cfg, mesh, sa)
    for (path, spec), (_, leaf), (_, s_ax) in zip(
            _leaves_with_paths(specs), _leaves_with_paths(pshape),
            _leaves_with_paths(sa)):
        if s_ax >= 0 and "model" in tuple(spec):
            assert tuple(spec)[leaf.ndim - 2] == "model", (path, spec)


def test_pool_kv_cut():
    cfg = get_config("llama2-7b").reduced(vocab_size=128, num_kv_heads=2)
    cache = jax.eval_shape(lambda: api.init_cache(cfg, 2, MAX_LEN))
    b1 = jax.eval_shape(lambda: api.init_cache(cfg, 1, MAX_LEN))
    grown = jax.eval_shape(lambda: api.init_cache(cfg, 2, MAX_LEN + PS))
    ba = slots_mod.batch_axes(b1, cache)
    sa = pages_mod.seq_axes(cache, grown, PS)
    pshape = pages_mod.pool_shape(cache, ba, sa, num_pages=16, page_size=PS)
    for tp, want in ((1, 1), (2, 2), (4, 1)):   # Hkv=2
        specs = shd.pool_pspecs(pshape, cfg, tp_mesh(tp), sa)
        assert shd.pool_kv_cut(specs, sa, tp, "model") == want, tp
    # an Hkv=2 cut at tp=2 halves the per-shard token bytes exactly
    full = pages_mod.kv_token_bytes(cache, ba, sa)
    assert pages_mod.kv_token_bytes(cache, ba, sa, kv_shards=2) == full // 2
    with pytest.raises(ValueError):
        pages_mod.kv_token_bytes(cache, ba, sa, kv_shards=3)


def test_one_device_mesh_placements_work(family):
    """The 1-device test mesh must accept every serve placement (specs may
    name size-1 axes; that is still a valid, trivially-replicated layout)."""
    cfg, cache, ba, sa = family
    mesh = make_test_mesh()
    sh = shd.with_sharding(mesh, shd.serve_cache_pspecs(cache, cfg, mesh))
    zeros = jax.tree.map(lambda a, s: jax.device_put(
        np.zeros(a.shape, a.dtype), s), cache, sh)
    for leaf in jax.tree.leaves(zeros):
        assert leaf.sharding.mesh == mesh


def test_mesh_shape_validation():
    """launch.mesh refuses shapes that do not factor the device count and
    says how to fix it (satellite: explicit (dp, tp) validation)."""
    from repro.launch import mesh as mesh_mod
    with pytest.raises(ValueError, match=r"dp\*tp|devices"):
        mesh_mod.make_test_mesh(shape=(3, 5))
    with pytest.raises(ValueError):
        mesh_mod.make_test_mesh(shape=(0, 1))
    m = mesh_mod.make_test_mesh(shape=(1, 1))
    assert m.axis_names == ("data", "model")
