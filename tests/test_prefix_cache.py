"""Shared-prefix KV reuse (serve/pages.py, DESIGN.md §7): the ref-counted
copy-on-write page pool with the radix block-hash prefix index.

Pool-level: match/publish chains, refcount lifecycle, CoW rules (copy when
shared, unpublish-in-place when sole owner), eviction of refcount-0 index
pages under pressure.  Scheduler-level: prefix cache on vs off must be
token-identical for lm (real reuse), gemma2 (mixed ring/paged — the no-op
index fallback) and split-brain (real reuse incl. the whole-prompt CoW
case), with cached tokens reported per request, boundary traffic exact
under the cached-token accounting, and zero steady-state recompiles."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve import pages, slots
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler, Request
from repro.serve.splitbrain_engine import SplitBrainEngine, traffic_model_for


# --------------------------------------------------------- pool-level radix
def test_pool_match_publish_and_refcount_lifecycle():
    pool = pages.PagePool(num_pages=9, page_size=4, n_slots=3, slot_pages=4)
    prompt = np.arange(1, 11, dtype=np.int32)          # T0=10, body=9
    assert pool.match_prefix(prompt) == []             # empty index
    # slot 0 prefills the body privately, then publishes its full pages
    assert pool.try_admit(0, 9 + 4)                    # body + max_new
    pool.ensure(0, 9)
    assert pool.publish(0, prompt, n_tokens=9) == 2    # 2 full pages of 4
    assert pool.index_pages == 2 and pool.cached_pages == 0
    owned = [int(pool.table[0, i]) for i in range(2)]
    # a second identical prompt matches the whole chain; a diverging one
    # stops at the first miss (radix walk)
    assert pool.match_prefix(prompt) == owned
    other = prompt.copy()
    other[5] += 1                                      # diverge in page 1
    assert pool.match_prefix(other) == owned[:1]
    # slot 1 admits with the match: refcount++ but no new storage for them
    assert pool.try_admit(1, 9 + 4, matched=owned)
    assert all(pool.refcount[p] == 2 for p in owned)
    assert pool.pages_in_use == int(pool._n_alloc[0])  # shared, counted once
    # frees: refcount drops; published pages become evictable, private
    # pages return to the free list
    pool.free_slot(1)
    assert all(pool.refcount[p] == 1 for p in owned)
    pool.free_slot(0)
    assert all(pool.refcount[p] == 0 for p in owned)
    assert pool.cached_pages == 2                      # resident, matchable
    assert pool.match_prefix(prompt) == owned          # still hits
    # re-admitting pins them again (0 -> 1 refcount, leaves the LRU)
    assert pool.try_admit(2, 9 + 4, matched=pool.match_prefix(prompt))
    assert pool.cached_pages == 0 and pool.pages_in_use >= 2


def test_pool_eviction_under_pressure_and_invariant():
    """With the free list exhausted, draws evict the oldest-released
    refcount-0 index page instead of failing; admission never overcommits
    (pinned + outstanding reservations - drawn <= capacity)."""
    pool = pages.PagePool(num_pages=5, page_size=4, n_slots=2, slot_pages=4)
    prompt = np.arange(1, 14, dtype=np.int32)          # 3 full pages
    assert pool.try_admit(0, 13)
    pool.ensure(0, 13)
    pool.publish(0, prompt, n_tokens=12)
    pool.free_slot(0)
    assert pool.cached_pages == 3 and len(pool._free) == 1
    # capacity 4, 3 cached + 1 free: a 4-page private request must evict
    assert pool.try_admit(1, 16)                       # 4 pages, no match
    pool.ensure(1, 16)
    assert pool.evictions >= 2                         # pressure hit the LRU
    assert pool.pages_in_use == 4
    assert pool.index_pages + pool.cached_pages < 3    # entries retired
    pool.free_slot(1)
    # evicted entries no longer match (chain broken at the evicted page)
    assert len(pool.match_prefix(prompt)) < 3


def test_pool_cow_copy_when_shared_unpublish_when_sole():
    pool = pages.PagePool(num_pages=9, page_size=4, n_slots=3, slot_pages=4)
    prompt = np.arange(1, 9, dtype=np.int32)           # exactly 2 pages
    assert pool.try_admit(0, 8 + 2)
    pool.ensure(0, 8)
    pool.publish(0, prompt, n_tokens=8)
    owned = [int(pool.table[0, i]) for i in range(2)]
    # slot 1 maps the whole prompt (overshoot case: +1 CoW reservation)
    assert pool.try_admit(1, 7 + 2, matched=owned, extra_new=1)
    # shared page -> copy: new private dst, src refcount drops, table remaps
    op = pool.cow_page(1, 1)
    assert op is not None
    src, dst = op
    assert src == owned[1] and dst != src
    assert pool.refcount[src] == 1 and pool.refcount[dst] == 1
    assert int(pool.table[1, 1]) == dst
    assert int(pool.table[0, 1]) == src                # owner untouched
    assert pool.cow_copies == 1
    # sole owner but published -> unpublish in place, NO copy
    pool.free_slot(1)
    before = pool.index_pages
    assert pool.cow_page(0, 1) is None
    assert pool.index_pages == before - 1              # entry retired
    # private and unpublished -> nothing at all
    assert pool.cow_page(0, 1) is None
    assert pool.cow_copies == 1


# ------------------------------------------------------- scheduler parity
def _lm_engine(prefix, **kw):
    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, ServeEngine(cfg, params, max_len=64, page_size=8,
                            num_pages=33, prefix_cache=prefix, **kw)


def _shared_prefix_prompts(cfg, prefix_len=16, tails=(3, 5, 1, 7), seed=0):
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, cfg.vocab_size, (prefix_len,)).astype(np.int32)
    ps = [np.concatenate([shared,
                          rng.integers(1, cfg.vocab_size, (t,)
                                       ).astype(np.int32)]) for t in tails]
    ps.append(shared.copy())       # whole-prefix repeat: the CoW case
    return ps


def test_prefix_cache_on_off_token_identity_lm():
    """lm (every K/V leaf pages): prefix cache ON must be token-identical
    to OFF through the scheduler, report cached tokens per request, do
    strictly less prefill work, and keep eq. 7-10 boundary bytes exact
    under the cached-token accounting."""
    cfg, eng_off = _lm_engine("off")
    _, eng_on = _lm_engine("on")
    prompts = _shared_prefix_prompts(cfg)
    reqs = [Request(uid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]

    def run(eng):
        eng.meter.reset()
        sched = ContinuousBatchingScheduler(eng, max_slots=3,
                                            prefill_chunk=8)
        return sched.run([dataclasses.replace(r) for r in reqs]), sched

    off, _ = run(eng_off)
    on, sched_on = run(eng_on)
    for a, b in zip(off["results"], on["results"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert a.cached_tokens == 0
    assert on["cached_prompt_tokens"] > 0
    assert on["prefill_tokens"] < off["prefill_tokens"]
    # the whole-prefix repeat (last uid) hits with its full body cached
    assert on["results"][-1].cached_tokens == len(prompts[-1]) - 1
    # eq. 7-10 exactness with the cache on: cached tokens never cross
    n_tok = sum(len(p) - 1 + 6 for p in prompts)
    bpt = traffic_model_for(cfg).bytes_per_token()
    assert eng_off.measured_bytes()["total"] == n_tok * bpt
    assert eng_on.measured_bytes()["total"] == \
        (n_tok - on["cached_prompt_tokens"]) * bpt
    stats = eng_on.cache_stats(sched_on.cache)
    assert stats["prefix_hits"] > 0
    assert stats["pages_allocated"] < \
        eng_off.cache_stats(sched_on.cache)["pages_allocated"]
    assert stats["cow_copies"] >= 1          # the whole-prefix repeat


def test_prefix_cache_gemma2_mixed_ring_is_noop_but_identical():
    """gemma2 alternates ring (window) and paged (global) layers: the ring
    leaves are slot-private dense state a shared page cannot restore, so
    the prefix index must NO-OP (zero cached tokens) while staying
    token-identical with the knob on."""
    cfg = get_config("gemma2-27b").reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _shared_prefix_prompts(cfg, prefix_len=16, tails=(3, 6))

    def run(prefix):
        eng = ServeEngine(cfg, params, max_len=32, page_size=8,
                          num_pages=17, prefix_cache=prefix)
        sched = ContinuousBatchingScheduler(eng, max_slots=2,
                                            prefill_chunk=8)
        return sched.run([Request(uid=i, prompt=p, max_new=4)
                          for i, p in enumerate(prompts)]), eng

    off, _ = run("off")
    on, eng_on = run("on")
    assert not eng_on.prefix_sharing_active()    # ring leaves demote it
    assert on["cached_prompt_tokens"] == 0
    for a, b in zip(off["results"], on["results"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert b.cached_tokens == 0


def test_splitbrain_prefix_identity_with_cow():
    """Split-brain engine (k/v always page): prefix cache vs the fused
    one-request generate, including the whole-prompt CoW hit, and pages
    drain back after the run (shared pages become cached, not leaked)."""
    cfg = get_config("tinyllama-1.1b").reduced(vocab_size=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    ref = SplitBrainEngine(cfg, params, max_len=64, quantize=False)
    rng = np.random.default_rng(0)
    shared = rng.integers(1, 120, (16,)).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, 120, (t,)).astype(np.int32)])
               for t in (2, 5, 3)]
    prompts.append(shared.copy())            # whole-prompt hit -> CoW
    base = [ref.generate(p[None, :], max_new=5)["tokens"][0]
            for p in prompts]

    eng = SplitBrainEngine(cfg, params, max_len=64, quantize=False,
                           page_size=8, num_pages=25, prefix_cache="on")
    sched = ContinuousBatchingScheduler(eng, max_slots=2, prefill_chunk=8)
    res = sched.run([Request(uid=i, prompt=p, max_new=5)
                     for i, p in enumerate(prompts)])
    for i, r in enumerate(res["results"]):
        np.testing.assert_array_equal(r.tokens, base[i])
    assert res["cached_prompt_tokens"] > 0
    assert res["results"][-1].cached_tokens == len(shared) - 1
    stats = eng.cache_stats(sched.cache)
    assert stats["pages_in_use"] == 0        # all slots freed
    assert stats["cow_copies"] >= 1
    assert stats["cached_index_pages"] > 0   # prefix stays matchable


def test_prefix_cache_zero_steady_state_recompiles():
    """After warmup (which exercises the seed gather AND the CoW copy), a
    fresh shared-prefix workload compiles NOTHING — match lengths, page
    assignments and copies are traced operands, not compile keys."""
    cfg, eng = _lm_engine("on")
    prompts = _shared_prefix_prompts(cfg)
    sched = ContinuousBatchingScheduler(eng, max_slots=3, prefill_chunk=8)
    sched.warmup()
    reqs = [Request(uid=i, prompt=p, max_new=6)
            for i, p in enumerate(prompts)]
    sched.run([dataclasses.replace(r) for r in reqs])
    counter = slots.CompileCounter.instance()
    c0 = counter.count
    out = sched.run([dataclasses.replace(r) for r in reqs])
    assert out["cached_prompt_tokens"] > 0
    if counter.available:
        assert counter.count == c0, "prefix-cache steady state recompiled"


def test_request_latency_metrics():
    """queue_wait_s and ttft_s ship on every RequestResult and are
    consistent: admission comes at/after arrival, the first token at/after
    admission, finish at/after the first token."""
    cfg, eng = _lm_engine("on")
    prompts = _shared_prefix_prompts(cfg, tails=(3, 5))
    sched = ContinuousBatchingScheduler(eng, max_slots=2, prefill_chunk=8)
    res = sched.run([Request(uid=i, prompt=p, max_new=4,
                             arrival_s=0.01 * i)
                     for i, p in enumerate(prompts)], realtime=True)
    for i, r in enumerate(res["results"]):
        assert r.queue_wait_s >= 0.0
        assert r.ttft_s >= r.queue_wait_s
        # finished_s is loop-relative; ttft_s is arrival-relative
        assert r.finished_s >= r.ttft_s + 0.01 * i - 1e-9
        assert r.gen_len == 4


def test_prefix_cache_knob_validation():
    with pytest.raises(ValueError):
        _lm_engine("sometimes")


# ------------------------------------------- quantized pools (DESIGN.md §13)
def test_prefix_identity_and_kv_read_shrink_under_int8():
    """kv_dtype='int8': prefix cache ON stays token-identical to OFF (the
    shared pages quantize once at publish; later consumers dequantize the
    same codes — fake-quant during prefill makes both paths attend to the
    stored values), eq. 7-10 boundary bytes stay byte-identical to the
    bf16 pool, and the host_read KV channel shrinks ~2x (1-byte codes plus
    page-amortized scales vs 2-byte bf16)."""
    prompts = None

    def run(kv_dtype, prefix):
        nonlocal prompts
        cfg, eng = _lm_engine(prefix, kv_dtype=kv_dtype)
        if prompts is None:
            prompts = _shared_prefix_prompts(cfg)
        sched = ContinuousBatchingScheduler(eng, max_slots=3,
                                            prefill_chunk=8)
        out = sched.run([Request(uid=i, prompt=p, max_new=6)
                         for i, p in enumerate(prompts)])
        return out, eng

    base, eng_bf = run("bf16", "off")
    off, eng_off = run("int8", "off")
    on, eng_on = run("int8", "on")
    # the identity gate: quantized ON == quantized OFF, token for token
    for a, b in zip(off["results"], on["results"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert on["cached_prompt_tokens"] > 0
    assert on["results"][-1].cached_tokens == len(prompts[-1]) - 1
    # eq. 7-10 channels are byte-exact vs the bf16 pool (quantization only
    # changes host-local storage, never the boundary accounting)
    assert eng_off.measured_bytes() == eng_bf.measured_bytes()
    # host_read KV bytes/token shrink ~2x: (hd + 4/ps) vs 2*hd per head
    rb = eng_bf.meter.host_channel_bytes("kv_cache_read")
    ri = eng_off.meter.host_channel_bytes("kv_cache_read")
    assert rb > 0 and 1.8 <= rb / ri <= 2.0


def test_splitbrain_prefix_identity_under_int8():
    """Split-brain engine: same ON == OFF identity gate on its stacked
    (L, ...) quantized pools, CoW included."""
    cfg = get_config("tinyllama-1.1b").reduced(vocab_size=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    shared = rng.integers(1, 120, (16,)).astype(np.int32)
    prompts = [np.concatenate([shared,
                               rng.integers(1, 120, (t,)).astype(np.int32)])
               for t in (2, 5, 3)]
    prompts.append(shared.copy())

    def run(prefix):
        eng = SplitBrainEngine(cfg, params, max_len=64, quantize=False,
                               page_size=8, num_pages=25,
                               prefix_cache=prefix, kv_dtype="int8")
        sched = ContinuousBatchingScheduler(eng, max_slots=2,
                                            prefill_chunk=8)
        return sched.run([Request(uid=i, prompt=p, max_new=5)
                          for i, p in enumerate(prompts)]), eng, sched

    off, _, _ = run("off")
    on, eng, sched = run("on")
    for a, b in zip(off["results"], on["results"]):
        np.testing.assert_array_equal(a.tokens, b.tokens)
    assert on["cached_prompt_tokens"] > 0
    stats = eng.cache_stats(sched.cache)
    assert stats["kv_dtype"] == "int8"
    assert stats["cow_copies"] >= 1              # whole-prompt repeat
    assert stats["kv_token_bytes_stored"] < eng._kv_tok_bytes
