"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv_scan import rwkv6_scan
from repro.kernels.w4a8_matmul import w4a8_matmul


# ----------------------------------------------------------------- w4a8 matmul
@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (128, 128, 128, 128, 128, 128),
    (256, 512, 384, 128, 128, 128),
    (64, 256, 128, 64, 64, 256),     # single K step vs multi
    (512, 256, 256, 256, 128, 64),
])
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_w4a8_matches_oracle(M, K, N, bm, bn, bk, out_dtype):
    rng = np.random.default_rng(M + K + N)
    qx = jnp.asarray(rng.integers(-127, 128, (M, K)).astype(np.int8))
    xs = jnp.asarray(rng.uniform(0.01, 0.1, (M, 1)).astype(np.float32))
    codes = jnp.asarray(rng.integers(-7, 8, (K, N)).astype(np.int8))
    ws = jnp.asarray(rng.uniform(0.01, 0.1, (N,)).astype(np.float32))
    got = w4a8_matmul(qx, xs, codes, ws, bm=bm, bn=bn, bk=bk,
                      out_dtype=out_dtype)
    want = ref.w4a8_matmul(qx, xs, codes, ws, out_dtype)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-2 if out_dtype == jnp.bfloat16 else 1e-6)


def test_w4a8_integer_path_bit_exact():
    """int32 accumulation must be exact — the hardware-equivalence claim."""
    rng = np.random.default_rng(7)
    qx = jnp.asarray(rng.integers(-127, 128, (128, 256)).astype(np.int8))
    codes = jnp.asarray(rng.integers(-7, 8, (256, 128)).astype(np.int8))
    ones_m = jnp.ones((128, 1), jnp.float32)
    ones_n = jnp.ones((128,), jnp.float32)
    got = w4a8_matmul(qx, ones_m, codes, ones_n, bm=64, bn=64, bk=64,
                      out_dtype=jnp.float32)
    want = np.asarray(qx, np.int64) @ np.asarray(codes, np.int64)
    np.testing.assert_array_equal(np.asarray(got).astype(np.int64), want)


# ------------------------------------------------------------- flash attention
ATTN_CASES = [
    dict(B=2, Hq=4, Hkv=2, Tq=128, Tk=128, D=64, causal=True),
    dict(B=1, Hq=8, Hkv=2, Tq=96, Tk=96, D=32, causal=True, window=48),
    dict(B=2, Hq=4, Hkv=4, Tq=64, Tk=64, D=64, causal=True, softcap=30.0),
    dict(B=1, Hq=4, Hkv=1, Tq=64, Tk=128, D=64, causal=False),
    dict(B=1, Hq=2, Hkv=2, Tq=80, Tk=80, D=16, causal=True),  # ragged blocks
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_naive(case, dtype):
    c = dict(case)
    B, Hq, Hkv, Tq, Tk, D = (c.pop(k) for k in ("B", "Hq", "Hkv", "Tq", "Tk", "D"))
    rng = np.random.default_rng(Tq + Tk)
    q = jnp.asarray(rng.normal(size=(B, Hq, Tq, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, Tk, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, Tk, D)), dtype)
    kvo = Tk - Tq if c.get("causal") else 0
    want = ref.mha(q, k, v, kv_offset=kvo, **c)
    got = flash_attention(q, k, v, kv_offset=kvo, bq=32, bk=32, **c)
    atol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=atol)


@pytest.mark.parametrize("case", ATTN_CASES)
def test_chunked_ref_matches_naive(case):
    c = dict(case)
    B, Hq, Hkv, Tq, Tk, D = (c.pop(k) for k in ("B", "Hq", "Hkv", "Tq", "Tk", "D"))
    rng = np.random.default_rng(Tq)
    q = jnp.asarray(rng.normal(size=(B, Hq, Tq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, Tk, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, Tk, D)).astype(np.float32))
    kvo = Tk - Tq if c.get("causal") else 0
    want = ref.mha(q, k, v, kv_offset=kvo, **c)
    got = ref.mha_chunked(q, k, v, kv_offset=kvo, q_chunk=32, kv_chunk=32, **c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_decode_attention_matches_full():
    rng = np.random.default_rng(0)
    B, Hq, Hkv, S, D = 3, 8, 2, 64, 32
    q = jnp.asarray(rng.normal(size=(B, Hq, 1, D)).astype(np.float32))
    kc = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    vc = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    lens = jnp.asarray([10, 64, 33], jnp.int32)
    got = ref.decode_attention(q, kc, vc, lens)
    for b in range(B):
        L = int(lens[b])
        want = ref.mha(q[b:b + 1], kc[b:b + 1, :, :L], vc[b:b + 1, :, :L],
                       causal=True, kv_offset=L - 1)
        np.testing.assert_allclose(np.asarray(got[b]), np.asarray(want[0]),
                                   atol=2e-5)


# ----------------------------------------------------------------- rwkv kernel
@pytest.mark.parametrize("B,H,T,D,bt", [
    (2, 3, 64, 16, 16),
    (1, 2, 128, 32, 64),
    (1, 1, 32, 64, 32),
])
def test_rwkv_kernel_matches_ref(B, H, T, D, bt):
    rng = np.random.default_rng(B * T)
    r, k, v = (jnp.asarray(rng.normal(size=(B, H, T, D)).astype(np.float32))
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.8, 0.999, (B, H, T, D)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, D)).astype(np.float32))
    want_o, want_s = ref.rwkv6_scan(r, k, v, w, u)
    got_o, got_s = rwkv6_scan(r, k, v, w, u, bt=bt)
    np.testing.assert_allclose(np.asarray(got_o), np.asarray(want_o), atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), atol=1e-4)


def test_rwkv_ref_state_continuation():
    """Processing [t0:t1] then [t1:t2] with carried state == full scan."""
    rng = np.random.default_rng(5)
    B, H, T, D = 1, 2, 32, 8
    r, k, v = (jnp.asarray(rng.normal(size=(B, H, T, D)).astype(np.float32))
               for _ in range(3))
    w = jnp.asarray(rng.uniform(0.9, 0.999, (B, H, T, D)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(H, D)).astype(np.float32))
    full, _ = ref.rwkv6_scan(r, k, v, w, u)
    h = T // 2
    o1, s1 = ref.rwkv6_scan(r[:, :, :h], k[:, :, :h], v[:, :, :h], w[:, :, :h], u)
    o2, _ = ref.rwkv6_scan(r[:, :, h:], k[:, :, h:], v[:, :, h:], w[:, :, h:], u, state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 2)),
                               np.asarray(full), atol=1e-4)


def test_selective_scan_state_continuation():
    rng = np.random.default_rng(6)
    B, T, D, N = 2, 24, 8, 4
    x = jnp.asarray(rng.normal(size=(B, T, D)).astype(np.float32))
    delta = jnp.asarray(rng.uniform(0.01, 0.5, (B, T, D)).astype(np.float32))
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (D, N)).astype(np.float32))
    Bm = jnp.asarray(rng.normal(size=(B, T, N)).astype(np.float32))
    Cm = jnp.asarray(rng.normal(size=(B, T, N)).astype(np.float32))
    full, _ = ref.selective_scan(x, delta, A, Bm, Cm)
    h = T // 2
    y1, s1 = ref.selective_scan(x[:, :h], delta[:, :h], A, Bm[:, :h], Cm[:, :h])
    y2, _ = ref.selective_scan(x[:, h:], delta[:, h:], A, Bm[:, h:], Cm[:, h:], state=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(full), atol=1e-5)
