"""OnlineServer: the thread-queue front end over the open-loop scheduler.

One background loop thread owns the scheduler and all JAX state; callers
only enqueue ops and read futures/queues.  Contracts: streamed tokens ==
the terminal result's tokens == the fused baseline; every submitted
request terminates exactly once (DONE, CANCELLED, TIMEOUT or REJECTED —
nothing hangs); cancellation and deadlines work mid-flight; concurrent
submitters from many threads are all served correctly."""
import dataclasses
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler
from repro.serve.server import OnlineServer, ServerClosed

MAX_NEW = 6


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=64)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
               for t in (5, 8, 4, 6)]
    base = [np.asarray(eng.generate(p[None, :], max_new=MAX_NEW)
                       ["tokens"][0]) for p in prompts]
    return cfg, eng, prompts, base


def _server(eng, **kw):
    return OnlineServer(ContinuousBatchingScheduler(eng, max_slots=2, **kw))


def test_stream_result_and_baseline_agree(setup):
    cfg, eng, prompts, base = setup
    with _server(eng) as srv:
        handles = [srv.submit(p, max_new=MAX_NEW) for p in prompts]
        streamed = [list(h.stream()) for h in handles]
        results = [h.result(timeout=60) for h in handles]
    for got, res, b in zip(streamed, results, base):
        assert res.state == "DONE"
        np.testing.assert_array_equal(got, b)
        np.testing.assert_array_equal(res.tokens, b)
        assert res.admitted_s >= 0.0 and res.ttft_s >= 0.0


def test_concurrent_submitters(setup):
    """Many caller threads, one loop: every request is served and
    token-identical to its fused baseline."""
    cfg, eng, prompts, base = setup
    results = {}
    lock = threading.Lock()

    def client(i):
        h = srv.submit(prompts[i % len(prompts)], max_new=MAX_NEW)
        r = h.result(timeout=60)
        with lock:
            results[h.uid] = (i % len(prompts), r)

    with _server(eng) as srv:
        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert len(results) == 8
    for _, (pi, r) in results.items():
        assert r.state == "DONE"
        np.testing.assert_array_equal(r.tokens, base[pi])


def test_cancel_mid_flight(setup):
    cfg, eng, prompts, base = setup
    with _server(eng) as srv:
        h = srv.submit(prompts[0], max_new=40)
        for i, _tok in enumerate(h.stream()):
            if i == 2:
                h.cancel()
        r = h.result(timeout=60)
    assert r.state == "CANCELLED"
    assert 1 <= r.gen_len < 40
    np.testing.assert_array_equal(r.tokens, base[0][:min(r.gen_len, MAX_NEW)])


def test_deadline_times_out(setup):
    cfg, eng, prompts, base = setup
    with _server(eng) as srv:
        h = srv.submit(prompts[1], max_new=MAX_NEW, deadline_s=0.0)
        r = h.result(timeout=60)
    assert r.state == "TIMEOUT"
    assert r.gen_len == 0


def test_rejection_resolves_with_reason(setup):
    cfg, eng, prompts, base = setup
    with _server(eng) as srv:
        h = srv.submit(prompts[0], max_new=10 ** 6)   # cannot fit max_len
        r = h.result(timeout=60)
        ok = srv.submit(prompts[2], max_new=MAX_NEW).result(timeout=60)
    assert r.state == "REJECTED" and r.gen_len == 0
    assert "does not fit" in h.reject_reason
    assert ok.state == "DONE"       # the bad request didn't kill the loop
    np.testing.assert_array_equal(ok.tokens, base[2])


def test_priority_orders_admission(setup):
    """With one slot and a backlog, the high-priority request admitted
    after a queue of low-priority ones must finish before them."""
    cfg, eng, prompts, base = setup
    srv = OnlineServer(ContinuousBatchingScheduler(eng, max_slots=1))
    with srv:
        low = [srv.submit(prompts[i % len(prompts)], max_new=MAX_NEW,
                          priority=0) for i in range(4)]
        high = srv.submit(prompts[1], max_new=MAX_NEW, priority=3)
        rh = high.result(timeout=60)
        rl = [h.result(timeout=60) for h in low]
    assert rh.state == "DONE"
    np.testing.assert_array_equal(rh.tokens, base[1])
    # the high-priority request jumped the part of the queue that had not
    # been admitted yet when it arrived
    later = [r for r in rl if r.admitted_s > rh.admitted_s]
    assert later, "high-priority request did not overtake the backlog"


def test_stop_without_drain_cancels_outstanding(setup):
    cfg, eng, prompts, base = setup
    srv = _server(eng).start()
    handles = [srv.submit(prompts[i % len(prompts)], max_new=40)
               for i in range(6)]
    srv.stop(drain=False)
    states = {h.result(timeout=60).state for h in handles}
    assert states <= {"CANCELLED", "DONE"}
    assert "CANCELLED" in states
    with pytest.raises(ServerClosed):
        srv.submit(prompts[0])
