"""Fault-injection suite: graceful degradation as a tested property.

Every injection point of serve/faults.py is driven against the real
scheduler + paged engine and the loop must absorb it: injected prefill
failures release the slot, reserved pages and radix refcounts (pool
occupancy returns to baseline — the strand-pages regression); injected
admission refusals delay but never wrongly reject; a pool-squeeze window
only queues work; a mid-decode cancellation burst frees pages within one
iteration and leaves the surviving streams token-identical; a stalled
prefill is reaped by its deadline.  The CI chaos-smoke job sweeps this
file over a fixed seed matrix via CHAOS_SEED, so determinism is part of
the contract: same (plan, seed) -> same fault sequence."""
import dataclasses
import os

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import ServeEngine
from repro.serve.errors import InjectedFault, SchedulerError
from repro.serve.faults import FaultInjector, FaultPlan
from repro.serve.scheduler import ContinuousBatchingScheduler, Request

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))
MAX_NEW = 6


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("stablelm-1.6b").reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=32, page_size=4, num_pages=33,
                      prefix_cache="on")
    rng = np.random.default_rng(CHAOS_SEED)
    prompts = [rng.integers(1, cfg.vocab_size, (t,)).astype(np.int32)
               for t in (5, 9, 4, 7)]
    base = [np.asarray(eng.generate(p[None, :], max_new=MAX_NEW)
                       ["tokens"][0]) for p in prompts]
    return cfg, eng, prompts, base


def _pool_baseline(eng):
    pool = eng._pager.pool
    return (pool.pages_in_use, pool.total_reserved, pool.total_drawn)


def _drain(sched, limit=500):
    for _ in range(limit):
        sched.step()
        if not sched.has_work():
            return
    raise AssertionError("scheduler did not drain")


def test_injected_prefill_failure_releases_everything(setup):
    """THE strand-pages regression (satellite): a prefill job that throws
    mid-chunk must release its slot, reserved pages and radix-admission
    refcounts — pool occupancy returns to baseline — while every other
    request is served token-identically."""
    cfg, eng, prompts, base = setup
    inj = FaultInjector(FaultPlan(prefill_error_uids=(1,)), seed=CHAOS_SEED)
    sched = ContinuousBatchingScheduler(eng, max_slots=2, prefill_chunk=4,
                                        faults=inj)
    sched.begin()
    baseline = _pool_baseline(eng)
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
    _drain(sched)
    assert inj.fired("prefill_fault") == 1
    rej = sched.poll_rejected()
    assert [r.uid for r in rej] == [1] and "injected" in rej[0].reason
    res = {r.uid: r for r in sched.poll()}
    for i in (0, 2, 3):
        np.testing.assert_array_equal(res[i].tokens, base[i])
        assert res[i].state == "DONE"
    assert _pool_baseline(eng) == baseline, "stranded pages after fault"


def test_prefill_exception_is_recoverable_not_fatal(setup):
    """The typed-exception satellite end to end: InjectedFault is a
    SchedulerError, the loop survives it, and an UNKNOWN exception type
    still propagates (after cleanup) instead of being swallowed."""
    cfg, eng, prompts, base = setup
    assert issubclass(InjectedFault, SchedulerError)

    class Hostile:
        def __init__(self):
            self.plan = FaultPlan()

        def on_step(self, sched):
            pass

        def admission_fault(self, uid):
            return False

        def prefill_fault(self, uid):
            if uid == 0:
                raise RuntimeError("not a SchedulerError")

        def prefill_stalled(self, uid):
            return False

    sched = ContinuousBatchingScheduler(eng, max_slots=2, prefill_chunk=4,
                                        faults=Hostile())
    sched.begin()
    baseline = _pool_baseline(eng)
    sched.submit(Request(uid=0, prompt=prompts[1], max_new=MAX_NEW))
    with pytest.raises(RuntimeError, match="not a SchedulerError"):
        _drain(sched)
    # the cleanup still ran: nothing stranded even on the fatal path
    assert _pool_baseline(eng) == baseline


def test_admission_faults_delay_but_never_reject(setup):
    """Injected admission refusals look like transient pool pressure: the
    scheduler must keep waiting (never eat the request via the idle-reject
    backstop) and serve everything once the fault budget is spent."""
    cfg, eng, prompts, base = setup
    inj = FaultInjector(FaultPlan(admission_failures=3), seed=CHAOS_SEED)
    sched = ContinuousBatchingScheduler(eng, max_slots=2, faults=inj)
    sched.begin()
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
    _drain(sched)
    assert inj.fired("admission_fault") == 3
    assert not sched.poll_rejected()
    res = {r.uid: r for r in sched.poll()}
    assert len(res) == len(prompts)
    for i, b in enumerate(base):
        np.testing.assert_array_equal(res[i].tokens, b)


def test_pool_squeeze_window_queues_then_recovers(setup):
    """A sustained exhaustion window: every admission fails during the
    squeeze, the queue builds, and service resumes cleanly after."""
    cfg, eng, prompts, base = setup
    inj = FaultInjector(FaultPlan(pool_squeeze_at=1, pool_squeeze_iters=10),
                        seed=CHAOS_SEED)
    sched = ContinuousBatchingScheduler(eng, max_slots=2, faults=inj)
    sched.begin()
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
    _drain(sched)
    assert inj.fired("pool_squeeze") > 0
    assert not sched.poll_rejected()
    res = {r.uid: r for r in sched.poll()}
    for i, b in enumerate(base):
        np.testing.assert_array_equal(res[i].tokens, b)
        assert res[i].state == "DONE"


def test_cancel_burst_frees_pages_within_one_iteration(setup):
    """A seeded mid-decode cancellation burst: the victims terminate
    CANCELLED in the burst iteration itself (pages back in the pool), and
    the surviving streams stay token-identical."""
    cfg, eng, prompts, base = setup
    inj = FaultInjector(FaultPlan(cancel_burst_at=6, cancel_burst_frac=0.5),
                        seed=CHAOS_SEED)
    sched = ContinuousBatchingScheduler(eng, max_slots=4, faults=inj)
    sched.begin()
    for i, p in enumerate(prompts):
        sched.submit(Request(uid=i, prompt=p, max_new=16))
    pool = eng._pager.pool
    cancelled_now = []
    for _ in range(500):
        before = pool.pages_in_use
        fin = sched.step()
        hit = [r for r in fin if r.state == "CANCELLED"]
        if hit:
            cancelled_now = hit
            # the burst fired THIS iteration and the pages came back in it
            assert pool.pages_in_use < before
            break
        if not sched.has_work():
            break
    assert inj.fired("cancel_burst") == len(cancelled_now) > 0
    _drain(sched)
    res = {r.uid: r for r in sched.poll()}
    burst_uids = {r.uid for r in cancelled_now}
    for i, b in enumerate(base):
        if i not in burst_uids:
            np.testing.assert_array_equal(res[i].tokens[:len(b)], b)
    assert _pool_baseline(eng) == (0, 0, 0)


def test_cancel_burst_defers_until_decoding(setup):
    """Regression: ``cancel_burst_at=0`` arms the burst before ANY request
    has reached DECODE.  The old code consumed the one-shot on the empty
    batch and silently injected nothing — a chaos test that injects
    nothing proves nothing.  The burst must defer until decoding uids
    exist and then actually fire."""
    cfg, eng, prompts, base = setup
    inj = FaultInjector(FaultPlan(cancel_burst_at=0, cancel_burst_frac=1.0),
                        seed=CHAOS_SEED)
    sched = ContinuousBatchingScheduler(eng, max_slots=2, faults=inj)
    sched.begin()
    for i, p in enumerate(prompts[:2]):
        sched.submit(Request(uid=i, prompt=p, max_new=MAX_NEW))
    _drain(sched)
    assert inj.fired("cancel_burst") > 0
    res = sched.poll()
    assert any(r.state == "CANCELLED" for r in res)
    assert _pool_baseline(eng) == (0, 0, 0)


def test_stalled_prefill_reaped_by_deadline(setup):
    """A wedged prefill job (chunks withheld indefinitely) cannot hold its
    slot forever: the request's deadline reaps it as TIMEOUT and the pool
    returns to baseline."""
    cfg, eng, prompts, base = setup
    inj = FaultInjector(FaultPlan(stall_uids=(0,), stall_iters=10 ** 9),
                        seed=CHAOS_SEED)
    sched = ContinuousBatchingScheduler(eng, max_slots=2, prefill_chunk=4,
                                        faults=inj)
    sched.begin()
    baseline = _pool_baseline(eng)
    sched.submit(Request(uid=0, prompt=prompts[1], max_new=MAX_NEW,
                         deadline_s=0.25))
    sched.submit(Request(uid=1, prompt=prompts[2], max_new=MAX_NEW))
    _drain(sched, limit=2_000_000)
    assert inj.fired("stall") == 1
    res = {r.uid: r for r in sched.poll()}
    assert res[0].state == "TIMEOUT" and res[0].gen_len == 0
    assert res[1].state == "DONE"
    np.testing.assert_array_equal(res[1].tokens, base[2])
    assert _pool_baseline(eng) == baseline
