"""Substrate tests: optimizer, data determinism/elasticity, checkpoint
atomicity + elastic restore, preemption, HLO analyzer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.manager import CheckpointManager
from repro.data.pipeline import DataConfig, DataLoader, global_batch_at_step
from repro.train import optimizer as opt_mod


# ------------------------------------------------------------------ optimizer
def _quad_params():
    return {"a": jnp.asarray([2.0, -3.0]), "b": {"c": jnp.ones((3, 3)) * 5}}


@pytest.mark.parametrize("quantize", [False, True])
def test_adamw_minimizes_quadratic(quantize):
    cfg = opt_mod.AdamWConfig(lr=0.15, warmup_steps=1, total_steps=200,
                              weight_decay=0.0, quantize_moments=quantize,
                              moment_block=4)
    params = _quad_params()
    state = opt_mod.init_state(params, cfg)
    loss = lambda p: jnp.sum(p["a"] ** 2) + jnp.sum((p["b"]["c"] - 1.0) ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, m = opt_mod.apply_updates(params, g, state, cfg)
    assert float(loss(params)) < 1e-2
    assert int(state["step"]) == 200


def test_adamw_grad_clip_and_schedule():
    cfg = opt_mod.AdamWConfig(lr=1e-2, grad_clip=1.0, warmup_steps=10,
                              total_steps=100)
    assert float(opt_mod.lr_schedule(cfg, jnp.asarray(0))) < 1e-2 * 0.2
    assert float(opt_mod.lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1e-2, rel=0.05)
    assert float(opt_mod.lr_schedule(cfg, jnp.asarray(99))) <= 1e-2 * 0.15
    params = {"a": jnp.zeros((4,))}
    state = opt_mod.init_state(params, cfg)
    huge = {"a": jnp.full((4,), 1e6)}
    _, _, m = opt_mod.apply_updates(params, huge, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(2e6, rel=1e-3)


def test_quantized_moments_match_float_closely():
    cfg_f = opt_mod.AdamWConfig(lr=0.05, warmup_steps=1, total_steps=50,
                                weight_decay=0.0)
    cfg_q = opt_mod.AdamWConfig(lr=0.05, warmup_steps=1, total_steps=50,
                                weight_decay=0.0, quantize_moments=True,
                                moment_block=8)
    pf = _quad_params()
    pq = _quad_params()
    sf = opt_mod.init_state(pf, cfg_f)
    sq = opt_mod.init_state(pq, cfg_q)
    loss = lambda p: jnp.sum(p["a"] ** 2) + jnp.sum((p["b"]["c"] - 1.0) ** 2)
    for _ in range(50):
        pf, sf, _ = opt_mod.apply_updates(pf, jax.grad(loss)(pf), sf, cfg_f)
        pq, sq, _ = opt_mod.apply_updates(pq, jax.grad(loss)(pq), sq, cfg_q)
    np.testing.assert_allclose(np.asarray(pf["a"]), np.asarray(pq["a"]),
                               atol=0.15)


# ----------------------------------------------------------------------- data
def test_data_deterministic_and_restartable():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=8, seed=3)
    a = global_batch_at_step(cfg, 5)
    b = global_batch_at_step(cfg, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    loader = DataLoader(cfg, start_step=5)
    c = next(loader)
    np.testing.assert_array_equal(a["tokens"], c["tokens"])


def test_data_elastic_resharding():
    """Concatenating 4 shards == the 1-shard global batch (elastic DP)."""
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8, seed=1)
    full = global_batch_at_step(cfg, 7, shard=0, num_shards=1)
    parts = [global_batch_at_step(cfg, 7, shard=s, num_shards=4)["tokens"]
             for s in range(4)]
    np.testing.assert_array_equal(full["tokens"], np.concatenate(parts, 0))


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2, seed=0)
    b = global_batch_at_step(cfg, 0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ----------------------------------------------------------------- checkpoint
def _tree(x=1.0):
    return {"w": jnp.full((4, 4), x), "opt": {"m": jnp.full((4, 4), x / 2),
                                              "step": jnp.asarray(3)}}


def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(float(s)), metadata={"step": s})
    assert mgr.all_steps() == [3, 4]  # GC keeps last 2
    restored, meta = mgr.restore(_tree())
    assert meta["step"] == 4
    assert float(restored["w"][0, 0]) == 4.0


def test_checkpoint_atomicity_crash_mid_write(tmp_path):
    """A stale tmp dir (simulated crash) must never shadow a good ckpt."""
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(1.0), metadata={"step": 1})
    # simulate a crashed writer: tmp dir without manifest
    os.makedirs(os.path.join(str(tmp_path), "tmp.2.999"))
    # and a half-written final dir without manifest
    os.makedirs(os.path.join(str(tmp_path), "step_2"))
    assert mgr.latest_step() == 1
    restored, meta = mgr.restore(_tree())
    assert meta["step"] == 1


def test_checkpoint_elastic_restore_resharding(tmp_path):
    """Restore device_puts onto whatever sharding the new mesh uses."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(2.0), metadata={"step": 1})
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), _tree())
    restored, _ = mgr.restore(_tree(), shardings=sh)
    assert restored["w"].sharding == NamedSharding(mesh, P())


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    mgr.save(7, _tree(7.0), metadata={"step": 7})
    mgr.wait()
    assert mgr.latest_step() == 7


# ------------------------------------------------------------- HLO analyzer
def test_hlo_analyzer_counts_scan_bodies():
    """Trip-count weighting: a 6-iteration scan of a matmul must count 6x."""
    import jax
    from repro.launch import hlo_analysis as H

    def f(w, x):
        def body(x, wi):
            return jnp.tanh(x @ wi), ()
        return jax.lax.scan(body, x, w)[0].sum()

    w = jax.ShapeDtypeStruct((6, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    compiled = jax.jit(f).lower(w, x).compile()
    totals = H.analyze(compiled.as_text())
    want = 6 * 2 * 8 * 32 * 32  # 6 iterations x 2mnk
    assert totals.flops_per_chip == pytest.approx(want, rel=0.01)


def test_hlo_analyzer_collective_bytes():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch import hlo_analysis as H
    mesh = jax.make_mesh((1,), ("data",))
    # psum over a single-device axis still emits an all-reduce in HLO only if
    # the partitioner keeps it; accept zero-or-positive but parse cleanly
    f = jax.jit(lambda x: x * 2, in_shardings=NamedSharding(mesh, P()))
    compiled = f.lower(jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    totals = H.analyze(compiled.as_text())
    assert totals.coll_bytes_per_chip >= 0.0
