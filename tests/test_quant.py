"""Logic-aware quantization: error bounds, pruning, LAQ trade-off."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import csd, quant


def _rand_w(seed, shape=(128, 64), scale=0.1):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32) * scale)


def test_roundtrip_error_bounded():
    w = _rand_w(0)
    ql = quant.quantize_weights(w, logic_aware=False, prune_threshold=0.0)
    deq = quant.dequantize(ql, jnp.float32)
    # symmetric int4: error <= scale/2 per channel
    scale = np.asarray(ql.scales)
    err = np.abs(np.asarray(deq) - np.asarray(w))
    assert (err <= scale / 2 + 1e-6).all()


def test_laq_error_bounded_by_slack():
    w = _rand_w(1)
    ql = quant.quantize_weights(w, logic_aware=True, prune_threshold=0.0,
                                laq_slack=0.35)
    deq = quant.dequantize(ql, jnp.float32)
    scale = np.asarray(ql.scales)
    err = np.abs(np.asarray(deq) - np.asarray(w))
    assert (err <= scale * (0.5 + 0.35) + 1e-6).all()


def test_laq_reduces_adders_vs_plain_rounding():
    """The point of LAQ: cheaper CSD codes for ~equal quality (§IV-C)."""
    w = _rand_w(2, shape=(512, 256))
    plain = quant.quantize_weights(w, logic_aware=False)
    laq = quant.quantize_weights(w, logic_aware=True)
    table = csd.csd_cost_table(4)
    cost = lambda q: int(table[np.asarray(q.codes).astype(np.int64) + 8].sum())
    assert cost(laq) < cost(plain)


def test_pruned_fraction_in_paper_range():
    """§IV-C.3: 15-25% of weights prune at the 2^-6 threshold for typical
    (gaussian-ish) weight distributions."""
    w = _rand_w(3, shape=(1024, 512))
    ql = quant.quantize_weights(w)
    frac = float(quant.pruned_fraction(ql))
    assert 0.10 <= frac <= 0.30, frac


def test_w4a8_matmul_matches_dequant_matmul():
    w = _rand_w(4, shape=(96, 80))
    x = _rand_w(5, shape=(7, 96), scale=1.0)
    ql = quant.quantize_weights(w)
    got = np.asarray(quant.w4a8_matmul_ref(x, ql, jnp.float32))
    want = np.asarray(x) @ np.asarray(quant.dequantize(ql, jnp.float32))
    np.testing.assert_allclose(got, want, rtol=0.05, atol=0.02)


@given(st.integers(min_value=0, max_value=10_000))
@settings(max_examples=50, deadline=None)
def test_codes_always_int4_range(seed):
    w = _rand_w(seed, shape=(32, 16), scale=float(1 + seed % 7))
    ql = quant.quantize_weights(w)
    codes = np.asarray(ql.codes)
    assert codes.min() >= -7 and codes.max() <= 7


def test_activation_quant_roundtrip():
    x = _rand_w(6, shape=(4, 256), scale=3.0)
    q, s = quant.quantize_activations_int8(x)
    err = np.abs(np.asarray(q, np.float32) * np.asarray(s) - np.asarray(x))
    assert (err <= np.asarray(s) / 2 + 1e-6).all()


def test_activation_quant_per_tensor_static_range():
    """per_tensor=True models the paper's §V-C static-range device: ONE
    scale for the whole tensor (broadcast row-shaped), still a bounded
    roundtrip; the default stays per-row dynamic."""
    x = _rand_w(7, shape=(4, 256), scale=3.0)
    q, s = quant.quantize_activations_int8(x, per_tensor=True)
    s_np = np.asarray(s)
    assert s_np.shape == (4, 1)                  # broadcasts like per-row
    assert np.unique(s_np).size == 1             # but is a single range
    np.testing.assert_allclose(
        float(s_np[0, 0]), float(np.abs(np.asarray(x)).max()) / 127.0,
        rtol=1e-6)
    err = np.abs(np.asarray(q, np.float32) * s_np - np.asarray(x))
    assert (err <= s_np / 2 + 1e-6).all()
    # per-row default gives row-wise distinct scales on ragged rows
    _, s_row = quant.quantize_activations_int8(x)
    assert np.unique(np.asarray(s_row)).size > 1
