"""Multi-device distribution tests.

These run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count
(the main test process must keep exactly 1 device — the shared runner in
conftest.py owns that boilerplate), exercising:
  * sharding-rules partitioning of a real train step on a 2x4 mesh,
  * int8-compressed gradient all-reduce vs exact psum,
  * distributed flash-decode (seq-sharded KV) vs the single-device oracle,
  * GPipe pipeline vs sequential stage application.
"""
import pytest

from conftest import run_multidev

_SCRIPT = """
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    # ---------------- 1. train step partitions on a 2x4 mesh ----------------
    import dataclasses
    from repro.configs import get_config
    from repro.models import api
    from repro.train import optimizer as opt_mod, step as step_mod

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = get_config("granite-8b").reduced()
    cfg = dataclasses.replace(cfg, parallel=dataclasses.replace(
        cfg.parallel, remat="none", batch_axes=("data",)))
    optcfg = opt_mod.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=5)
    with mesh:
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        opt_state = opt_mod.init_state(params, optcfg)
        step = step_mod.make_train_step(cfg, optcfg, mesh, params, opt_state,
                                        donate=False)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks,
                 "mask": jnp.ones((4, 16), jnp.float32)}
        p2, o2, m = step(params, opt_state, batch)
        assert np.isfinite(float(m["loss"]))
        # verify a TP-ruled weight is actually sharded over "model"
        w = p2["blocks"]["mlp"]["w1"]
        assert "model" in str(w.sharding.spec), w.sharding
    print("TRAIN_STEP_OK")

    # ---------------- 2. compressed psum vs exact ----------------------------
    from repro.distributed.collectives import compressed_psum_mean
    gmesh = jax.make_mesh((8,), ("data",))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(8, 64)).astype(np.float32))

    def red(x):
        return compressed_psum_mean({"g": x}, "data")["g"]

    got = shard_map(red, mesh=gmesh, in_specs=P("data"), out_specs=P("data"),
                    check_rep=False)(x)
    want = jnp.mean(x, axis=0)
    err = float(jnp.max(jnp.abs(got[0] - want)))
    scale_bound = float(jnp.max(jnp.abs(x)) / 127.0) * 2
    assert err <= scale_bound, (err, scale_bound)
    print("COMPRESSED_PSUM_OK", err)

    # ---------------- 3. distributed decode attention ------------------------
    from repro.distributed.collectives import distributed_decode_attention
    from repro.kernels import ref
    dmesh = jax.make_mesh((8,), ("model",))
    rng = np.random.default_rng(1)
    B, Hq, Hkv, S, D = 2, 4, 2, 64, 16
    q = jnp.asarray(rng.normal(size=(B, Hq, 1, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)).astype(np.float32))
    lens = jnp.asarray([40, 64], jnp.int32)
    valid = jnp.arange(S)[None, :] < lens[:, None]
    fn = distributed_decode_attention(dmesh, "model")
    with dmesh:
        got = fn(q, k, v, valid)
    want = ref.decode_attention(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    print("DIST_DECODE_OK")

    # ---------------- 4. pipeline parallel vs sequential ---------------------
    from repro.distributed.pipeline import pipeline_apply
    pmesh = jax.make_mesh((8,), ("pipe",))
    Sstages, M, mb, dim = 8, 16, 4, 32
    Ws = jnp.asarray(rng.normal(size=(Sstages, dim, dim)).astype(np.float32) * 0.3)
    xs = jnp.asarray(rng.normal(size=(M, mb, dim)).astype(np.float32))

    piped = pipeline_apply(pmesh, lambda p, x: jnp.tanh(x @ p),
                           num_microbatches=M, axis_name="pipe")
    with pmesh:
        got = piped(Ws, xs)
    want = xs
    for s in range(Sstages):
        want = jnp.tanh(want @ Ws[s])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_multidevice_distribution():
    run_multidev(_SCRIPT, devices=8,
                 markers=("TRAIN_STEP_OK", "COMPRESSED_PSUM_OK",
                          "DIST_DECODE_OK", "PIPELINE_OK"))
