"""CSD encoding: exactness, non-adjacency, minimality — incl. property tests."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import csd


@given(st.integers(min_value=-(2**20), max_value=2**20))
@settings(max_examples=300, deadline=None)
def test_csd_reconstructs_value(n):
    digits = csd.csd_encode(n)
    assert sum(s * 2**sh for s, sh in digits) == n


@given(st.integers(min_value=-(2**20), max_value=2**20))
@settings(max_examples=300, deadline=None)
def test_csd_non_adjacent_form(n):
    shifts = sorted(sh for _, sh in csd.csd_encode(n))
    assert all(b - a >= 2 for a, b in zip(shifts, shifts[1:]))


@given(st.integers(min_value=-(2**15), max_value=2**15))
@settings(max_examples=200, deadline=None)
def test_csd_no_more_digits_than_binary(n):
    # NAF is minimal-weight: never worse than plain binary popcount
    assert csd.csd_nonzero_digits(n) <= max(1, bin(abs(n)).count("1") + (n < 0))


@given(st.integers(min_value=-7, max_value=7),
       st.lists(st.integers(min_value=-128, max_value=127), min_size=1,
                max_size=32))
@settings(max_examples=200, deadline=None)
def test_shift_add_bit_exact(w, xs):
    """The synthesized shift-add tree equals integer multiplication exactly —
    the core hardware-correctness invariant of the ITA MAC (paper §IV-C.2)."""
    plan = csd.shift_add_plan(w)
    x = jnp.asarray(xs, jnp.int32)
    np.testing.assert_array_equal(np.asarray(csd.shift_add_eval(plan, x)),
                                  w * np.asarray(xs))


def test_paper_example_7():
    # paper: 7 = CSD 100-1 (one subtraction), vs binary 0111 (three adds)
    assert csd.csd_nonzero_digits(7) == 2
    assert csd.binary_nonzero_digits(7) == 3
    digits = dict((sh, s) for s, sh in csd.csd_encode(7))
    assert digits == {3: 1, 0: -1}  # 8 - 1


def test_shift_add_plan_adder_counts():
    assert csd.shift_add_plan(0).num_adders == 0      # pruned
    assert csd.shift_add_plan(4).num_adders == 0      # pure wire (shift)
    assert csd.shift_add_plan(7).num_adders == 1      # 8 - 1
    assert csd.shift_add_plan(5).num_adders == 1      # 4 + 1


def test_adder_reduction_matches_paper_range_int8():
    """Paper §IV-C.1: CSD reduces shift-add adders by 30-40% on average."""
    rng = np.random.default_rng(0)
    vals = rng.integers(-127, 128, 200_000)
    stats = csd.adder_reduction(vals, num_bits=8)
    assert 0.30 <= stats["adder_reduction_frac"] <= 0.45, stats


def test_cost_tables_match_scalar_function():
    table = csd.csd_cost_table(4)
    for i, v in enumerate(range(-8, 8)):
        assert table[i] == csd.csd_nonzero_digits(v)
