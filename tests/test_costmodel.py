"""Analytical cost models vs the paper's published numbers (Tables I-V,
VI/VII, Fig 3, eq. 7-11)."""
import numpy as np
import pytest

from repro.core import costmodel, fpga, splitbrain


def test_table1_gate_counts():
    g = costmodel.gate_reduction()
    assert g["generic_int8_gates"] == 1180
    assert g["ita_gates"] == pytest.approx(243, abs=1)
    assert g["ita_shift_add_tree"] == pytest.approx(156, abs=1)
    assert g["ita_accumulator"] == pytest.approx(68, abs=1)
    assert g["ita_pipeline_register"] == pytest.approx(19, abs=1)
    assert g["reduction_x"] == pytest.approx(4.85, abs=0.05)


def test_table2_energy():
    e = costmodel.energy_comparison()
    assert e["gpu_fp16"]["total_pj"] == pytest.approx(401.1, abs=0.5)
    assert e["gpu_int8"]["total_pj"] == pytest.approx(201.0, abs=0.5)
    assert e["ita"]["total_pj"] == pytest.approx(4.05, abs=0.05)
    assert e["improvement_vs_int8"]["x"] == pytest.approx(49.6, abs=0.5)
    assert e["ita"]["dram_pj"] == 0.0  # no memory hierarchy


def test_system_power_matches_paper():
    p = costmodel.system_power(tokens_per_s=20.0, params=7e9)
    assert 1.0 <= p["device_w"] <= 1.3          # paper: 1.13 W
    assert 6.0 <= p["system_w_lo"] <= 8.0       # paper: ~7 W
    assert 11.0 <= p["system_w_hi"] <= 13.0     # paper: ~12 W


def test_table4_die_areas():
    a11 = costmodel.die_area_mm2(1.1e9)
    assert a11["raw_mm2"] == pytest.approx(528, abs=1)        # §VI-D.1
    assert a11["with_overheads_mm2"] == pytest.approx(850, abs=2)
    assert a11["final_mm2"] == pytest.approx(520, abs=2)
    a7 = costmodel.die_area_mm2(7e9)
    assert a7["raw_mm2"] == pytest.approx(3360, abs=2)
    assert a7["with_overheads_mm2"] == pytest.approx(5410, abs=5)
    # paper "conservative" row: 3x routing, post-optimization -> 7885 mm^2
    cons = costmodel.die_area_mm2(7e9, conservative=True)
    assert cons["final_mm2"] == pytest.approx(7885, rel=0.15)


def test_table4_unit_costs():
    c11 = costmodel.unit_cost(1.1e9)
    assert c11["config"] == "monolithic"
    assert c11["silicon_cost"] == pytest.approx(52, abs=2)    # paper: $52
    assert 60 <= c11["unit_cost"] <= 77                        # paper: $64-77
    c7 = costmodel.unit_cost(7e9)
    assert c7["n_chiplets"] == 8                               # paper: 8-chiplet
    # NOTE: the paper's $14/chiplet ($165 total) is NOT reproducible from its
    # own inputs: a 414 mm^2 28nm chiplet yields ~130 good dies/wafer ->
    # >=$34/chiplet.  Our first-principles cost is ~2x the paper's claim;
    # recorded as a reproduction finding in EXPERIMENTS.md.
    assert 250 <= c7["unit_cost"] <= 420


def test_table5_nre_amortization():
    c = costmodel.unit_cost(1.1e9, volume=10_000)
    assert c["nre_per_unit"] == pytest.approx(250, abs=1)      # paper: $250
    assert c["unit_cost_with_nre"] == pytest.approx(314, abs=10)  # paper: $314
    c1m = costmodel.unit_cost(1.1e9, volume=1_000_000)
    assert c1m["nre_per_unit"] == pytest.approx(2.5, abs=0.1)


def test_fig3_security_barrier():
    b = costmodel.extraction_barrier()
    assert b["software_dump_usd"] <= 2_000
    assert b["ita_physical_re_usd"] >= 50_000
    assert b["barrier_increase_x"] >= 25          # paper: 25x increase


def test_tables67_fpga():
    n = fpga.single_neuron_table()
    assert n["lut_reduction_x"] == pytest.approx(1.81, abs=0.03)   # Table VII
    assert n["hardwired_luts"] == pytest.approx(788, abs=10)
    assert n["reg_reduction_x"] == pytest.approx(20.8, abs=0.2)
    f = fpga.full_network_table()
    assert f["n_macs"] == 16384
    assert f["hardwired_over_capacity_x"] == pytest.approx(3.2, abs=0.1)
    assert f["fits_baseline"] and not f["fits_hardwired"]          # Table VI
    gap = fpga.fpga_vs_asic_gap()
    assert gap["asic_gate_reduction_x"] > gap["fpga_lut_reduction_x"]


def test_eq10_bytes_per_token():
    tm = splitbrain.TrafficModel.llama2_7b()
    assert tm.device_to_host_kv_bytes_per_layer() == 16 * 1024     # eq. 7
    assert tm.host_to_device_attn_bytes_per_layer() == 8 * 1024    # eq. 8
    assert tm.logits_bytes() == 64_000                             # eq. 9
    # eq. 10: 832 KB/token (24 KiB x 32 layers + logits)
    assert tm.bytes_per_token() == pytest.approx(832 * 1024, rel=0.01)
    # eq. 11: ~16.64 MB/s at 20 tok/s
    assert tm.bandwidth_bytes_per_s(20) == pytest.approx(16.64e6, rel=0.05)


def test_table3_interface_latencies():
    tm = splitbrain.TrafficModel.llama2_7b()
    rows = {r["interface"]: r for r in tm.interface_table()}
    assert rows["PCIe 3.0 x4"]["total_ms"] == pytest.approx(5.3, abs=0.1)
    assert rows["PCIe 3.0 x4"]["tokens_per_s"] == pytest.approx(188, abs=3)
    assert rows["Thunderbolt 4"]["total_ms"] == pytest.approx(5.2, abs=0.1)
    assert rows["USB 3.0"]["total_ms"] == pytest.approx(7.9, abs=0.1)
    assert rows["USB 3.0"]["tokens_per_s"] == pytest.approx(126, abs=3)
    assert rows["USB 4.0"]["total_ms"] == pytest.approx(5.5, abs=0.1)


def test_cpu_scenario_throughput():
    """§VI-C.2: realistic CPU attention (50-100ms) -> 10-20 tok/s."""
    tm = splitbrain.TrafficModel.llama2_7b()
    row = tm.interface_latency(splitbrain.INTERFACES["pcie3x4"],
                               host_attention_s=splitbrain.HOST_ATTENTION_CPU_S)
    assert 10 <= row["tokens_per_s"] <= 20


def test_gate_reduction_improves_on_real_distribution():
    """Pruned+LAQ real weights beat the paper's uniform reference point."""
    rng = np.random.default_rng(0)
    from repro.core import quant
    import jax.numpy as jnp
    w = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32) * 0.1)
    ql = quant.quantize_weights(w)
    g = costmodel.gate_reduction(np.asarray(ql.codes))
    assert g["reduction_x"] > 4.85
