"""End-to-end training driver example: train a reduced-config model for a few
hundred steps on the deterministic synthetic stream, with checkpointing and a
kill-resume demonstration (fault tolerance).

Run:  PYTHONPATH=src python examples/train_e2e.py [--arch granite-8b] [--steps 300]

Loss must drop substantially from its initial value (the stream has Zipf +
copy-run structure), proving the whole substrate — data, model, optimizer,
checkpoints — learns end to end.
"""
import argparse
import shutil
import tempfile

from repro.launch import train as train_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=300)
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="ita_e2e_")
    try:
        # phase 1: train halfway, checkpointing
        half = args.steps // 2
        print(f"=== phase 1: steps 0..{half} ===")
        r1 = train_mod.main([
            "--arch", args.arch, "--smoke", "--steps", str(half),
            "--batch", "16", "--seq", "128", "--ckpt-dir", ckpt_dir,
            "--ckpt-every", "20", "--lr", "3e-3",
        ])
        # phase 2: "restart after preemption" — resume from checkpoint
        print(f"=== phase 2: resume -> step {args.steps} ===")
        r2 = train_mod.main([
            "--arch", args.arch, "--smoke", "--steps", str(args.steps),
            "--batch", "16", "--seq", "128", "--ckpt-dir", ckpt_dir,
            "--ckpt-every", "20", "--lr", "3e-3", "--resume",
        ])
        drop = r1["first_loss"] - r2["last_loss"]
        print(f"\nloss {r1['first_loss']:.3f} -> {r2['last_loss']:.3f} "
              f"(drop {drop:.3f}) across a checkpoint/restart boundary")
        assert drop > 0.5, "training did not learn"
        print("OK: end-to-end training + fault-tolerant restart works")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
