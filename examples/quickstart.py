"""Quickstart: the ITA pipeline in 60 lines.

1. take a (small) LM, 2. run LAQ "synthesis" (CSD-aware INT4 + pruning),
3. decode with the Split-Brain engine, 4. print the hardware report the
paper would print for this model: gates/MAC, energy/MAC, die area, cost,
interface traffic.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import costmodel
from repro.models import api
from repro.serve.splitbrain_engine import SplitBrainEngine, traffic_model_for


def main():
    # -- 1. a TinyLlama-family model at CPU-demo scale -----------------------
    cfg = get_config("tinyllama-1.1b").reduced(vocab_size=512)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    print(f"model: {cfg.name} ({cfg.num_layers}L d={cfg.d_model})")

    # -- 2. LAQ synthesis: weights -> immutable INT4 shift-add codes ---------
    qparams = api.quantize_model(params, cfg)
    codes = np.asarray(qparams["blocks"]["attn"]["wq"].codes).ravel()
    pruned = float((codes == 0).mean())
    print(f"LAQ: {pruned:.1%} of wq weights pruned to zero (paper: 15-25%)")

    # -- 3. split-brain decoding ---------------------------------------------
    eng = SplitBrainEngine(cfg, params, max_len=32)
    cache = eng.init_cache(batch=1)
    tok = jnp.asarray([1], jnp.int32)
    generated = []
    for _ in range(8):
        tok, _, cache = eng.decode_token(cache, tok)
        generated.append(int(tok[0]))
    print(f"generated tokens: {generated}")
    meas = eng.measured_bytes_per_token(batch=1)
    tm = traffic_model_for(cfg)
    print(f"interface traffic: measured {meas['total']//8} B/token "
          f"(analytical {tm.bytes_per_token()} B/token)")

    # -- 4. the hardware report for the FULL-SIZE model ----------------------
    full = get_config("tinyllama-1.1b")
    n = full.param_count()
    gates = costmodel.gate_reduction(codes)
    energy = costmodel.energy_comparison(codes)
    area = costmodel.die_area_mm2(n)
    cost = costmodel.unit_cost(n)
    tm_full = traffic_model_for(full)
    print(f"\n=== ITA hardware report: {full.name} ({n/1e9:.2f}B params) ===")
    print(f"gates/MAC:        {gates['ita_gates']:.0f} vs 1180 generic "
          f"({gates['reduction_x']:.2f}x)")
    print(f"energy/MAC:       {energy['ita']['total_pj']:.2f} pJ vs "
          f"{energy['gpu_int8']['total_pj']:.0f} pJ INT8-GPU "
          f"({energy['improvement_vs_int8']['x']:.1f}x)")
    print(f"die area:         {area['final_mm2']:.0f} mm^2 ({cost['config']})")
    print(f"unit cost:        ${cost['unit_cost']:.0f} at 10K volume")
    print(f"interface:        {tm_full.bytes_per_token()/1024:.0f} KiB/token, "
          f"{tm_full.bandwidth_bytes_per_s(20)/1e6:.1f} MB/s @ 20 tok/s")
    for row in tm_full.interface_table():
        print(f"  {row['interface']:15s} {row['total_ms']:.1f} ms/token "
              f"-> {row['tokens_per_s']:.0f} tok/s")


if __name__ == "__main__":
    main()
