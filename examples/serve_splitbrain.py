"""Serve a small model with batched requests through the Split-Brain engine,
comparing float vs LAQ-quantized "device" weights, and print the per-request
interface accounting — the runnable version of the paper's deployment story.

Run:  PYTHONPATH=src python examples/serve_splitbrain.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve.splitbrain_engine import SplitBrainEngine, traffic_model_for


def main():
    cfg = get_config("llama2-7b").reduced(vocab_size=512)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 5)), jnp.int32)

    print("== float device weights (fused one-dispatch generation) ==")
    eng_f = SplitBrainEngine(cfg, params, max_len=64, quantize=False)
    eng_f.generate(prompts, max_new=12)               # compile
    res_f = eng_f.generate(prompts, max_new=12)
    out_f = res_f["tokens"]
    print(f"4 requests x 12 tokens in {res_f['decode_s']:.3f}s "
          f"({res_f['tokens_per_s']:.0f} tok/s)")

    print("== eager per-layer reference loop (the protocol, spelled out) ==")
    eng_e = SplitBrainEngine(cfg, params, max_len=64, quantize=False, jit=False)
    res_e = eng_e.generate(prompts, max_new=12)
    print(f"4 requests x 12 tokens in {res_e['decode_s']:.2f}s "
          f"({res_e['tokens_per_s']:.0f} tok/s) -> fused speedup "
          f"{res_f['tokens_per_s'] / res_e['tokens_per_s']:.0f}x")

    print("== LAQ INT4 'hardwired' device weights ==")
    eng_q = SplitBrainEngine(cfg, params, max_len=64, quantize=True)
    out_q = eng_q.generate(prompts, max_new=12)["tokens"]
    agree = float((out_f == out_q).mean())
    print(f"token agreement float vs W4A8: {agree:.1%}")

    eng_q.meter.reset()
    _, _, _ = eng_q.decode_token(eng_q.init_cache(4), prompts[:, 0])
    meas = eng_q.measured_bytes_per_token(batch=4)
    tm = traffic_model_for(cfg)
    print(f"\nper-token interface bytes (per request): measured "
          f"{meas['total']} vs analytical {tm.bytes_per_token()}")
    full_tm = traffic_model_for(get_config('llama2-7b'))
    print("full-size llama2-7b deployment table (Table III):")
    for row in full_tm.interface_table():
        print(f"  {row['interface']:15s} {row['total_ms']:.1f} ms "
              f"-> {row['tokens_per_s']:.0f} tok/s (+${row['extra_cost_usd']:.0f})")


if __name__ == "__main__":
    main()
