"""Unified decoder-only transformer LM covering the dense, MoE, softcap,
sliding-window, and cross-attention (VLM) members of the assigned pool.

Depth is executed as ``lax.scan`` over layer *groups* so the HLO stays O(1)
in depth (94-layer qwen3 compiles in seconds at 512 devices).  A group is
``group_size`` consecutive layers (+ an optional cross-attention block for
VLM archs); heterogeneity inside a group (gemma2 local/global alternation)
is unrolled statically from ``cfg.layer_pattern``.

Params are plain pytrees; every stacked array has leading dims
(n_groups, group_size, ...), which is also what the sharding-rules engine
keys on.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as _shd
from repro.kernels import ops
from repro.models import layers as L
from repro.models import moe as moe_mod


def _pin(cfg: ModelConfig):
    """Serve-TP exactness hook for down-projection inputs (no-op unless
    cfg.parallel.exact_tp and a mesh is ambient — see shd.pin_tp_exact)."""
    if not cfg.parallel.exact_tp:
        return None
    return lambda a: _shd.pin_tp_exact(a, cfg)


def group_layout(cfg: ModelConfig) -> Tuple[int, int]:
    P = len(cfg.layer_pattern)
    group_size = cfg.cross_attn_every if cfg.cross_attn_every else P
    assert cfg.num_layers % group_size == 0, (cfg.num_layers, group_size)
    assert group_size % P == 0, (group_size, P)
    return cfg.num_layers // group_size, group_size


def _stack(key, n: int, init_fn):
    """Initialize ``n`` independent copies stacked on a new leading axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "ln_attn": jnp.zeros((cfg.d_model,), dtype),
        "ln_mlp": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.attn_init(ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd, dtype),
    }
    if cfg.moe:
        p["moe"] = moe_mod.moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.moe, dtype)
    else:
        p["mlp"] = {
            "w1": L.dense_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
            "w3": L.dense_init(ks[3], cfg.d_model, cfg.d_ff, dtype),
            "w2": L.dense_init(ks[4], cfg.d_ff, cfg.d_model, dtype),
        }
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.float32
    n_groups, group_size = group_layout(cfg)
    k_emb, k_blocks, k_cross, k_head = jax.random.split(key, 4)

    def group_init(k):
        return _stack(k, group_size, lambda kk: block_init(kk, cfg, dtype))

    params: Dict[str, Any] = {
        "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), dtype) * 0.02,
        "blocks": _stack(k_blocks, n_groups, group_init),
        "ln_final": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_size, dtype)
    if cfg.cross_attn_every:
        hd = cfg.resolved_head_dim

        def cross_init(k):
            kk = jax.random.split(k, 2)
            return {
                "ln": jnp.zeros((cfg.d_model,), dtype),
                "attn": L.attn_init(kk[0], cfg.d_model, cfg.num_heads,
                                    cfg.num_kv_heads, hd, dtype),
                "gate": jnp.zeros((), dtype),
            }
        params["cross"] = _stack(k_cross, n_groups, cross_init)
    return params


# ----------------------------------------------------------------------------
# Forward (training / prefill): full sequence
# ----------------------------------------------------------------------------
def _block_apply(p, x, spec, cfg: ModelConfig, positions):
    h = L.attn_apply(
        p["attn"],
        L.rmsnorm(x, p["ln_attn"], cfg.norm_eps),
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim, positions=positions,
        rope_theta=cfg.rope_theta, window=spec.window, softcap=cfg.softcap,
        use_pallas=cfg.use_pallas, pin_fn=_pin(cfg))
    x = x + h
    y = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    if cfg.moe:
        out, aux = moe_mod.moe_apply(p["moe"], y, cfg.moe)
    else:
        out, aux = L.swiglu(y, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"],
                            pin_fn=_pin(cfg)), 0.0
    return x + out, aux


def _cross_apply(p, x, cross_kv, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    h = L.attn_apply(
        p["attn"], L.rmsnorm(x, p["ln"], cfg.norm_eps),
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads, head_dim=hd,
        positions=jnp.zeros((1,), jnp.int32), rope_theta=cfg.rope_theta,
        kv=cross_kv, use_pallas=cfg.use_pallas, pin_fn=_pin(cfg))
    return x + jnp.tanh(p["gate"]).astype(x.dtype) * h


def _cross_kv(p, frontend: jnp.ndarray, cfg: ModelConfig):
    """Project stub modality embeddings to cross K/V (device-phase op)."""
    Bx, Tx, _ = frontend.shape
    hd = cfg.resolved_head_dim
    k = L.linear(frontend, p["attn"]["wk"]).reshape(Bx, Tx, cfg.num_kv_heads, hd)
    v = L.linear(frontend, p["attn"]["wv"]).reshape(Bx, Tx, cfg.num_kv_heads, hd)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.parallel.remat == "none":
        return fn
    if cfg.parallel.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def forward(params, tokens: jnp.ndarray, cfg: ModelConfig,
            frontend: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None):
    """tokens (B, T) -> (logits (B, T, V), aux_loss)."""
    n_groups, group_size = group_layout(cfg)
    P = len(cfg.layer_pattern)
    B, T = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    if cfg.tie_embeddings:
        x = x * math.sqrt(cfg.d_model)  # gemma-style scaling with tied embed
    if positions is None:
        positions = jnp.arange(T)

    def group_fn(x, group_in):
        gp = group_in["blocks"]
        if cfg.parallel.gather_fsdp_weights:
            from repro.distributed import sharding as _shd
            gp = _shd.gather_fsdp(gp, cfg)
            x = _shd.pin_batch(x, cfg)
        aux_total = 0.0
        for j in range(group_size):
            pj = jax.tree.map(lambda a: a[j], gp)
            x, aux = _block_apply(pj, x, cfg.layer_pattern[j % P], cfg, positions)
            aux_total = aux_total + aux
        if cfg.cross_attn_every:
            kv = _cross_kv(group_in["cross"], frontend.astype(dtype), cfg)
            x = _cross_apply(group_in["cross"], x, kv, cfg)
        return x, jnp.asarray(aux_total, jnp.float32)

    group_fn = _maybe_remat(group_fn, cfg)
    xs = {"blocks": params["blocks"]}
    if cfg.cross_attn_every:
        xs["cross"] = params["cross"]
    if cfg.parallel.scan_layers:
        x, auxs = jax.lax.scan(lambda c, g: group_fn(c, g), x, xs)
        aux = jnp.sum(auxs) if cfg.moe else 0.0
    else:
        aux = 0.0
        for g in range(n_groups):
            x, a = group_fn(x, jax.tree.map(lambda t: t[g], xs))
            aux += a

    logits = _logits_head(params, x, cfg)
    return logits, aux


# ----------------------------------------------------------------------------
# KV cache + decode
# ----------------------------------------------------------------------------
def _block_qkv(pj, x, positions, cfg: ModelConfig):
    """Shared block head for prefill/decode: pre-norm, QKV projection, rope."""
    xn = L.rmsnorm(x, pj["ln_attn"], cfg.norm_eps)
    q, k, v = L.qkv_project(pj["attn"], xn, cfg.num_heads, cfg.num_kv_heads,
                            cfg.resolved_head_dim)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    return q, k, v


def _block_tail(pj, x, o, cfg: ModelConfig):
    """Shared block tail for prefill/decode: attention-output projection,
    FFN (dense or MoE), both residual adds.  o: (B, H, T, hd)."""
    B, T = x.shape[:2]
    o = o.transpose(0, 2, 1, 3).reshape(B, T, cfg.num_heads * cfg.resolved_head_dim)
    pin = _pin(cfg)
    if pin is not None:
        o = pin(o)
    x = x + L.linear(o, pj["attn"]["wo"])
    y = L.rmsnorm(x, pj["ln_mlp"], cfg.norm_eps)
    if cfg.moe:
        out, _ = moe_mod.moe_apply(pj["moe"], y, cfg.moe)
    else:
        out = L.swiglu(y, pj["mlp"]["w1"], pj["mlp"]["w3"], pj["mlp"]["w2"],
                       pin_fn=pin)
    return x + out


def _embed_decode(params, tokens: jnp.ndarray, cfg: ModelConfig):
    """Shared decode preamble: embed one token per row -> (B, 1, d)."""
    x = params["embed"][tokens][:, None, :].astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        x = x * math.sqrt(cfg.d_model)
    return x


def _logits_head(params, x: jnp.ndarray, cfg: ModelConfig):
    """Shared logits tail: final norm, (tied) LM head, final softcap.
    ONE copy, so the dense and paged decode paths cannot drift apart on
    the head math their token-identity contract depends on."""
    x = L.rmsnorm(x, params["ln_final"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.linear(x, head).astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits



def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               frontend: Optional[jnp.ndarray] = None, params=None) -> Dict[str, Any]:
    n_groups, group_size = group_layout(cfg)
    hd = cfg.resolved_head_dim
    dtype = jnp.dtype(cfg.dtype)
    # per-pattern-slot window: cache only needs the window size for local slots
    sizes = tuple(min(max_len, s.window) if s.window else max_len
                  for s in cfg.layer_pattern)
    P = len(cfg.layer_pattern)
    cache: Dict[str, Any] = {
        "k": [jnp.zeros((n_groups, group_size // P, batch, cfg.num_kv_heads,
                         sizes[j], hd), dtype) for j in range(P)],
        "v": [jnp.zeros((n_groups, group_size // P, batch, cfg.num_kv_heads,
                         sizes[j], hd), dtype) for j in range(P)],
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if cfg.cross_attn_every and frontend is not None and params is not None:
        kv = jax.vmap(lambda cp: _cross_kv(cp, frontend.astype(dtype), cfg))(
            params["cross"])
        cache["cross_k"], cache["cross_v"] = kv
    return cache


def prefill(params, cache, tokens: jnp.ndarray, cfg: ModelConfig,
            true_len: Optional[jnp.ndarray] = None):
    """Fill a FRESH KV cache with a whole prompt in one forward-style pass.

    tokens (B, T) -> (last-position logits (B, V), cache with len = T).
    One fused program instead of T sequential decode steps: QKV for the full
    prompt, block-write into the cache, causal self-attention over the
    prompt.  Requires every cache slot to hold T tokens (``api.prefill``
    falls back to a scanned decode otherwise) and an empty cache.

    ``true_len`` (traced scalar, serve-path shape bucketing): tokens is
    right-padded to a bucket width and only the first ``true_len`` positions
    are real.  Logits are taken at position ``true_len - 1`` and ``len`` is
    advanced by ``true_len``.  The K/V written past ``true_len`` are garbage
    but unreachable: causal attention masks them during prefill, decode
    attends only to ``len`` positions, and each subsequent decode step
    overwrites slot ``len`` before attending to it (DESIGN.md §4).
    """
    n_groups, group_size = group_layout(cfg)
    P = len(cfg.layer_pattern)
    T = tokens.shape[1]
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    if cfg.tie_embeddings:
        x = x * math.sqrt(cfg.d_model)
    positions = jnp.arange(T)

    def group_fn(x, group_in):
        gp = group_in["blocks"]
        new_k, new_v = [], []
        for j in range(group_size):
            slot = j % P
            spec = cfg.layer_pattern[slot]
            pj = jax.tree.map(lambda a: a[j], gp)
            kc = group_in["k"][slot][j // P]
            vc = group_in["v"][slot][j // P]
            q, k, v = _block_qkv(pj, x, positions, cfg)
            kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, 0, 0))
            o = ops.attention(q, k, v, causal=True, window=spec.window,
                              softcap=cfg.softcap, use_pallas=cfg.use_pallas)
            x = _block_tail(pj, x, o, cfg)
            new_k.append(kc)
            new_v.append(vc)
        if cfg.cross_attn_every:
            kv = (group_in["cross_k"], group_in["cross_v"])
            x = _cross_apply(group_in["cross"], x, kv, cfg)
        upd = {
            "k": [jnp.stack(new_k[s::P]) for s in range(P)],
            "v": [jnp.stack(new_v[s::P]) for s in range(P)],
        }
        return x, upd

    xs = {"blocks": params["blocks"], "k": cache["k"], "v": cache["v"]}
    if cfg.cross_attn_every:
        xs["cross"] = params["cross"]
        xs["cross_k"] = cache["cross_k"]
        xs["cross_v"] = cache["cross_v"]
    x, upd = jax.lax.scan(group_fn, x, xs)

    x = L.rmsnorm(x, params["ln_final"], cfg.norm_eps)
    if true_len is None:
        x_last = x[:, -1]
        advance = T
    else:
        B = tokens.shape[0]
        idx = jnp.broadcast_to(jnp.asarray(true_len, jnp.int32) - 1, (B,))
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
        advance = jnp.asarray(true_len, jnp.int32)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.linear(x_last, head).astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = upd["k"], upd["v"]
    new_cache["len"] = cache["len"] + advance
    return logits, new_cache


def prefill_chunk(params, cache, tokens: jnp.ndarray, true_len, cfg: ModelConfig):
    """Advance a (possibly non-empty) KV cache by one right-padded chunk.

    The chunked-prefill block path: tokens (B, W) are the next ``true_len``
    prompt positions (bucket-padded to W), written at offset ``cache["len"]``
    and attended causally against the whole cache via
    ``ops.chunk_attention`` — positions past each row are masked, so the
    padding rows' K/V are garbage that the next chunk overwrites (or that
    sits beyond ``len``, unreachable by decode).  Requires every cache slot
    to be a LINEAR (non-ring) buffer of the full ``max_len``; windowed ring
    layouts take the masked scan-of-decode fallback in ``api.prefill_chunk``.

    PRECONDITION (enforced by the caller, not checkable on a traced
    ``len``): ``cache["len"]`` must be a multiple of W and W must divide
    the cache size, i.e. chunks are fed full-width back to back with only
    the LAST one padded — the scheduler's feeding order.  A misaligned
    start would make ``dynamic_update_slice`` clamp ``start + W`` back
    into bounds and silently overwrite earlier positions.  The start need
    NOT be zero: the shared-prefix serve path seeds ``len = cached`` from
    the page pool and streams only the prompt tail through here — rope and
    the causal mask are absolute-position, so the math is unchanged; the
    pager rounds the cached length to a multiple of lcm(page, W) exactly
    so this alignment precondition keeps holding (DESIGN.md §7).

    Returns the cache with ``len += true_len`` (no logits: chunked prefill
    feeds the last prompt token to the decode step, which produces them).
    """
    n_groups, group_size = group_layout(cfg)
    P = len(cfg.layer_pattern)
    B, W = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)
    if cfg.tie_embeddings:
        x = x * math.sqrt(cfg.d_model)
    start = cache["len"]                                   # (B,)
    positions = start[:, None] + jnp.arange(W)[None, :]    # (B, W)

    def group_fn(x, group_in):
        gp = group_in["blocks"]
        new_k, new_v = [], []
        for j in range(group_size):
            slot = j % P
            spec = cfg.layer_pattern[slot]
            pj = jax.tree.map(lambda a: a[j], gp)
            kc = group_in["k"][slot][j // P]
            vc = group_in["v"][slot][j // P]
            q, k, v = _block_qkv(pj, x, positions, cfg)
            kc = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice(
                c, kk, (0, i, 0)))(kc, k, start)
            vc = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice(
                c, vv, (0, i, 0)))(vc, v, start)
            o = ops.chunk_attention(q, kc, vc, positions, window=spec.window,
                                    softcap=cfg.softcap,
                                    use_pallas=cfg.use_pallas)
            x = _block_tail(pj, x, o, cfg)
            new_k.append(kc)
            new_v.append(vc)
        upd = {
            "k": [jnp.stack(new_k[s::P]) for s in range(P)],
            "v": [jnp.stack(new_v[s::P]) for s in range(P)],
        }
        return x, upd

    xs = {"blocks": params["blocks"], "k": cache["k"], "v": cache["v"]}
    _, upd = jax.lax.scan(group_fn, x, xs)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = upd["k"], upd["v"]
    new_cache["len"] = cache["len"] + jnp.asarray(true_len, jnp.int32)
    return new_cache


def paged_decode_step(params, cache, table, tokens: jnp.ndarray,
                      cfg: ModelConfig, *, write=None, seq_axes=None):
    """One decode step straight through the page pool — no dense view.

    The gather-free serve path (DESIGN.md §6): full-attention pattern slots
    hold their K/V as kernel-friendly pool leaves
    ``(n_groups, gs//P, num_pages, page_size, Hkv, hd)``; the step appends
    the new token to its page (``layers.paged_cache_write``) and attends via
    ``ops.paged_decode_attention`` with pages as the split-K axis.  Ring
    (window < max_len) slots and ``len`` keep the dense layout and the
    exact ``decode_step`` math.

    cache: paged slot-cache pytree; table: (B, P) physical page ids;
    tokens: (B,); write: (B,) bool — slots with ``write=False`` are frozen
    (their pool append routes to the scratch page, dense leaves and ``len``
    keep their old values; their logits are garbage and must be ignored).
    seq_axes: the per-leaf sequence-axis pytree from
    ``serve/pages.py::seq_axes`` discovery — entries >= 0 mark pool leaves.
    Token-identical to gathering the dense view and running ``decode_step``
    (tests/test_paged_attention.py), with O(live tokens) KV reads.
    """
    n_groups, group_size = group_layout(cfg)
    P = len(cfg.layer_pattern)
    B = tokens.shape[0]
    if write is None:
        write = jnp.ones((B,), bool)
    sa_k = [seq_axes["k"][j] for j in range(P)]
    x = _embed_decode(params, tokens, cfg)
    pos = cache["len"]                        # (B,)
    positions = pos[:, None]                  # (B, 1)
    wmask = write[:, None, None, None]

    def group_fn(x, group_in):
        gp = group_in["blocks"]
        new_k, new_v = [], []
        for j in range(group_size):
            slot = j % P
            spec = cfg.layer_pattern[slot]
            pj = jax.tree.map(lambda a: a[j], gp)
            kc = group_in["k"][slot][j // P]
            vc = group_in["v"][slot][j // P]
            q, k, v = _block_qkv(pj, x, positions, cfg)
            if sa_k[slot] >= 0:
                # pool leaf: in-place page append + gather-free attention.
                # NOTE: no seq-sharded (decode_attn="shard_map") variant —
                # the page pool is not sequence-sharded; configs needing it
                # must serve via the dense or gather disciplines.
                kc = L.paged_cache_write(kc, k, table, pos, write)
                vc = L.paged_cache_write(vc, v, table, pos, write)
                o = ops.paged_decode_attention(
                    q, kc, vc, table, pos + 1, window=spec.window,
                    softcap=cfg.softcap, use_pallas=cfg.use_pallas,
                    model_axis=cfg.parallel.model_axis,
                    batch_axes=cfg.parallel.batch_axes)
            else:
                # ring buffer (window < max_len): dense path, frozen where
                # the slot is inactive
                S = kc.shape[2]
                idx = pos % S
                kc_new = L.cache_write(kc, k[:, :, 0:1], idx,
                                       cfg.parallel.aligned_decode)
                vc_new = L.cache_write(vc, v[:, :, 0:1], idx,
                                       cfg.parallel.aligned_decode)
                kc = jnp.where(wmask, kc_new, kc)
                vc = jnp.where(wmask, vc_new, vc)
                eff_len = jnp.minimum(pos + 1, S)
                # no dist_axis: the engine refuses inplace paging under
                # decode_attn="shard_map" (serve/engine.py), so the seq-
                # sharded decode variant is unreachable from this step
                o = ops.decode_attention(q, kc_new, vc_new, eff_len,
                                         softcap=cfg.softcap)
            x = _block_tail(pj, x, o, cfg)
            new_k.append(kc)
            new_v.append(vc)
        # tree-map stack: quantized pool leaves are QuantizedLeaf pytrees
        # (codes + scales stack independently); dense ring leaves are plain
        # arrays and take the same path
        stack = lambda xs: jax.tree.map(lambda *ls: jnp.stack(ls), *xs)
        upd = {
            "k": [stack(new_k[s::P]) for s in range(P)],
            "v": [stack(new_v[s::P]) for s in range(P)],
        }
        return x, upd

    xs = {"blocks": params["blocks"], "k": cache["k"], "v": cache["v"]}
    x, upd = jax.lax.scan(group_fn, x, xs)

    logits = _logits_head(params, x[:, 0], cfg)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = upd["k"], upd["v"]
    new_cache["len"] = cache["len"] + write.astype(jnp.int32)
    return logits, new_cache


def decode_step(params, cache, tokens: jnp.ndarray, cfg: ModelConfig):
    """One decode step. tokens (B,) -> (logits (B, V), new_cache)."""
    n_groups, group_size = group_layout(cfg)
    P = len(cfg.layer_pattern)
    x = _embed_decode(params, tokens, cfg)
    pos = cache["len"]                        # (B,)
    positions = pos[:, None]                  # (B, 1)

    def group_fn(x, group_in):
        gp = group_in["blocks"]
        new_k, new_v = [], []
        for j in range(group_size):
            slot = j % P
            spec = cfg.layer_pattern[slot]
            pj = jax.tree.map(lambda a: a[j], gp)
            kc = group_in["k"][slot][j // P]
            vc = group_in["v"][slot][j // P]
            q, k, v = _block_qkv(pj, x, positions, cfg)
            S = kc.shape[2]
            if spec.window and spec.window <= S:
                idx = pos % S                 # ring buffer for local layers
            else:
                idx = jnp.minimum(pos, S - 1)
            kc = L.cache_write(kc, k[:, :, 0:1], idx,
                               cfg.parallel.aligned_decode)
            vc = L.cache_write(vc, v[:, :, 0:1], idx,
                               cfg.parallel.aligned_decode)
            dist_axis = (cfg.parallel.seq_axis
                         if cfg.parallel.decode_attn == "shard_map" else None)
            if spec.window and spec.window <= S:
                # ring buffer: all S slots valid once len >= S; attention mask
                # handles the general case via effective length
                eff_len = jnp.minimum(pos + 1, S)
                o = ops.decode_attention(q, kc, vc, eff_len, softcap=cfg.softcap,
                                         dist_axis=dist_axis,
                                         batch_axes=cfg.parallel.batch_axes)
            else:
                o = ops.decode_attention(q, kc, vc, pos + 1, window=spec.window,
                                         softcap=cfg.softcap,
                                         dist_axis=dist_axis,
                                         batch_axes=cfg.parallel.batch_axes)
            x = _block_tail(pj, x, o, cfg)
            new_k.append(kc)
            new_v.append(vc)
        if cfg.cross_attn_every:
            kv = (group_in["cross_k"], group_in["cross_v"])
            x = _cross_apply(group_in["cross"], x, kv, cfg)
        upd = {
            "k": [jnp.stack(new_k[s::P]) for s in range(P)],
            "v": [jnp.stack(new_v[s::P]) for s in range(P)],
        }
        return x, upd

    xs = {"blocks": params["blocks"], "k": cache["k"], "v": cache["v"]}
    if cfg.cross_attn_every:
        xs["cross"] = params["cross"]
        xs["cross_k"] = cache["cross_k"]
        xs["cross_v"] = cache["cross_v"]
    x, upd = jax.lax.scan(group_fn, x, xs)

    logits = _logits_head(params, x[:, 0], cfg)
    new_cache = dict(cache)
    new_cache["k"], new_cache["v"] = upd["k"], upd["v"]
    new_cache["len"] = cache["len"] + 1
    return logits, new_cache
