"""Unified model API: family dispatch, loss, LAQ model quantization,
and ShapeDtypeStruct input specs for the dry-run.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import quant
from repro.models import encdec, hymba, rwkv6, transformer

_FAMILIES = {
    "lm": transformer,
    "rwkv": rwkv6,
    "hymba": hymba,
    "encdec": encdec,
}


def family_module(cfg: ModelConfig):
    return _FAMILIES[cfg.family]


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    return family_module(cfg).init_params(cfg, key)


def forward(params, tokens, cfg: ModelConfig, frontend=None):
    return family_module(cfg).forward(params, tokens, cfg, frontend=frontend)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, frontend=None, params=None):
    return family_module(cfg).init_cache(cfg, batch, max_len,
                                         frontend=frontend, params=params)


def decode_step(params, cache, tokens, cfg: ModelConfig):
    return family_module(cfg).decode_step(params, cache, tokens, cfg)


def paged_decode_step(params, cache, table, tokens, cfg: ModelConfig, *,
                      write=None, seq_axes=None):
    """One decode step computed directly through the page pool.

    The gather-free serve path (DESIGN.md §6): ``cache`` is the paged slot
    cache (pool leaves in the kernel-friendly layout of
    ``serve/pages.py::make_pool``, dense leaves untouched), ``table`` the
    (B, P) physical page table, ``write`` the active-slot mask (frozen
    slots append to the scratch page and keep their dense leaves / ``len``).
    ``seq_axes`` is the discovery pytree marking which leaves page.
    Families whose caches never page (rwkv, and hymba/lm with every slot
    window-capped) are served by the dense fallback and never reach here.
    """
    mod = family_module(cfg)
    if not hasattr(mod, "paged_decode_step"):
        raise NotImplementedError(
            f"family {cfg.family!r} has no paged decode entry point; its "
            "caches should have fallen back to the dense slot layout")
    return mod.paged_decode_step(params, cache, table, tokens, cfg,
                                 write=write, seq_axes=seq_axes)


def _prefill_fits(cache, prompt_len: int) -> bool:
    """True when every KV slot can hold the whole prompt as one block."""
    kv = cache.get("k") if isinstance(cache, dict) else None
    if not isinstance(kv, list):
        return False
    return all(a.shape[4] >= prompt_len for a in kv)


def prefill(params, cache, tokens, cfg: ModelConfig):
    """Fill a fresh cache with a whole prompt in one fused call.

    tokens (B, T) -> (last-position logits (B, V), cache with len += T).
    Dispatches to the family module's block ``prefill`` when available and
    the cache geometry allows it (lm); otherwise falls back to a
    ``lax.scan`` of decode_step — still a single program, one dispatch.
    """
    mod = family_module(cfg)
    T = tokens.shape[1]
    if hasattr(mod, "prefill") and _prefill_fits(cache, T):
        # The block prefill writes at slot 0 with positions 0..T-1: it is
        # only correct on a FRESH cache.  Under jit ``len`` is a tracer and
        # the contract is on the caller; eager misuse is caught here.
        ln = cache.get("len")
        if isinstance(ln, jnp.ndarray) and not isinstance(ln, jax.core.Tracer):
            assert int(ln.max()) == 0, "prefill requires an empty cache"
        return mod.prefill(params, cache, tokens, cfg)

    def body(c, tok):
        logits, c = mod.decode_step(params, c, tok, cfg)
        return c, logits

    cache, logits = jax.lax.scan(body, cache, tokens.T)
    return logits[-1], cache


def prefill_bucketed(params, cache, tokens, true_len, cfg: ModelConfig):
    """Prefill a right-padded prompt: only the first ``true_len`` of the
    ``tokens`` width are real; the rest is bucket padding.

    tokens (B, Tb) with Tb a power-of-two bucket, true_len a (traced) scalar
    -> (logits at position true_len - 1, cache with len += true_len).  One
    compiled program per bucket width, reused by every prompt length that
    rounds up to it — the serve-path jit caches stay O(log max_len).

    The lm family takes the fused block-prefill fast path (garbage K/V past
    ``true_len`` is provably unreachable — see models/transformer.py); every
    other family scans ``decode_step`` with the state update *masked* past
    ``true_len``, so recurrent state (rwkv WKV, hymba SSM) is never touched
    by padding tokens.
    """
    mod = family_module(cfg)
    Tb = tokens.shape[1]
    true_len = jnp.asarray(true_len, jnp.int32)
    if hasattr(mod, "prefill") and _prefill_fits(cache, Tb):
        return mod.prefill(params, cache, tokens, cfg, true_len=true_len)

    def body(c, xt):
        tok, t = xt
        logits, c_new = mod.decode_step(params, c, tok, cfg)
        keep = t < true_len
        c = jax.tree.map(lambda new, old: jnp.where(keep, new, old), c_new, c)
        return c, logits

    steps = jnp.arange(Tb, dtype=jnp.int32)
    cache, logits = jax.lax.scan(body, cache, (tokens.T, steps))
    last = jax.lax.dynamic_index_in_dim(logits, true_len - 1, axis=0,
                                        keepdims=False)
    return last, cache


def prefill_chunk(params, cache, tokens, true_len, cfg: ModelConfig, *,
                  block: bool = True):
    """Advance a (possibly non-empty) cache by one right-padded prompt chunk.

    The chunked-prefill primitive: unlike :func:`prefill_bucketed` this
    starts from whatever state the cache is in, so a long prompt can be fed
    as fixed-width chunks interleaved with decode steps (serve/scheduler.py).
    tokens (B, W) with W the static chunk width; only the first ``true_len``
    (traced) positions are real.  Returns the advanced cache — no logits:
    the last prompt token goes through the decode step, which produces them.

    "Whatever state" includes a NONZERO cached start: the shared-prefix
    serve path (serve/pages.py, DESIGN.md §7) seeds a request cache with
    ``cached`` prompt positions gathered from the page pool and sets
    ``len = cached`` — both chunk paths then continue the prompt from
    there unchanged, because positions are absolute (``cache["len"]``-
    relative rope and causal masks in ``ops.chunk_attention``, the scanned
    ``decode_step`` respectively).  Families whose state does NOT all live
    in the paged K/V (recurrent state, window ring buffers) cannot be
    seeded this way — the prefix index no-ops for them and they always
    start from 0 via full prefill.

    ``block=True`` takes the lm fused chunk path
    (``models/transformer.py::prefill_chunk``); the caller must guarantee a
    linear (non-ring) cache layout.  ``block=False`` scans ``decode_step``
    with the state update masked past ``true_len`` — correct for every
    family (recurrent state never sees padding, ring buffers write exactly
    as decode would).
    """
    mod = family_module(cfg)
    W = tokens.shape[1]
    true_len = jnp.asarray(true_len, jnp.int32)
    if block and hasattr(mod, "prefill_chunk"):
        return mod.prefill_chunk(params, cache, tokens, true_len, cfg)

    def body(c, xt):
        tok, t = xt
        _, c_new = mod.decode_step(params, c, tok, cfg)
        keep = t < true_len
        c = jax.tree.map(lambda new, old: jnp.where(keep, new, old), c_new, c)
        return c, None

    steps = jnp.arange(W, dtype=jnp.int32)
    cache, _ = jax.lax.scan(body, cache, (tokens.T, steps))
    return cache


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            aux_weight: float = 0.01):
    """Next-token cross-entropy (+ MoE load-balance aux)."""
    logits, aux = forward(params, batch["tokens"], cfg,
                          frontend=batch.get("frontend"))
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss + aux_weight * aux, {"loss": loss, "aux": aux}


# ----------------------------------------------------------------------------
# LAQ quantization of a whole model (the ITA "synthesis" step)
# ----------------------------------------------------------------------------
_QUANT_KEYS = {"wq", "wk", "wv", "wo", "w1", "w2", "w3", "lm_head",
               "wr", "wg", "cm_k", "cm_v", "w_in", "w_out"}


def quantize_model(params: Dict[str, Any], cfg: ModelConfig) -> Dict[str, Any]:
    """Replace every device-side (static linear) weight with LAQ INT4 codes.

    Norm scales, embeddings, router logits weights, recurrent decay params —
    the host-side / dynamic pieces — stay in float.  Stacked (layer-leading)
    weights are quantized per layer via vmap, keeping per-(layer, channel)
    scales.
    """
    ita = cfg.ita

    def q2d(w):
        return quant.quantize_weights(
            w, prune_threshold=ita.prune_threshold, laq_slack=ita.laq_slack,
            logic_aware=ita.logic_aware)

    def quantize_entry(path_key: str, w):
        if path_key not in _QUANT_KEYS or not hasattr(w, "ndim") or w.ndim < 2:
            return w
        if w.ndim == 2:
            return q2d(w)
        lead = w.shape[:-2]
        flat = w.reshape((-1,) + w.shape[-2:])
        ql = jax.vmap(q2d)(flat)
        return quant.QuantizedLinear(
            codes=ql.codes.reshape(lead + w.shape[-2:]),
            scales=ql.scales.reshape(lead + (w.shape[-1],)))

    def walk(node):
        if isinstance(node, dict):
            return {k: (quantize_entry(k, v) if not isinstance(v, (dict, list))
                        else walk(v)) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        return node

    return walk(params)


# ----------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct; zero allocation)
# ----------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model inputs for one step of the given shape, as ShapeDtypeStructs."""
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        specs["tokens"] = jax.ShapeDtypeStruct((B, T), i32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, T), i32)
    else:  # decode: one new token against a T-long cache
        specs["tokens"] = jax.ShapeDtypeStruct((B,), i32)
    if cfg.frontend_tokens:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), dt)
    return specs
