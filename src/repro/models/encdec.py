"""Encoder-decoder backbone (seamless-m4t-medium): bidirectional encoder over
stub modality embeddings (precomputed audio-frame vectors per the assignment)
plus a causal decoder with cross-attention.

Split-brain: all enc/dec projections are device-side; the decoder KV cache,
cross-attention and softmax are host-side.  Cross K/V are projected once at
prefill (device) and live in the host cache thereafter — exactly the paper's
"static weights vs dynamic state" split (DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import layers as L


def _mlp_init(key, d, ff, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {"w1": L.dense_init(ks[0], d, ff, dtype),
            "w3": L.dense_init(ks[1], d, ff, dtype),
            "w2": L.dense_init(ks[2], ff, d, dtype)}


def _enc_block_init(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    hd = cfg.resolved_head_dim
    return {
        "ln_attn": jnp.zeros((cfg.d_model,), dtype),
        "ln_mlp": jnp.zeros((cfg.d_model,), dtype),
        "attn": L.attn_init(ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd, dtype),
        "mlp": _mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_block_init(key, cfg: ModelConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    hd = cfg.resolved_head_dim
    return {
        "ln_self": jnp.zeros((cfg.d_model,), dtype),
        "ln_cross": jnp.zeros((cfg.d_model,), dtype),
        "ln_mlp": jnp.zeros((cfg.d_model,), dtype),
        "self": L.attn_init(ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd, dtype),
        "cross": L.attn_init(ks[1], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd, dtype),
        "mlp": _mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    k_emb, k_enc, k_dec, k_head = jax.random.split(key, 4)
    enc_keys = jax.random.split(k_enc, cfg.num_encoder_layers)
    dec_keys = jax.random.split(k_dec, cfg.num_layers)
    return {
        "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model)) * 0.02,
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(enc_keys),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(dec_keys),
        "ln_enc": jnp.zeros((cfg.d_model,)),
        "ln_final": jnp.zeros((cfg.d_model,)),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.vocab_size),
    }


def encode(params, frontend: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frontend: (B, T_frames, d) stub audio embeddings -> (B, T, d)."""
    dtype = jnp.dtype(cfg.dtype)
    x = frontend.astype(dtype)
    positions = jnp.arange(x.shape[1])

    def layer(x, p):
        if cfg.parallel.gather_fsdp_weights:
            from repro.distributed import sharding as _shd
            p = _shd.gather_fsdp(p, cfg)
            x = _shd.pin_batch(x, cfg)
        h = L.attn_apply(p["attn"], L.rmsnorm(x, p["ln_attn"], cfg.norm_eps),
                         num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                         head_dim=cfg.resolved_head_dim, positions=positions,
                         rope_theta=cfg.rope_theta, causal=False,
                         use_pallas=cfg.use_pallas)
        x = x + h
        y = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
        x = x + L.swiglu(y, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"])
        return x, None

    if cfg.parallel.remat != "none":
        layer = jax.checkpoint(layer)
    x, _ = jax.lax.scan(layer, x, params["enc_blocks"])
    return L.rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def forward(params, tokens: jnp.ndarray, cfg: ModelConfig,
            frontend: Optional[jnp.ndarray] = None, **_):
    """Teacher-forced decode over full target sequence (training)."""
    dtype = jnp.dtype(cfg.dtype)
    enc = encode(params, frontend, cfg)
    hd = cfg.resolved_head_dim
    B, T = tokens.shape
    x = params["embed"][tokens].astype(dtype)
    positions = jnp.arange(T)

    def layer(x, p):
        if cfg.parallel.gather_fsdp_weights:
            from repro.distributed import sharding as _shd
            p = _shd.gather_fsdp(p, cfg)
            x = _shd.pin_batch(x, cfg)
        h = L.attn_apply(p["self"], L.rmsnorm(x, p["ln_self"], cfg.norm_eps),
                         num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                         head_dim=hd, positions=positions,
                         rope_theta=cfg.rope_theta, use_pallas=cfg.use_pallas)
        x = x + h
        xn = L.rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        Bx, Tx, _ = enc.shape
        ck = L.linear(enc, p["cross"]["wk"]).reshape(Bx, Tx, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
        cv = L.linear(enc, p["cross"]["wv"]).reshape(Bx, Tx, cfg.num_kv_heads, hd).transpose(0, 2, 1, 3)
        h = L.attn_apply(p["cross"], xn, num_heads=cfg.num_heads,
                         num_kv_heads=cfg.num_kv_heads, head_dim=hd,
                         positions=positions, rope_theta=cfg.rope_theta,
                         kv=(ck, cv), use_pallas=cfg.use_pallas)
        x = x + h
        y = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
        x = x + L.swiglu(y, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"])
        return x, None

    if cfg.parallel.remat != "none":
        layer = jax.checkpoint(layer)
    x, _ = jax.lax.scan(layer, x, params["dec_blocks"])
    x = L.rmsnorm(x, params["ln_final"], cfg.norm_eps)
    logits = L.linear(x, params["lm_head"]).astype(jnp.float32)
    return logits, 0.0


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               frontend: Optional[jnp.ndarray] = None, params=None) -> Dict[str, Any]:
    hd = cfg.resolved_head_dim
    Ld = cfg.num_layers
    dtype = jnp.dtype(cfg.dtype)
    cache: Dict[str, Any] = {
        "k": jnp.zeros((Ld, batch, cfg.num_kv_heads, max_len, hd), dtype),
        "v": jnp.zeros((Ld, batch, cfg.num_kv_heads, max_len, hd), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if frontend is not None and params is not None:
        enc = encode(params, frontend, cfg)
        Bx, Tx, _ = enc.shape

        def proj(p):
            ck = L.linear(enc, p["cross"]["wk"]).reshape(Bx, Tx, cfg.num_kv_heads, hd)
            cv = L.linear(enc, p["cross"]["wv"]).reshape(Bx, Tx, cfg.num_kv_heads, hd)
            return ck.transpose(0, 2, 1, 3), cv.transpose(0, 2, 1, 3)

        ck, cv = jax.vmap(proj)(params["dec_blocks"])
        cache["cross_k"], cache["cross_v"] = ck, cv
    return cache


def decode_step(params, cache, tokens: jnp.ndarray, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    hd = cfg.resolved_head_dim
    x = params["embed"][tokens][:, None, :].astype(dtype)
    pos = cache["len"]
    positions = pos[:, None]

    def layer(x, inputs):
        p, kc, vc, ck, cv = inputs
        xn = L.rmsnorm(x, p["ln_self"], cfg.norm_eps)
        q, k, v = L.qkv_project(p["self"], xn, cfg.num_heads, cfg.num_kv_heads, hd)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        kc = L.cache_write(kc, k[:, :, 0:1], pos,
                           cfg.parallel.aligned_decode)
        vc = L.cache_write(vc, v[:, :, 0:1], pos,
                           cfg.parallel.aligned_decode)
        dist_axis = (cfg.parallel.seq_axis
                     if cfg.parallel.decode_attn == "shard_map" else None)
        o = ops.decode_attention(q, kc, vc, pos + 1, dist_axis=dist_axis,
                                 batch_axes=cfg.parallel.batch_axes)
        x = x + L.linear(o.transpose(0, 2, 1, 3).reshape(B, 1, cfg.num_heads * hd),
                         p["self"]["wo"])
        xn = L.rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        qx = L.linear(xn, p["cross"]["wq"]).reshape(B, 1, cfg.num_heads, hd).transpose(0, 2, 1, 3)
        Tx = ck.shape[2]
        o = ops.decode_attention(qx, ck, cv, jnp.full((B,), Tx, jnp.int32),
                                 dist_axis=dist_axis,
                                 batch_axes=cfg.parallel.batch_axes)
        x = x + L.linear(o.transpose(0, 2, 1, 3).reshape(B, 1, cfg.num_heads * hd),
                         p["cross"]["wo"])
        y = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
        x = x + L.swiglu(y, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"])
        return x, (kc, vc)

    x, (k, v) = jax.lax.scan(
        layer, x, (params["dec_blocks"], cache["k"], cache["v"],
                   cache["cross_k"], cache["cross_v"]))
    x = L.rmsnorm(x, params["ln_final"], cfg.norm_eps)
    logits = L.linear(x[:, 0], params["lm_head"]).astype(jnp.float32)
    new_cache = dict(cache)
    new_cache.update({"k": k, "v": v, "len": cache["len"] + 1})
    return logits, new_cache
