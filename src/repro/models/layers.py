"""Shared pure-JAX building blocks: norms, rope, linear (raw or LAQ-quantized),
embeddings, GQA attention.  No flax — params are plain pytrees of arrays.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels import ops

Init = jax.nn.initializers


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return jax.random.uniform(key, (in_dim, out_dim), dtype, -scale, scale)


def linear(x: jnp.ndarray, w, use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Apply a linear map; ``w`` is a raw (in,out) array or a QuantizedLinear.

    The quantized branch is the ITA device datapath: INT8 activations times
    hardwired INT4 codes (see core/quant.py, kernels/w4a8_matmul.py).
    ``use_pallas`` selects the Pallas W4A8 kernel for the quantized branch
    (None defers to the ``kernels.ops`` module default).
    """
    if isinstance(w, quant.QuantizedLinear):
        shape = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        qx, xs = quant.quantize_activations_int8(x2)
        y = ops.w4a8_matmul(qx, xs, w.codes, w.scales, out_dtype=x.dtype,
                            use_pallas=use_pallas)
        return y.reshape(*shape, w.codes.shape[-1])
    return x @ w.astype(x.dtype)


def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, H, T, D) with even D; positions: (T,) or (B, T)."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]   # (T, half)
        ang = ang[None, None]
    else:
        ang = positions[:, None, :, None].astype(jnp.float32) * freqs[None, None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(x.dtype)


def swiglu(x: jnp.ndarray, w1, w3, w2,
           use_pallas: Optional[bool] = None, pin_fn=None) -> jnp.ndarray:
    """FFN(x) = W2 . (silu(W1 x) * (W3 x)) — eq. (4)/(5) of the paper.

    ``pin_fn`` (serve TP exactness, DESIGN.md §11) is applied to the hidden
    activation before the W2 contraction — sharding.pin_tp_exact gathers a
    d_ff-sharded hidden so the down-projection is never split."""
    h = jax.nn.silu(linear(x, w1, use_pallas)) * linear(x, w3, use_pallas)
    if pin_fn is not None:
        h = pin_fn(h)
    return linear(h, w2, use_pallas)


# ----------------------------------------------------------------------------
# GQA attention block
# ----------------------------------------------------------------------------
def attn_init(key, d_model: int, num_heads: int, num_kv_heads: int,
              head_dim: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, num_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }


def qkv_project(p: dict, x: jnp.ndarray, num_heads: int, num_kv_heads: int,
                head_dim: int, use_pallas: Optional[bool] = None):
    """The ITA device phase of attention: static linear maps only."""
    B, T, _ = x.shape
    q = linear(x, p["wq"], use_pallas).reshape(B, T, num_heads, head_dim).transpose(0, 2, 1, 3)
    k = linear(x, p["wk"], use_pallas).reshape(B, T, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    v = linear(x, p["wv"], use_pallas).reshape(B, T, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    return q, k, v


def attn_apply(p: dict, x: jnp.ndarray, *, num_heads: int, num_kv_heads: int,
               head_dim: int, positions: jnp.ndarray, rope_theta: float,
               window: Optional[int] = None, softcap: Optional[float] = None,
               causal: bool = True, use_pallas: bool = False,
               kv: Optional[tuple] = None, pin_fn=None) -> jnp.ndarray:
    """Full attention block (prefill/training path). ``kv`` overrides K/V
    (cross-attention: keys/values from another sequence, no rope).
    ``pin_fn`` gathers the head-sharded attention output before the wo
    contraction (serve TP exactness, DESIGN.md §11)."""
    B, T, _ = x.shape
    q, k, v = qkv_project(p, x, num_heads, num_kv_heads, head_dim)
    if kv is None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    else:
        k, v = kv
        causal, window = False, None
    o = ops.attention(q, k, v, causal=causal, window=window, softcap=softcap,
                      use_pallas=use_pallas)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, num_heads * head_dim)
    if pin_fn is not None:
        o = pin_fn(o)
    return linear(o, p["wo"])


def cache_write(cache: jnp.ndarray, new: jnp.ndarray, pos: jnp.ndarray,
                aligned: bool = True) -> jnp.ndarray:
    """Write one token's K or V into the cache at per-sequence positions.

    cache: (B, Hkv, S, D); new: (B, Hkv, 1, D); pos: (B,).

    ``aligned=True`` (lockstep decode, the dry-run serving shapes): a single
    dynamic_update_slice at the scalar position — SPMD-partitions cleanly
    with the cache sharded on batch and sequence.  The batched-index vmap
    form lowers to ``scatter``, which XLA's partitioner can only handle by
    all-gathering the cache every layer (measured 77 GB/chip/step on
    granite-8b decode_32k — §Perf H2 log).  ``aligned=False`` keeps ragged
    positions via a one-hot masked select (shardable, full-cache traffic).
    """
    if aligned:
        return jax.lax.dynamic_update_slice(
            cache, new, (0, 0, pos[0], 0))
    S = cache.shape[2]
    onehot = (jnp.arange(S)[None, :] == pos[:, None])[:, None, :, None]
    return jnp.where(onehot, new, cache)


# Physical page 0 of every page pool is the reserved scratch page: writes
# for inactive slots are routed there so the jitted step keeps fixed shapes
# (serve/pages.py re-exports this as the allocator's contract).
SCRATCH_PAGE = 0

# re-exported KV page-quantization vocabulary (core/quant.py owns it so the
# kernel dispatcher can see QuantizedLeaf without an import cycle)
QuantizedLeaf = quant.QuantizedLeaf
KV_DTYPES = quant.KV_DTYPES
KV_QMAX = quant.KV_QMAX


def kv_pow2_scale(amax: jnp.ndarray, kv_dtype: str) -> jnp.ndarray:
    """Smallest power-of-two scale s with ``amax/s <= qmax``.

    Power-of-two scales make the page quantizer IDEMPOTENT: requantizing
    already-roundtripped content lands on the same codes (int8: any page
    whose ratio amax/s exceeded qmax/2 before rounding still exceeds it
    after, so the exponent never drops), which is what lets shared prefix
    pages quantize once and the prefix on/off token-identity survive
    quantization (DESIGN.md §13)."""
    qmax = KV_QMAX[kv_dtype]
    amax = jnp.maximum(amax.astype(jnp.float32), 1e-30)
    return jnp.exp2(jnp.ceil(jnp.log2(amax / qmax)))


def kv_quantize(x: jnp.ndarray, scale: jnp.ndarray,
                kv_dtype: str) -> jnp.ndarray:
    """Encode f32 values into page codes under a (broadcastable) scale."""
    y = x.astype(jnp.float32) / scale
    if kv_dtype == "int8":
        return jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return y.astype(KV_DTYPES[kv_dtype])


def kv_dequantize(codes: jnp.ndarray, scale: jnp.ndarray,
                  out_dtype=jnp.float32) -> jnp.ndarray:
    """codes × scale.  Exact for both formats: |code| · 2^e products carry
    at most 8 significant bits, so even a bfloat16 ``out_dtype`` holds them
    without rounding — dequantized views are bit-stable."""
    return (codes.astype(jnp.float32) * scale).astype(out_dtype)


def page_offsets(table: jnp.ndarray, pos: jnp.ndarray, write: jnp.ndarray,
                 page_size: int):
    """Resolve per-slot write coordinates through the page table: position
    ``pos[b]`` of slot ``b`` lives at ``(table[b, pos // ps], pos % ps)``;
    slots with ``write=False`` are routed to the scratch page so jitted
    programs keep fixed shapes whatever the active set.  The ONE place the
    table-indexing/scratch contract lives — shared by the in-place append
    (``paged_cache_write``) and the gather discipline's writeback
    (``serve/pages.py::scatter_token``)."""
    page = jnp.take_along_axis(table, (pos // page_size)[:, None],
                               axis=1)[:, 0]
    return jnp.where(write, page, SCRATCH_PAGE), pos % page_size


def paged_cache_write(pool, new: jnp.ndarray,
                      table: jnp.ndarray, pos: jnp.ndarray,
                      write: jnp.ndarray):
    """Append one token's K or V per slot directly into the page pool.

    pool: (num_pages, page_size, Hkv, D) — one layer's kernel-friendly pool
    slice (or its :class:`QuantizedLeaf` counterpart, which routes to the
    quantize-on-write append); new: (B, Hkv, 1, D); table: (B, P) physical
    page ids; pos: (B,) write positions (== ``len``); write: (B,) bool —
    inactive slots land on the scratch page so the program shape never
    depends on the active set.  O(B x token bytes) pool traffic: the
    in-place counterpart of ``cache_write`` with no dense view in sight.
    """
    if isinstance(pool, QuantizedLeaf):
        ps = pool.codes.shape[1]
        page, off = page_offsets(table, pos, write, ps)
        tok = new[:, :, 0, :]                          # (B, Hkv, D)
        codes, scales = quant_page_append(pool.codes, pool.scales, tok,
                                          page, off, pool.kv_dtype)
        return QuantizedLeaf(codes, scales, pool.kv_dtype, pool.out_dtype)
    page, off = page_offsets(table, pos, write, pool.shape[1])
    tok = new[:, :, 0, :].astype(pool.dtype)           # (B, Hkv, D)
    return pool.at[page, off].set(tok)


def quant_page_append(codes: jnp.ndarray, scales: jnp.ndarray,
                      tok: jnp.ndarray, page: jnp.ndarray, off: jnp.ndarray,
                      kv_dtype: str):
    """The quantize-on-write page append core (DESIGN.md §13).

    codes: (N, ps, *rest) pool codes in pages-leading layout; scales:
    (N, *rest[:-1]) matching per-page scales (the trailing head_dim axis is
    reduced away); tok: (B, *rest) the new token; page/off: (B,) resolved
    write coordinates (``page_offsets``).  Decode-append must REQUANTIZE
    the page — the incoming token can exceed the page's current range — so
    the page is dequantized, the token inserted at ``off``, and the whole
    page re-encoded under ``max(old_scale, needed)``:

      * ``off == 0`` means a FRESH (or reused) page: the stale codes and
        scale are dead, so the effective old scale is zeroed and positions
        past ``off`` are masked out of the re-encode — a recycled page can
        never leak a stale amax into the new sequence's scale;
      * the scale is monotone within a page lifetime (never shrinks), so
        already-written positions only ever requantize under an equal or
        coarser power-of-two scale.

    Returns ``(codes, scales)`` with the touched pages rewritten.  Both
    scatters may hit duplicate indices only on the scratch page (inactive
    slots), whose content is garbage by contract.
    """
    nd = codes.ndim
    ps = codes.shape[1]
    B = tok.shape[0]

    def _x(s):  # (B, *rest[:-1]) -> broadcast over (B, ps, *rest)
        return jnp.expand_dims(s, (1, nd - 1))

    cp = codes[page]                                   # (B, ps, *rest)
    sp = scales[page]                                  # (B, *rest[:-1])
    fresh = (off > 0).reshape((B,) + (1,) * (sp.ndim - 1))
    sp_eff = jnp.where(fresh, sp, 0.0)
    old = cp.astype(jnp.float32) * _x(sp_eff)
    idx = jnp.arange(ps)[None, :]
    keep = (idx < off[:, None]).reshape((B, ps) + (1,) * (nd - 2))
    ins = (idx == off[:, None]).reshape((B, ps) + (1,) * (nd - 2))
    merged = jnp.where(keep, old, 0.0)
    merged = jnp.where(ins, tok[:, None].astype(jnp.float32), merged)
    amax = jnp.max(jnp.abs(merged), axis=(1, nd - 1))  # (B, *rest[:-1])
    new_sc = jnp.maximum(sp_eff, kv_pow2_scale(amax, kv_dtype))
    q = kv_quantize(merged, _x(new_sc), kv_dtype)
    return codes.at[page].set(q), scales.at[page].set(new_sc)


def fake_quant_pages(leaf: jnp.ndarray, s_ax: int, n_tokens,
                     page_size: int, kv_dtype: str) -> jnp.ndarray:
    """Round-trip the COMPLETED pages of a dense request-cache leaf through
    the page quantizer (quantize→dequantize in place, dense dtype kept).

    The prefix-cache identity glue (DESIGN.md §13): under a quantized pool,
    a page's content is frozen at quantized precision the moment the page
    completes during prefill, so the chunk stream attends to exactly the
    values a later consumer will dequantize out of the shared page — the
    prefix on/off token identity survives quantization.  ``n_tokens``
    (traced) marks the filled length; only pages wholly below it round-trip
    (the partial tail page stays dense until insertion).  Per-page scales
    reduce over the within-page and trailing head_dim axes, matching the
    pool layout's per-page × per-kv-head scale exactly, and the insert
    quantizer reproduces the same codes from the roundtripped content
    (power-of-two idempotence), so shared pages quantize ONCE.
    """
    S = leaf.shape[s_ax]
    P = S // page_size
    x = jnp.moveaxis(leaf, s_ax, 0)                    # (S, *rest)
    xp = x.reshape((P, page_size) + x.shape[1:]).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xp), axis=(1, xp.ndim - 1), keepdims=True)
    sc = kv_pow2_scale(amax, kv_dtype)
    rt = kv_dequantize(kv_quantize(xp, sc, kv_dtype), sc)
    done = (jnp.arange(P) < jnp.asarray(n_tokens, jnp.int32) // page_size)
    rt = jnp.where(done.reshape((P,) + (1,) * (xp.ndim - 1)), rt, xp)
    out = rt.reshape(x.shape).astype(leaf.dtype)
    return jnp.moveaxis(out, 0, s_ax)
