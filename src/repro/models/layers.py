"""Shared pure-JAX building blocks: norms, rope, linear (raw or LAQ-quantized),
embeddings, GQA attention.  No flax — params are plain pytrees of arrays.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.kernels import ops

Init = jax.nn.initializers


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return jax.random.uniform(key, (in_dim, out_dim), dtype, -scale, scale)


def linear(x: jnp.ndarray, w, use_pallas: Optional[bool] = None) -> jnp.ndarray:
    """Apply a linear map; ``w`` is a raw (in,out) array or a QuantizedLinear.

    The quantized branch is the ITA device datapath: INT8 activations times
    hardwired INT4 codes (see core/quant.py, kernels/w4a8_matmul.py).
    ``use_pallas`` selects the Pallas W4A8 kernel for the quantized branch
    (None defers to the ``kernels.ops`` module default).
    """
    if isinstance(w, quant.QuantizedLinear):
        shape = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        qx, xs = quant.quantize_activations_int8(x2)
        y = ops.w4a8_matmul(qx, xs, w.codes, w.scales, out_dtype=x.dtype,
                            use_pallas=use_pallas)
        return y.reshape(*shape, w.codes.shape[-1])
    return x @ w.astype(x.dtype)


def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0) -> jnp.ndarray:
    """x: (B, H, T, D) with even D; positions: (T,) or (B, T)."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        ang = positions[:, None].astype(jnp.float32) * freqs[None, :]   # (T, half)
        ang = ang[None, None]
    else:
        ang = positions[:, None, :, None].astype(jnp.float32) * freqs[None, None, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1).astype(x.dtype)


def swiglu(x: jnp.ndarray, w1, w3, w2,
           use_pallas: Optional[bool] = None, pin_fn=None) -> jnp.ndarray:
    """FFN(x) = W2 . (silu(W1 x) * (W3 x)) — eq. (4)/(5) of the paper.

    ``pin_fn`` (serve TP exactness, DESIGN.md §11) is applied to the hidden
    activation before the W2 contraction — sharding.pin_tp_exact gathers a
    d_ff-sharded hidden so the down-projection is never split."""
    h = jax.nn.silu(linear(x, w1, use_pallas)) * linear(x, w3, use_pallas)
    if pin_fn is not None:
        h = pin_fn(h)
    return linear(h, w2, use_pallas)


# ----------------------------------------------------------------------------
# GQA attention block
# ----------------------------------------------------------------------------
def attn_init(key, d_model: int, num_heads: int, num_kv_heads: int,
              head_dim: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, num_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, num_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, num_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], num_heads * head_dim, d_model, dtype),
    }


def qkv_project(p: dict, x: jnp.ndarray, num_heads: int, num_kv_heads: int,
                head_dim: int, use_pallas: Optional[bool] = None):
    """The ITA device phase of attention: static linear maps only."""
    B, T, _ = x.shape
    q = linear(x, p["wq"], use_pallas).reshape(B, T, num_heads, head_dim).transpose(0, 2, 1, 3)
    k = linear(x, p["wk"], use_pallas).reshape(B, T, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    v = linear(x, p["wv"], use_pallas).reshape(B, T, num_kv_heads, head_dim).transpose(0, 2, 1, 3)
    return q, k, v


def attn_apply(p: dict, x: jnp.ndarray, *, num_heads: int, num_kv_heads: int,
               head_dim: int, positions: jnp.ndarray, rope_theta: float,
               window: Optional[int] = None, softcap: Optional[float] = None,
               causal: bool = True, use_pallas: bool = False,
               kv: Optional[tuple] = None, pin_fn=None) -> jnp.ndarray:
    """Full attention block (prefill/training path). ``kv`` overrides K/V
    (cross-attention: keys/values from another sequence, no rope).
    ``pin_fn`` gathers the head-sharded attention output before the wo
    contraction (serve TP exactness, DESIGN.md §11)."""
    B, T, _ = x.shape
    q, k, v = qkv_project(p, x, num_heads, num_kv_heads, head_dim)
    if kv is None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    else:
        k, v = kv
        causal, window = False, None
    o = ops.attention(q, k, v, causal=causal, window=window, softcap=softcap,
                      use_pallas=use_pallas)
    o = o.transpose(0, 2, 1, 3).reshape(B, T, num_heads * head_dim)
    if pin_fn is not None:
        o = pin_fn(o)
    return linear(o, p["wo"])


def cache_write(cache: jnp.ndarray, new: jnp.ndarray, pos: jnp.ndarray,
                aligned: bool = True) -> jnp.ndarray:
    """Write one token's K or V into the cache at per-sequence positions.

    cache: (B, Hkv, S, D); new: (B, Hkv, 1, D); pos: (B,).

    ``aligned=True`` (lockstep decode, the dry-run serving shapes): a single
    dynamic_update_slice at the scalar position — SPMD-partitions cleanly
    with the cache sharded on batch and sequence.  The batched-index vmap
    form lowers to ``scatter``, which XLA's partitioner can only handle by
    all-gathering the cache every layer (measured 77 GB/chip/step on
    granite-8b decode_32k — §Perf H2 log).  ``aligned=False`` keeps ragged
    positions via a one-hot masked select (shardable, full-cache traffic).
    """
    if aligned:
        return jax.lax.dynamic_update_slice(
            cache, new, (0, 0, pos[0], 0))
    S = cache.shape[2]
    onehot = (jnp.arange(S)[None, :] == pos[:, None])[:, None, :, None]
    return jnp.where(onehot, new, cache)


# Physical page 0 of every page pool is the reserved scratch page: writes
# for inactive slots are routed there so the jitted step keeps fixed shapes
# (serve/pages.py re-exports this as the allocator's contract).
SCRATCH_PAGE = 0


def page_offsets(table: jnp.ndarray, pos: jnp.ndarray, write: jnp.ndarray,
                 page_size: int):
    """Resolve per-slot write coordinates through the page table: position
    ``pos[b]`` of slot ``b`` lives at ``(table[b, pos // ps], pos % ps)``;
    slots with ``write=False`` are routed to the scratch page so jitted
    programs keep fixed shapes whatever the active set.  The ONE place the
    table-indexing/scratch contract lives — shared by the in-place append
    (``paged_cache_write``) and the gather discipline's writeback
    (``serve/pages.py::scatter_token``)."""
    page = jnp.take_along_axis(table, (pos // page_size)[:, None],
                               axis=1)[:, 0]
    return jnp.where(write, page, SCRATCH_PAGE), pos % page_size


def paged_cache_write(pool: jnp.ndarray, new: jnp.ndarray,
                      table: jnp.ndarray, pos: jnp.ndarray,
                      write: jnp.ndarray) -> jnp.ndarray:
    """Append one token's K or V per slot directly into the page pool.

    pool: (num_pages, page_size, Hkv, D) — one layer's kernel-friendly pool
    slice; new: (B, Hkv, 1, D); table: (B, P) physical page ids; pos: (B,)
    write positions (== ``len``); write: (B,) bool — inactive slots land on
    the scratch page so the program shape never depends on the active set.
    O(B x token bytes) pool traffic: the in-place counterpart of
    ``cache_write`` with no dense view in sight.
    """
    page, off = page_offsets(table, pos, write, pool.shape[1])
    tok = new[:, :, 0, :].astype(pool.dtype)           # (B, Hkv, D)
    return pool.at[page, off].set(tok)
