"""Pure-JAX model zoo (dense/MoE/softcap/sliding/cross-attn LMs, RWKV6,
Hymba hybrid, enc-dec)."""
from repro.models import api  # noqa: F401
