"""Mixture-of-Experts FFN: top-k routing, sort-based capacity dispatch,
grouped matmul (shardable over the expert dim = EP on the "model" axis).

Memory is O(tokens * k) — no (T, E, C) one-hot dispatch tensors — so the
32k-seq dry-run cells lower without materializing terabytes.  Dropped-token
handling follows the standard capacity-factor scheme; the combine step
scatter-adds weighted expert outputs back per token.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.layers import dense_init


def moe_init(key, d_model: int, d_ff: int, cfg: MoEConfig, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    E = cfg.num_experts
    s1 = 1.0 / math.sqrt(d_model)
    s2 = 1.0 / math.sqrt(d_ff)
    return {
        "router": dense_init(ks[0], d_model, E, dtype),
        "w1": jax.random.uniform(ks[1], (E, d_model, d_ff), dtype, -s1, s1),
        "w3": jax.random.uniform(ks[2], (E, d_model, d_ff), dtype, -s1, s1),
        "w2": jax.random.uniform(ks[3], (E, d_ff, d_model), dtype, -s2, s2),
    }


def _expert_matmul(eb: jnp.ndarray, w) -> jnp.ndarray:
    """(E,C,d) x (E,d,f) grouped matmul; w may be LAQ-quantized (W4A8 —
    the ITA device datapath applied per expert)."""
    from repro.core import quant

    if isinstance(w, quant.QuantizedLinear):
        E, C, d = eb.shape
        qx, xs = quant.quantize_activations_int8(eb.reshape(E * C, d))
        acc = jax.lax.dot_general(
            qx.reshape(E, C, d), w.codes,
            (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.int32)
        out = acc.astype(jnp.float32) * xs.reshape(E, C, 1) * w.scales[:, None, :]
        return out.astype(eb.dtype)
    return jnp.einsum("ecd,edf->ecf", eb, w.astype(eb.dtype))


def moe_apply(p: dict, x: jnp.ndarray, cfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, d) -> (out (B, T, d), aux_loss scalar).

    Router/gating (dynamic, data-dependent) is a *host* op under split-brain;
    the expert matmuls are static linear maps — the device side.  The aux
    loss is the standard load-balancing loss (Shazeer et al.).
    """
    B, T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    xt = x.reshape(-1, d)
    n = xt.shape[0]
    C = max(1, int(math.ceil(n * k / E * cfg.capacity_factor)))

    logits = (xt @ p["router"].astype(xt.dtype)).astype(jnp.float32)  # (n, E)
    probs = jax.nn.softmax(logits, -1)
    gate, ids = jax.lax.top_k(probs, k)                       # (n, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss
    me = probs.mean(0)                                        # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (n * k)
    aux = E * jnp.sum(me * ce)

    # --- sort-based dispatch ---
    flat_ids = ids.reshape(-1)                                # (S=n*k,)
    S = flat_ids.shape[0]
    order = jnp.argsort(flat_ids)                             # stable
    sorted_ids = flat_ids[order]
    tok = order // k                                          # source token per slot
    counts = jnp.zeros((E,), jnp.int32).at[flat_ids].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(S, dtype=jnp.int32) - offsets[sorted_ids]
    keep = rank < C
    dest = jnp.where(keep, sorted_ids * C + rank, E * C)      # overflow slot

    buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].set(xt[tok])
    eb = buf[:-1].reshape(E, C, d)

    h = _expert_matmul(eb, p["w1"])
    g = _expert_matmul(eb, p["w3"])
    y = _expert_matmul(jax.nn.silu(h) * g, p["w2"])

    y_slots = y.reshape(E * C, d)
    gathered = jnp.where(keep[:, None], y_slots[jnp.minimum(dest, E * C - 1)], 0.0)
    w_sorted = gate.reshape(-1)[order]
    out = jnp.zeros((n, d), x.dtype).at[tok].add(
        (gathered.astype(jnp.float32) * w_sorted[:, None]).astype(x.dtype))
    return out.reshape(B, T, d), aux
