"""RWKV6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
decay.  Split-brain mapping (DESIGN.md §7): all projections (r,k,v,g,o + the
decay LoRA + channel-mix matrices) are static linear maps -> ITA device; the
WKV recurrence carries dynamic state -> host.

Faithful-but-lean Finch block:
  time-mix: token-shift lerp with learned mixes; decay
      w_t = exp(-exp(w0 + lora_w(x_shift)))  (data-dependent, per channel)
  wkv: S_t = diag(w_t) S_{t-1} + k_t v_t^T ; out = r_t (S + diag(u) k v^T)
  group-norm over heads, silu(g) gate, output projection.
  channel-mix: squared-relu MLP with token shift.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import sharding as _shd
from repro.kernels import ops
from repro.models.layers import dense_init, linear, rmsnorm

HEAD_DIM = 64  # RWKV6 uses 64-wide heads
LORA_DIM = 64


def _pin(cfg: ModelConfig):
    """Serve-TP exactness hook (shd.pin_tp_exact): gathers model-sharded
    activations before contractions AND before the ln_x group norm, whose
    mean reduces over the sharded channel axis.  Identity unless
    cfg.parallel.exact_tp is set under an ambient mesh."""
    if not cfg.parallel.exact_tp:
        return lambda a: a
    return lambda a: _shd.pin_tp_exact(a, cfg)


def block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    H = d // HEAD_DIM
    return {
        "ln_tm": jnp.zeros((d,), dtype),
        "ln_cm": jnp.zeros((d,), dtype),
        "mix": jax.random.uniform(ks[0], (5, d), dtype, 0.0, 1.0),  # r,k,v,g,w mixes
        "wr": dense_init(ks[1], d, d, dtype),
        "wk": dense_init(ks[2], d, d, dtype),
        "wv": dense_init(ks[3], d, d, dtype),
        "wg": dense_init(ks[4], d, d, dtype),
        "wo": dense_init(ks[5], d, d, dtype),
        "w0": jax.random.uniform(ks[6], (d,), dtype, -8.0, -5.0),
        "w_lora_a": dense_init(ks[7], d, LORA_DIM, dtype),
        "w_lora_b": dense_init(ks[8], LORA_DIM, d, dtype) * 0.1,
        "u": jax.random.normal(ks[9], (H, HEAD_DIM), dtype) * 0.3,
        "ln_x": jnp.zeros((d,), dtype),
        "cm_k": dense_init(ks[10], d, cfg.d_ff, dtype),
        "cm_v": dense_init(ks[11], cfg.d_ff, d, dtype),
    }


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    assert cfg.d_model % HEAD_DIM == 0
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_blocks, cfg.num_layers)
    return {
        "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model)) * 0.02,
        "blocks": jax.vmap(lambda k: block_init(k, cfg))(keys),
        "ln_final": jnp.zeros((cfg.d_model,)),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab_size),
    }


def _token_shift(x: jnp.ndarray, x_prev: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """shifted[t] = x[t-1]; position 0 uses ``x_prev`` (decode carry) or 0."""
    if x_prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1]], axis=1)


def _time_mix(p, x, cfg, state=None, x_prev=None):
    B, T, d = x.shape
    H = d // HEAD_DIM
    xs = _token_shift(x, x_prev)
    mix = p["mix"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + mix[i] * (xs - x) for i in range(5))
    r = linear(xr, p["wr"]).reshape(B, T, H, HEAD_DIM).transpose(0, 2, 1, 3)
    k = linear(xk, p["wk"]).reshape(B, T, H, HEAD_DIM).transpose(0, 2, 1, 3)
    v = linear(xv, p["wv"]).reshape(B, T, H, HEAD_DIM).transpose(0, 2, 1, 3)
    pin = _pin(cfg)
    g = jax.nn.silu(linear(xg, p["wg"]))
    dw = linear(pin(jnp.tanh(linear(xw, p["w_lora_a"]))), p["w_lora_b"])
    w = jnp.exp(-jnp.exp((p["w0"].astype(jnp.float32) + dw.astype(jnp.float32))))
    w = w.reshape(B, T, H, HEAD_DIM).transpose(0, 2, 1, 3)
    if cfg.rwkv_chunk and T > 1:
        out, new_state = ops.rwkv6_chunked(
            r, k, v, w.astype(r.dtype), p["u"].astype(jnp.float32), state,
            chunk=cfg.rwkv_chunk)
    else:
        out, new_state = ops.rwkv6(r, k, v, w.astype(r.dtype),
                                   p["u"].astype(jnp.float32), state,
                                   use_pallas=cfg.use_pallas)
    out = pin(out.transpose(0, 2, 1, 3).reshape(B, T, d))
    out = rmsnorm(out, p["ln_x"], cfg.norm_eps) * g
    return linear(pin(out), p["wo"]), new_state, x[:, -1]


def _channel_mix(p, x, x_prev=None, pin_fn=None):
    xs = _token_shift(x, x_prev)
    mix = p["mix"].astype(x.dtype)
    xk = x + mix[1] * (xs - x)
    h = jnp.square(jax.nn.relu(linear(xk, p["cm_k"])))
    if pin_fn is not None:
        h = pin_fn(h)
    return linear(h, p["cm_v"]), x[:, -1]


def forward(params, tokens: jnp.ndarray, cfg: ModelConfig, **_):
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens].astype(dtype)

    def layer(x, p):
        if cfg.parallel.gather_fsdp_weights:
            from repro.distributed import sharding as _shd
            p = _shd.gather_fsdp(p, cfg)
            x = _shd.pin_batch(x, cfg)
        h, _, _ = _time_mix(p, rmsnorm(x, p["ln_tm"], cfg.norm_eps), cfg)
        x = x + h
        h, _ = _channel_mix(p, rmsnorm(x, p["ln_cm"], cfg.norm_eps),
                            pin_fn=_pin(cfg) if cfg.parallel.exact_tp else None)
        return x + h, jnp.zeros((), jnp.float32)

    if cfg.parallel.remat != "none":
        layer = jax.checkpoint(layer)
    x, _ = jax.lax.scan(layer, x, params["blocks"])
    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    logits = linear(x, params["lm_head"]).astype(jnp.float32)
    return logits, 0.0


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0, **_) -> Dict[str, Any]:
    """Recurrent state: O(1) in sequence length — this is why rwkv6 runs the
    long_500k cell that full-attention archs skip."""
    H = cfg.d_model // HEAD_DIM
    L = cfg.num_layers
    return {
        "wkv": jnp.zeros((L, batch, H, HEAD_DIM, HEAD_DIM), jnp.float32),
        "x_tm": jnp.zeros((L, batch, cfg.d_model), jnp.dtype(cfg.dtype)),
        "x_cm": jnp.zeros((L, batch, cfg.d_model), jnp.dtype(cfg.dtype)),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, cache, tokens: jnp.ndarray, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"][tokens][:, None, :].astype(dtype)

    def layer(x, inputs):
        p, wkv, x_tm, x_cm = inputs
        h, new_wkv, last_tm = _time_mix(
            p, rmsnorm(x, p["ln_tm"], cfg.norm_eps), cfg, state=wkv, x_prev=x_tm)
        x = x + h
        h, last_cm = _channel_mix(p, rmsnorm(x, p["ln_cm"], cfg.norm_eps),
                                  x_prev=x_cm,
                                  pin_fn=_pin(cfg) if cfg.parallel.exact_tp
                                  else None)
        return x + h, (new_wkv, last_tm, last_cm)

    x, (wkv, x_tm, x_cm) = jax.lax.scan(
        layer, x, (params["blocks"], cache["wkv"], cache["x_tm"], cache["x_cm"]))
    x = rmsnorm(x, params["ln_final"], cfg.norm_eps)
    logits = linear(x[:, 0], params["lm_head"]).astype(jnp.float32)
    return logits, {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm,
                    "len": cache["len"] + 1}
