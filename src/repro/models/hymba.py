"""Hymba (arXiv:2411.13676): hybrid-head LM — every layer runs attention
heads and Mamba-style SSM heads **in parallel** on the same input, then
fuses the two branches.

Faithful skeleton: GQA sliding-window attention branch + selective-scan SSM
branch, per-branch RMS normalization, averaged fusion, SwiGLU FFN.  (The
paper's meta-tokens and cross-layer KV sharing are omitted; noted in
DESIGN.md.)  The SSM branch gives O(1) decode state, which is what makes the
long_500k cell runnable: attention uses a bounded ring-buffer window while
the SSM carries unbounded context.

Split-brain: all projections (QKV/O, in/out/Δ/B/C, FFN) are static ->
device; selective-scan state update + attention over the window -> host.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.distributed import sharding as _shd
from repro.kernels import ops
from repro.models import layers as L


def _pin(cfg: ModelConfig):
    """Serve-TP exactness hook for down-projection inputs (no-op unless
    cfg.parallel.exact_tp and a mesh is ambient — see shd.pin_tp_exact)."""
    if not cfg.parallel.exact_tp:
        return None
    return lambda a: _shd.pin_tp_exact(a, cfg)


def block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> Dict[str, Any]:
    d = cfg.d_model
    ssm = cfg.ssm or SSMConfig()
    N, R = ssm.state_dim, ssm.dt_rank
    ks = jax.random.split(key, 10)
    hd = cfg.resolved_head_dim
    return {
        "ln_in": jnp.zeros((d,), dtype),
        "ln_mlp": jnp.zeros((d,), dtype),
        "ln_attn_out": jnp.zeros((d,), dtype),
        "ln_ssm_out": jnp.zeros((d,), dtype),
        "attn": L.attn_init(ks[0], d, cfg.num_heads, cfg.num_kv_heads, hd, dtype),
        "ssm": {
            "w_in": L.dense_init(ks[1], d, d, dtype),
            "w_delta": L.dense_init(ks[2], d, R, dtype),
            "w_delta_up": L.dense_init(ks[3], R, d, dtype),
            "A_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (d, 1))),
            "w_B": L.dense_init(ks[4], d, N, dtype),
            "w_C": L.dense_init(ks[5], d, N, dtype),
            "D": jnp.ones((d,), dtype),
            "w_out": L.dense_init(ks[6], d, d, dtype),
        },
        "mlp": {
            "w1": L.dense_init(ks[7], d, cfg.d_ff, dtype),
            "w3": L.dense_init(ks[8], d, cfg.d_ff, dtype),
            "w2": L.dense_init(ks[9], cfg.d_ff, d, dtype),
        },
    }


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    k_emb, k_blocks, k_head = jax.random.split(key, 3)
    keys = jax.random.split(k_blocks, cfg.num_layers)
    return {
        "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model)) * 0.02,
        "blocks": jax.vmap(lambda k: block_init(k, cfg))(keys),
        "ln_final": jnp.zeros((cfg.d_model,)),
        "lm_head": L.dense_init(k_head, cfg.d_model, cfg.vocab_size),
    }


def _ssm_branch(p, x, cfg: ModelConfig, state=None):
    """x: (B, T, d) -> (out, new_state (B, d, N))."""
    ssm_p = p["ssm"]
    pin = _pin(cfg) or (lambda a: a)
    h = jax.nn.silu(L.linear(x, ssm_p["w_in"]))
    delta = jax.nn.softplus(
        L.linear(pin(L.linear(x, ssm_p["w_delta"])), ssm_p["w_delta_up"])
    ).astype(jnp.float32)
    A = -jnp.exp(ssm_p["A_log"].astype(jnp.float32))
    Bm = L.linear(x, ssm_p["w_B"]).astype(jnp.float32)
    Cm = L.linear(x, ssm_p["w_C"]).astype(jnp.float32)
    y, new_state = ops.selective_scan(h, delta, A, Bm, Cm, state,
                                      use_pallas=cfg.use_pallas,
                                      algorithm=cfg.ssm_scan)
    y = y + h * ssm_p["D"].astype(h.dtype)
    return L.linear(pin(y), ssm_p["w_out"]), new_state


def _embed_decode(params, tokens: jnp.ndarray, cfg: ModelConfig):
    """Shared decode preamble: embed one token per row -> (B, 1, d)."""
    return params["embed"][tokens][:, None, :].astype(jnp.dtype(cfg.dtype))


def _fuse_tail(p, x, xn, o, sstate, cfg: ModelConfig):
    """Shared hybrid-head tail for both decode disciplines: attention-out
    projection, SSM branch, per-branch norms + averaged fusion, MLP.  ONE
    copy, so the dense and paged decode paths cannot drift apart on the
    fusion math their token-identity contract depends on.
    o: (B, Hq, 1, hd) -> (new x, new ssm state)."""
    B = x.shape[0]
    hd = cfg.resolved_head_dim
    pin = _pin(cfg) or (lambda a: a)
    attn_out = L.linear(
        pin(o.transpose(0, 2, 1, 3).reshape(B, 1, cfg.num_heads * hd)),
        p["attn"]["wo"])
    ssm_out, new_state = _ssm_branch(p, xn, cfg, state=sstate)
    fused = 0.5 * (L.rmsnorm(attn_out, p["ln_attn_out"], cfg.norm_eps)
                   + L.rmsnorm(ssm_out, p["ln_ssm_out"], cfg.norm_eps))
    x = x + fused
    y = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    x = x + L.swiglu(y, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"],
                     pin_fn=_pin(cfg))
    return x, new_state


def _logits_head(params, x: jnp.ndarray, cfg: ModelConfig):
    """Shared logits tail: final norm + LM head at the single position."""
    x = L.rmsnorm(x, params["ln_final"], cfg.norm_eps)
    return L.linear(x[:, 0], params["lm_head"]).astype(jnp.float32)


def forward(params, tokens: jnp.ndarray, cfg: ModelConfig, **_):
    dtype = jnp.dtype(cfg.dtype)
    B, T = tokens.shape
    x = params["embed"][tokens].astype(dtype)
    positions = jnp.arange(T)
    window = cfg.layer_pattern[0].window

    def layer(x, p):
        if cfg.parallel.gather_fsdp_weights:
            from repro.distributed import sharding as _shd
            p = _shd.gather_fsdp(p, cfg)
            x = _shd.pin_batch(x, cfg)
        xn = L.rmsnorm(x, p["ln_in"], cfg.norm_eps)
        attn_out = L.attn_apply(
            p["attn"], xn, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, positions=positions,
            rope_theta=cfg.rope_theta, window=window, use_pallas=cfg.use_pallas)
        ssm_out, _ = _ssm_branch(p, xn, cfg)
        fused = 0.5 * (L.rmsnorm(attn_out, p["ln_attn_out"], cfg.norm_eps)
                       + L.rmsnorm(ssm_out, p["ln_ssm_out"], cfg.norm_eps))
        x = x + fused
        y = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
        x = x + L.swiglu(y, p["mlp"]["w1"], p["mlp"]["w3"], p["mlp"]["w2"])
        return x, jnp.zeros((), jnp.float32)

    if cfg.parallel.remat != "none":
        layer = jax.checkpoint(layer)
    x, _ = jax.lax.scan(layer, x, params["blocks"])
    x = L.rmsnorm(x, params["ln_final"], cfg.norm_eps)
    logits = L.linear(x, params["lm_head"]).astype(jnp.float32)
    return logits, 0.0


def init_cache(cfg: ModelConfig, batch: int, max_len: int, **_) -> Dict[str, Any]:
    ssm = cfg.ssm or SSMConfig()
    hd = cfg.resolved_head_dim
    window = cfg.layer_pattern[0].window or max_len
    S = min(window, max_len)
    Lc = cfg.num_layers
    dtype = jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((Lc, batch, cfg.num_kv_heads, S, hd), dtype),
        "v": jnp.zeros((Lc, batch, cfg.num_kv_heads, S, hd), dtype),
        "ssm": jnp.zeros((Lc, batch, cfg.d_model, ssm.state_dim), jnp.float32),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def paged_decode_step(params, cache, table, tokens: jnp.ndarray,
                      cfg: ModelConfig, *, write=None, seq_axes=None):
    """Hymba decode straight through the page pool (DESIGN.md §6).

    Only engaged when the attention window covers the whole cache (global
    attention), i.e. the K/V leaves actually page: k/v arrive as
    kernel-friendly ``(L, num_pages, page_size, Hkv, hd)`` pool leaves swept
    by the layer scan, appended in place and attended gather-free; the SSM
    state — the O(1) recurrent half of the hybrid head — stays dense and is
    frozen (like ``len``) where ``write`` is False.
    """
    del seq_axes  # hymba pages k/v iff this entry point is reached at all
    B = tokens.shape[0]
    hd = cfg.resolved_head_dim
    if write is None:
        write = jnp.ones((B,), bool)
    x = _embed_decode(params, tokens, cfg)
    pos = cache["len"]
    positions = pos[:, None]
    window = cfg.layer_pattern[0].window

    def layer(x, inputs):
        p, kc, vc, sstate = inputs
        xn = L.rmsnorm(x, p["ln_in"], cfg.norm_eps)
        q, k, v = L.qkv_project(p["attn"], xn, cfg.num_heads,
                                cfg.num_kv_heads, hd)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        kc = L.paged_cache_write(kc, k, table, pos, write)
        vc = L.paged_cache_write(vc, v, table, pos, write)
        o = ops.paged_decode_attention(q, kc, vc, table, pos + 1,
                                       window=window,
                                       use_pallas=cfg.use_pallas,
                                       model_axis=cfg.parallel.model_axis,
                                       batch_axes=cfg.parallel.batch_axes)
        x, new_state = _fuse_tail(p, x, xn, o, sstate, cfg)
        new_state = jnp.where(write[:, None, None], new_state, sstate)
        return x, (kc, vc, new_state)

    x, (k, v, ssm) = jax.lax.scan(
        layer, x, (params["blocks"], cache["k"], cache["v"], cache["ssm"]))
    logits = _logits_head(params, x, cfg)
    return logits, {"k": k, "v": v, "ssm": ssm,
                    "len": cache["len"] + write.astype(jnp.int32)}


def decode_step(params, cache, tokens: jnp.ndarray, cfg: ModelConfig):
    hd = cfg.resolved_head_dim
    x = _embed_decode(params, tokens, cfg)
    pos = cache["len"]
    positions = pos[:, None]

    def layer(x, inputs):
        p, kc, vc, sstate = inputs
        xn = L.rmsnorm(x, p["ln_in"], cfg.norm_eps)
        q, k, v = L.qkv_project(p["attn"], xn, cfg.num_heads, cfg.num_kv_heads, hd)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        S = kc.shape[2]
        idx = pos % S  # ring buffer (window-bounded attention)
        kc = L.cache_write(kc, k[:, :, 0:1], idx,
                           cfg.parallel.aligned_decode)
        vc = L.cache_write(vc, v[:, :, 0:1], idx,
                           cfg.parallel.aligned_decode)
        eff_len = jnp.minimum(pos + 1, S)
        o = ops.decode_attention(q, kc, vc, eff_len)
        x, new_state = _fuse_tail(p, x, xn, o, sstate, cfg)
        return x, (kc, vc, new_state)

    x, (k, v, ssm) = jax.lax.scan(
        layer, x, (params["blocks"], cache["k"], cache["v"], cache["ssm"]))
    logits = _logits_head(params, x, cfg)
    return logits, {"k": k, "v": v, "ssm": ssm, "len": cache["len"] + 1}
