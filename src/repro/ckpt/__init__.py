"""repro.ckpt"""
