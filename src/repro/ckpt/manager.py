"""Fault-tolerant checkpointing (no orbax): atomic, sharded, elastic.

Guarantees:
  * **Atomicity** — writes go to ``<dir>/tmp.<step>`` and are renamed to
    ``<dir>/step_<step>`` only after an fsync'd manifest lands; a crash
    mid-write can never corrupt the latest restorable checkpoint.
  * **Keep-k** — older checkpoints are garbage-collected after a successful
    save, never before.
  * **Elastic restore** — arrays are saved logically-global (npz per pytree
    leaf path); on restore they are resharded to whatever mesh/sharding the
    new job uses, so a 512-chip run restores onto 256 chips (changed DP
    size) without conversion.
  * **Preemption hook** — ``CheckpointManager.save_on_signal`` installs a
    SIGTERM handler that flushes a final checkpoint (standard TPU-preemption
    grace-period pattern).
  * **Async** — saves can run on a background thread (device->host copy is
    synchronous, serialization isn't), overlapping I/O with the next steps.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_elem_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_elem_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Dict[str, Any],
             metadata: Optional[Dict[str, Any]] = None) -> str:
        host_tree = jax.tree.map(lambda a: np.asarray(a), tree)

        if self.async_save:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, metadata or {}))
            self._thread.start()
        else:
            self._write(step, host_tree, metadata or {})
        return os.path.join(self.directory, f"step_{step}")

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, metadata: Dict[str, Any]) -> None:
        final = os.path.join(self.directory, f"step_{step}")
        tmp = os.path.join(self.directory, f"tmp.{step}.{os.getpid()}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        arrays = dict(_flatten_with_paths(host_tree))
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{k: v for k, v in arrays.items()})
        manifest = {
            "step": step,
            "keys": sorted(arrays.keys()),
            "metadata": metadata,
        }
        mpath = os.path.join(tmp, "manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Dict[str, Any], step: Optional[int] = None,
                shardings=None) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """Restore into the structure of ``like``; optionally device_put with
        per-leaf ``shardings`` (elastic re-shard onto the current mesh)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        d = os.path.join(self.directory, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        paths = [k for k, _ in _flatten_with_paths(like)]
        leaves = []
        for key in paths:
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            leaves.append(data[key])
        tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
        if shardings is not None:
            tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
        return tree, manifest["metadata"]

    # ------------------------------------------------------------ preemption
    def save_on_signal(self, get_state: Callable[[], Tuple[int, Dict[str, Any]]],
                       sig=signal.SIGTERM) -> None:
        """Install a preemption handler: on SIGTERM, write a final checkpoint
        synchronously before the process dies (TPU maintenance-event flow)."""

        def handler(signum, frame):
            step, tree = get_state()
            self.async_save = False
            self.save(step, tree, metadata={"preempted": True})
            raise SystemExit(143)

        signal.signal(sig, handler)
