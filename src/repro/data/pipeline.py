"""Deterministic, shard-aware synthetic data pipeline.

Production shape without production data: batches are generated from a
counter-based PRNG keyed by (seed, step, shard), so

  * every restart resumes exactly (step index is the only state),
  * every data-parallel shard draws a disjoint, reproducible stream,
  * elastic re-sharding (change in DP size) re-partitions the same global
    stream — batch `step` is identical regardless of how many hosts read it.

The synthetic stream is a mixture of Zipf-distributed tokens and copy runs
(so models have learnable structure and loss decreases during the e2e
example runs, rather than staying at uniform entropy).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    copy_prob: float = 0.3      # fraction of positions inside copy runs
    frontend_tokens: int = 0
    d_model: int = 0


def _batch_rng(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))


def global_batch_at_step(cfg: DataConfig, step: int,
                         shard: int = 0, num_shards: int = 1) -> Dict[str, np.ndarray]:
    """The (deterministic) shard-local slice of global batch ``step``."""
    assert cfg.global_batch % num_shards == 0
    per = cfg.global_batch // num_shards
    rng = _batch_rng(cfg, step, 0)  # one global stream...
    toks = rng.zipf(cfg.zipf_a, size=(cfg.global_batch, cfg.seq_len + 1))
    toks = np.minimum(toks, cfg.vocab_size - 1).astype(np.int32)
    # inject copy runs: second half of each row repeats the first half with p
    half = (cfg.seq_len + 1) // 2
    copy_mask = rng.random((cfg.global_batch, half)) < cfg.copy_prob
    toks[:, half:half * 2][copy_mask] = toks[:, :half][copy_mask]
    sl = slice(shard * per, (shard + 1) * per)  # ...sliced per shard
    out = {
        "tokens": toks[sl, :-1],
        "labels": toks[sl, 1:],
    }
    if cfg.frontend_tokens:
        out["frontend"] = rng.standard_normal(
            (cfg.global_batch, cfg.frontend_tokens, cfg.d_model)
        ).astype(np.float32)[sl]
    return out


class DataLoader:
    """Stateful iterator facade; state == step index (checkpointable)."""

    def __init__(self, cfg: DataConfig, start_step: int = 0,
                 shard: int = 0, num_shards: int = 1):
        self.cfg = cfg
        self.step = start_step
        self.shard = shard
        self.num_shards = num_shards

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = global_batch_at_step(self.cfg, self.step, self.shard, self.num_shards)
        self.step += 1
        return b

    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    def load_state_dict(self, s: Dict[str, int]) -> None:
        self.step = int(s["step"])
