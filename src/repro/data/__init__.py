"""repro.data"""
