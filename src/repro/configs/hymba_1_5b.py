"""Architecture config: hymba-1.5b.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import LayerSpec, ModelConfig, SSMConfig
from repro.configs.common import PAR_SMALL

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hymba",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5, head_dim=64,
    d_ff=5504, vocab_size=32001, ssm=SSMConfig(state_dim=16, dt_rank=64),
    layer_pattern=(LayerSpec(window=1024),),   # SWA; SSM heads carry global ctx
    supports_long_context=True,
    parallel=PAR_SMALL, source="arXiv:2411.13676")
