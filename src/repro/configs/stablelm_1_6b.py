"""Architecture config: stablelm-1.6b.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import ModelConfig
from repro.configs.common import PAR_SMALL

CONFIG = ModelConfig(
    name="stablelm-1.6b", family="lm",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=5632, vocab_size=100352,
    parallel=PAR_SMALL, source="hf:stabilityai/stablelm-2-1_6b")
