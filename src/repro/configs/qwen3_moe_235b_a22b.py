"""Architecture config: qwen3-moe-235b-a22b.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import ModelConfig, MoEConfig
from repro.configs.common import PAR_BIG

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="lm",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=64,
    d_ff=1536, vocab_size=151936, moe=MoEConfig(num_experts=128, top_k=8),
    parallel=PAR_BIG, source="hf:Qwen/Qwen3-30B-A3B (scaled per assignment)")
