"""Arch configs (one module per assigned architecture) + registry."""
from repro.configs.base import *  # noqa: F401,F403
from repro.configs.registry import ASSIGNED, CONFIGS, get_config  # noqa: F401
