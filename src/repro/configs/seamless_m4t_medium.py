"""Architecture config: seamless-m4t-medium.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import ModelConfig
from repro.configs.common import PAR_BIG

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, num_encoder_layers=12, d_model=1024, num_heads=16,
    num_kv_heads=16, head_dim=64, d_ff=4096, vocab_size=256206,
    frontend_tokens=960,  # precomputed audio-frame embeddings (stub frontend)
    parallel=PAR_BIG, source="arXiv:2308.11596")
