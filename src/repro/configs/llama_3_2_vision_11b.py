"""Architecture config: llama-3.2-vision-11b.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import ModelConfig
from repro.configs.common import PAR_BIG

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="lm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, cross_attn_every=5,
    frontend_tokens=1600,  # precomputed patch embeddings (stub frontend)
    parallel=PAR_BIG, source="hf:meta-llama/Llama-3.2-11B-Vision")
