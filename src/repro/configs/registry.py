"""Config registry: aggregates the per-arch modules.

One module per assigned architecture (assignment requirement), plus the
paper's own two models (TinyLlama-1.1B / Llama-2-7B, Table IV).
"""
from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs import phi3_5_moe_42b_a6_6b as _phi3_5_moe_42b_a6_6b
from repro.configs import qwen3_moe_235b_a22b as _qwen3_moe_235b_a22b
from repro.configs import stablelm_1_6b as _stablelm_1_6b
from repro.configs import minitron_8b as _minitron_8b
from repro.configs import gemma2_27b as _gemma2_27b
from repro.configs import granite_8b as _granite_8b
from repro.configs import seamless_m4t_medium as _seamless_m4t_medium
from repro.configs import hymba_1_5b as _hymba_1_5b
from repro.configs import rwkv6_7b as _rwkv6_7b
from repro.configs import llama_3_2_vision_11b as _llama_3_2_vision_11b
from repro.configs import tinyllama_1_1b as _tinyllama_1_1b
from repro.configs import llama2_7b as _llama2_7b

CONFIGS: Dict[str, ModelConfig] = {
    "phi3.5-moe-42b-a6.6b": _phi3_5_moe_42b_a6_6b.CONFIG,
    "qwen3-moe-235b-a22b": _qwen3_moe_235b_a22b.CONFIG,
    "stablelm-1.6b": _stablelm_1_6b.CONFIG,
    "minitron-8b": _minitron_8b.CONFIG,
    "gemma2-27b": _gemma2_27b.CONFIG,
    "granite-8b": _granite_8b.CONFIG,
    "seamless-m4t-medium": _seamless_m4t_medium.CONFIG,
    "hymba-1.5b": _hymba_1_5b.CONFIG,
    "rwkv6-7b": _rwkv6_7b.CONFIG,
    "llama-3.2-vision-11b": _llama_3_2_vision_11b.CONFIG,
    "tinyllama-1.1b": _tinyllama_1_1b.CONFIG,
    "llama2-7b": _llama2_7b.CONFIG,
}

ASSIGNED = [
    "phi3.5-moe-42b-a6.6b",
    "qwen3-moe-235b-a22b",
    "stablelm-1.6b",
    "minitron-8b",
    "gemma2-27b",
    "granite-8b",
    "seamless-m4t-medium",
    "hymba-1.5b",
    "rwkv6-7b",
    "llama-3.2-vision-11b",
]


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(CONFIGS)}")
    return CONFIGS[name]
