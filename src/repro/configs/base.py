"""Config schema: model architecture + parallelism + ITA feature flags.

Every assigned architecture is a ``ModelConfig`` instance in its own module
(``src/repro/configs/<arch>.py``) built from the exact figures in the
assignment; ``reduced()`` derives the CPU smoke-test version.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

# One layer-pattern entry: attention window (None = global) — the pattern
# repeats over the depth, so gemma2's local/global alternation is
# ("local", "global") with a 4096 window on the local slots.


@dataclass(frozen=True)
class LayerSpec:
    window: Optional[int] = None   # sliding-window size; None = full attention


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16
    conv_dim: int = 4              # depthwise conv width (not used on decode fast path)
    dt_rank: int = 64


@dataclass(frozen=True)
class ITAConfig:
    """The paper's technique as a first-class feature."""
    quantize_weights: bool = False    # LAQ W4A8 device projections
    split_brain: bool = False         # partition serve_step into device/host phases
    prune_threshold: float = 2.0 ** -6
    laq_slack: float = 0.35
    logic_aware: bool = True


@dataclass(frozen=True)
class ParallelConfig:
    # logical -> mesh-axis mapping; None = replicated on that logical axis
    batch_axes: Tuple[str, ...] = ("pod", "data")
    model_axis: str = "model"
    fsdp_axis: Optional[str] = None   # shard weights over this too (ZeRO-3)
    seq_axis: Optional[str] = None    # KV-cache sequence sharding for decode
    remat: str = "full"               # "none" | "full" | "dots"
    scan_layers: bool = True
    grad_compression: bool = False    # int8 all-reduce (shard_map)
    pipeline_stages: int = 1
    decode_attn: str = "xla"          # "shard_map" = LSE-combined flash decode (Perf H2)
    aligned_decode: bool = True       # lockstep decode -> scalar-index cache writes (Perf H2)
    gather_fsdp_weights: bool = False # ZeRO-3 per-layer weight gather (Perf H4)
    exact_tp: bool = False            # serve TP: all-gather before down-projections
                                      # so no float contraction is ever split
                                      # (greedy token identity, DESIGN.md §11)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # "lm" | "rwkv" | "hymba" | "encdec"
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None     # default d_model // num_heads
    layer_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    softcap: Optional[float] = None            # gemma2 logit softcap
    final_softcap: Optional[float] = None      # gemma2 final-logit softcap
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    # cross-attention (VLM / enc-dec)
    cross_attn_every: Optional[int] = None     # insert a cross block each N layers
    num_encoder_layers: int = 0                # enc-dec only
    frontend_tokens: int = 0                   # stub modality tokens (audio/vision)
    # numerics / execution
    rwkv_chunk: int = 0                # >0: chunked matmul-form WKV (Perf H1)
    ssm_scan: str = "sequential"       # "associative" = log-depth scan (Perf H5)
    dtype: str = "bfloat16"
    use_pallas: bool = False
    ita: ITAConfig = field(default_factory=ITAConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    # notes for DESIGN/EXPERIMENTS (e.g. long_500k applicability)
    supports_long_context: bool = False
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.resolved_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim
        attn = d * hd * self.num_heads + 2 * d * self.kv_dim + hd * self.num_heads * d
        if self.family == "rwkv":
            attn = 4 * d * d + d * d  # r,k,v,g,o (decay via small lora)
        if self.moe:
            ffn = 3 * d * ff * self.moe.num_experts + d * self.moe.num_experts
        else:
            ffn = 3 * d * ff
        if self.family == "hymba":
            ssm = self.ssm or SSMConfig()
            attn += 2 * d * (2 * ssm.state_dim) + d * ssm.dt_rank + ssm.dt_rank * d
        emb = V * d * (1 if self.tie_embeddings else 2)
        cross = 0
        if self.cross_attn_every:
            n_cross = L // self.cross_attn_every
            cross = n_cross * (2 * d * hd * self.num_heads + 2 * d * self.kv_dim)
        enc = self.num_encoder_layers * (attn + (3 * d * ff)) if self.num_encoder_layers else 0
        return L * (attn + ffn) + emb + cross + enc

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only top-k experts count)."""
        if not self.moe:
            return self.param_count()
        dense_like = replace(self, moe=None)
        base = dense_like.param_count() - 3 * self.d_model * self.d_ff * self.num_layers
        active_ffn = 3 * self.d_model * self.d_ff * self.moe.top_k * self.num_layers
        return base + active_ffn

    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            num_layers=max(2, len(self.layer_pattern)),
            d_model=64,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            frontend_tokens=min(self.frontend_tokens, 8),
            num_encoder_layers=2 if self.num_encoder_layers else 0,
        )
        if self.moe:
            small["moe"] = MoEConfig(num_experts=4, top_k=2)
        if self.ssm:
            small["ssm"] = SSMConfig(state_dim=8, dt_rank=8)
        if self.cross_attn_every:
            small["cross_attn_every"] = 2
            small["num_layers"] = 4
        if self.layer_pattern and len(self.layer_pattern) > 1:
            small["layer_pattern"] = tuple(
                LayerSpec(window=16 if s.window else None) for s in self.layer_pattern)
        elif self.layer_pattern[0].window:
            small["layer_pattern"] = (LayerSpec(window=16),)
        small.update(overrides)
        return replace(self, name=self.name + "-smoke", **small)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
