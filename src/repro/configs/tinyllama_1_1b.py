"""Architecture config: tinyllama-1.1b.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import ITAConfig, ModelConfig
from repro.configs.common import PAR_SMALL

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="lm",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=64,
    d_ff=5632, vocab_size=32000,
    ita=ITAConfig(quantize_weights=True, split_brain=True),
    parallel=PAR_SMALL, source="hf:TinyLlama/TinyLlama-1.1B (paper Table IV)")
