"""Architecture config: rwkv6-7b.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import ModelConfig
from repro.configs.common import PAR_BIG

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64, head_dim=64,
    d_ff=14336, vocab_size=65536, supports_long_context=True,
    parallel=PAR_BIG, source="arXiv:2404.05892")
