"""Shared parallelism presets for the arch configs."""
from repro.configs.base import ParallelConfig

PAR_BIG = ParallelConfig(batch_axes=("pod", "data"), model_axis="model",
                         fsdp_axis="data", seq_axis="model", remat="full")
PAR_SMALL = ParallelConfig(batch_axes=("pod", "data"), model_axis="model",
                           fsdp_axis=None, seq_axis="model", remat="full")
