"""Architecture config: phi3.5-moe-42b-a6.6b.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import ModelConfig, MoEConfig
from repro.configs.common import PAR_BIG

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="lm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064, moe=MoEConfig(num_experts=16, top_k=2),
    parallel=PAR_BIG, source="hf:microsoft/Phi-3.5-MoE-instruct")
