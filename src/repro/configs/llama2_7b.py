"""Architecture config: llama2-7b.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import ITAConfig, ModelConfig
from repro.configs.common import PAR_BIG

CONFIG = ModelConfig(
    name="llama2-7b", family="lm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=32000,
    ita=ITAConfig(quantize_weights=True, split_brain=True),
    parallel=PAR_BIG, source="arXiv:2307.09288 (paper §V-C)")
