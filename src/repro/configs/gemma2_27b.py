"""Architecture config: gemma2-27b.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import LayerSpec, ModelConfig
from repro.configs.common import PAR_BIG

CONFIG = ModelConfig(
    name="gemma2-27b", family="lm",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000, tie_embeddings=True,
    layer_pattern=(LayerSpec(window=4096), LayerSpec(window=None)),
    softcap=50.0, final_softcap=30.0,
    parallel=PAR_BIG, source="arXiv:2408.00118")
