"""Architecture config: minitron-8b.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import ModelConfig
from repro.configs.common import PAR_BIG

CONFIG = ModelConfig(
    name="minitron-8b", family="lm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=16384, vocab_size=256000,
    parallel=PAR_BIG, source="arXiv:2407.14679")
