"""Architecture config: granite-8b.

Exact figures from the assignment; see ``source=`` for provenance.
"""
from repro.configs.base import ModelConfig
from repro.configs.common import PAR_BIG

CONFIG = ModelConfig(
    name="granite-8b", family="lm",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=49152,
    parallel=PAR_BIG, source="arXiv:2405.04324")
