"""Jitted, mesh-sharded train and serve steps.

``make_train_step``/``make_serve_step`` bind a ModelConfig + mesh into a
``jax.jit`` with explicit in/out shardings from the rules engine — these are
the exact callables the multi-pod dry-run lowers and compiles.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed import sharding as shd
from repro.models import api
from repro.train import optimizer as opt


def make_train_step(cfg: ModelConfig, optcfg: opt.AdamWConfig, mesh: Mesh,
                    params_like, opt_like, donate: bool = True):
    p_specs = shd.param_pspecs(params_like, cfg, mesh)
    o_specs = {
        "step": P(),
        "m": shd.param_pspecs(opt_like["m"], cfg, mesh),
        "v": shd.param_pspecs(opt_like["v"], cfg, mesh),
    }
    b_specs = shd.batch_pspecs(cfg, mesh, "train")

    def train_step(params, opt_state, batch):
        (tot, metrics), grads = jax.value_and_grad(
            api.loss_fn, has_aux=True)(params, batch, cfg)
        params, opt_state, om = opt.apply_updates(params, grads, opt_state, optcfg)
        metrics = dict(metrics, **om, total=tot)
        return params, opt_state, metrics

    in_sh = (shd.with_sharding(mesh, p_specs), shd.with_sharding(mesh, o_specs),
             {k: NamedSharding(mesh, v) for k, v in b_specs.items()})
    out_sh = (shd.with_sharding(mesh, p_specs), shd.with_sharding(mesh, o_specs),
              None)
    return jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                   donate_argnums=(0, 1) if donate else ())


def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    b_specs = shd.batch_pspecs(cfg, mesh, "prefill")

    def prefill_step(params, batch):
        logits, _ = api.forward(params, batch["tokens"], cfg,
                                frontend=batch.get("frontend"))
        return logits

    def wrap(params_like):
        p_specs = shd.param_pspecs(params_like, cfg, mesh)
        return jax.jit(
            prefill_step,
            in_shardings=(shd.with_sharding(mesh, p_specs),
                          {k: NamedSharding(mesh, v) for k, v in b_specs.items()}),
            out_shardings=NamedSharding(mesh, shd.logits_pspec(cfg, mesh, "prefill")))
    return wrap


def make_bucketed_prefill(cfg: ModelConfig, mesh: Mesh, params_like,
                          cache_like, donate: bool = True,
                          cache_spec_fn=shd.cache_pspecs,
                          param_spec_fn=shd.param_pspecs):
    """Bucketed prompt->KV-cache fill: tokens are right-padded to a
    power-of-two width and ``true_len`` (a traced scalar) marks the real
    prompt length, so ONE compiled program serves every prompt length that
    rounds up to the same bucket (api.prefill_bucketed).

    ``cache_spec_fn`` picks the cache partitioning rules: the default train
    rules, or ``shd.serve_cache_pspecs`` for the TP serving mesh (head-cut
    KV, DESIGN.md §11).  ``param_spec_fn`` likewise: float serving engines
    pass ``shd.serve_param_pspecs`` (column-only TP, exact greedy tokens)."""
    p_specs = param_spec_fn(params_like, cfg, mesh)
    c_specs = cache_spec_fn(cache_like, cfg, mesh)
    b = shd.MeshAxes(mesh, cfg).resolve("batch")

    def prefill_step(params, cache, tokens, true_len):
        return api.prefill_bucketed(params, cache, tokens, true_len, cfg)

    return jax.jit(
        prefill_step,
        in_shardings=(shd.with_sharding(mesh, p_specs),
                      shd.with_sharding(mesh, c_specs),
                      NamedSharding(mesh, P(b, None)),
                      None),
        out_shardings=(NamedSharding(mesh, shd.logits_pspec(cfg, mesh, "decode")),
                       shd.with_sharding(mesh, c_specs)),
        donate_argnums=(1,) if donate else ())


def make_decode_loop(cfg: ModelConfig, mesh: Mesh, params_like, cache_like,
                     steps: int, eos_id: Optional[int] = None,
                     donate: bool = True, param_spec_fn=shd.param_pspecs,
                     cache_spec_fn=shd.cache_pspecs):
    """``steps`` greedy decode iterations fused into ONE dispatch.

    The whole multi-token loop is a jitted ``lax.scan`` over decode_step —
    one program launch per generation instead of one per token.
    Returns (tokens (B, steps), last_token (B,), cache, gen_len (B,)).

    With ``eos_id`` set, a request that emits the stop token stops counting:
    its later outputs are padded with ``eos_id`` (and fed back as such, so
    the trajectory is deterministic) while ``gen_len`` freezes at the number
    of tokens actually generated, EOS inclusive.  The cache keeps advancing
    in lockstep — harmless garbage for a finished stream — which keeps the
    scan body identical for all batch members.  Without ``eos_id``,
    gen_len == steps and the tokens match the pre-EOS behaviour exactly.
    """
    p_specs = param_spec_fn(params_like, cfg, mesh)
    c_specs = cache_spec_fn(cache_like, cfg, mesh)
    b = shd.MeshAxes(mesh, cfg).resolve("batch")

    def decode_loop(params, cache, tokens):
        B = tokens.shape[0]

        def body(carry, _):
            cache, tok, alive, n = carry
            logits, cache = api.decode_step(params, cache, tok, cfg)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            n = n + alive.astype(jnp.int32)
            if eos_id is None:
                emitted = nxt
            else:
                emitted = jnp.where(alive, nxt, jnp.int32(eos_id))
                alive = alive & (emitted != eos_id)
            return (cache, emitted, alive, n), emitted

        init = (cache, tokens, jnp.ones((B,), bool), jnp.zeros((B,), jnp.int32))
        (cache, tok, _, gen_len), ys = jax.lax.scan(body, init, None,
                                                    length=steps)
        return jnp.swapaxes(ys, 0, 1), tok, cache, gen_len

    return jax.jit(
        decode_loop,
        in_shardings=(shd.with_sharding(mesh, p_specs),
                      shd.with_sharding(mesh, c_specs),
                      NamedSharding(mesh, P(b))),
        out_shardings=(NamedSharding(mesh, P(b, None)),
                       NamedSharding(mesh, P(b)),
                       shd.with_sharding(mesh, c_specs),
                       NamedSharding(mesh, P(b))),
        donate_argnums=(1,) if donate else ())


def make_slot_step(cfg: ModelConfig, mesh: Mesh, params_like, cache_like,
                   axes, donate: bool = True,
                   cache_spec_fn=shd.cache_pspecs,
                   param_spec_fn=shd.param_pspecs):
    """Masked batched decode step for continuous batching.

    One greedy token for EVERY slot of the fixed-size slot cache, but only
    slots where ``active`` is True advance: inactive slots' cache leaves
    (K/V, recurrent state, ``len``) are frozen via a per-leaf select along
    that leaf's own batch axis (serve/slots.py).  Shapes are fixed at
    (max_slots, ...), so the steady-state serve loop re-dispatches this ONE
    compiled program forever — zero recompiles.

    Besides the tokens, the step returns a per-slot finite-logits sentinel
    (``ok``): False flags a slot whose logits went non-finite this step, so
    the scheduler can quarantine it instead of appending garbage.  The
    ``corrupt`` input is the fault-injection hook — slots where it is True
    get their logits NaN-poisoned *inside* the jitted step (all-False in
    the steady state; fixed shape, so still zero recompiles).

    ``cfg`` must have ``parallel.aligned_decode=False``: slots sit at ragged
    positions, so the lockstep scalar-index cache write is wrong here.
    """
    assert not cfg.parallel.aligned_decode, \
        "slot decode needs ragged cache writes (aligned_decode=False)"
    from repro.serve import slots as slots_mod
    p_specs = param_spec_fn(params_like, cfg, mesh)
    c_specs = cache_spec_fn(cache_like, cfg, mesh)
    b = shd.MeshAxes(mesh, cfg).resolve("batch")

    def slot_step(params, cache, tokens, active, corrupt):
        logits, new_cache = api.decode_step(params, cache, tokens, cfg)
        logits = slots_mod.corrupt_logits(logits, corrupt)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        ok = slots_mod.finite_logits(logits)
        new_cache = slots_mod.select_slots(active, new_cache, cache, axes)
        return next_tok, ok, new_cache

    return jax.jit(
        slot_step,
        in_shardings=(shd.with_sharding(mesh, p_specs),
                      shd.with_sharding(mesh, c_specs),
                      NamedSharding(mesh, P(b)),
                      NamedSharding(mesh, P(b)),
                      NamedSharding(mesh, P(b))),
        out_shardings=(NamedSharding(mesh, P(b)),
                       NamedSharding(mesh, P(b)),
                       shd.with_sharding(mesh, c_specs)),
        donate_argnums=(1,) if donate else ())


def make_serve_step(cfg: ModelConfig, mesh: Mesh, params_like, cache_like,
                    donate: bool = True, param_spec_fn=shd.param_pspecs,
                    cache_spec_fn=shd.cache_pspecs):
    """One decode step (the paper's per-token loop) with sharded KV cache."""
    p_specs = param_spec_fn(params_like, cfg, mesh)
    c_specs = cache_spec_fn(cache_like, cfg, mesh)
    b = shd.MeshAxes(mesh, cfg).resolve("batch")

    def serve_step(params, cache, tokens):
        logits, cache = api.decode_step(params, cache, tokens, cfg)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, cache

    return jax.jit(
        serve_step,
        in_shardings=(shd.with_sharding(mesh, p_specs),
                      shd.with_sharding(mesh, c_specs),
                      NamedSharding(mesh, P(b))),
        out_shardings=(NamedSharding(mesh, P(b)),
                       NamedSharding(mesh, shd.logits_pspec(cfg, mesh, "decode")),
                       shd.with_sharding(mesh, c_specs)),
        donate_argnums=(1,) if donate else ())
