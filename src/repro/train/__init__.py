"""repro.train"""
