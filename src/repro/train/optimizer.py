"""Optimizers in pure JAX: AdamW with optional INT8-quantized moments.

The 8-bit moment storage is a distributed-optimization feature in the spirit
of the paper's quantization philosophy: the (m, v) state of a 235B-param MoE
drops from 8 bytes/param to ~2 bytes/param, which is what lets the qwen3
train_4k cell fit the 16 GB/chip HBM budget at 256 chips (EXPERIMENTS.md).

State layout is a pytree mirroring params, so the sharding rules engine
shards it exactly like the weights (fully sharded, ZeRO style).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    quantize_moments: bool = False   # int8 blockwise moment storage
    moment_block: int = 256


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_frac."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


# --- int8 blockwise moment codec -------------------------------------------
def _q8(x: jnp.ndarray, block: int):
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jnp.ndarray, scale: jnp.ndarray, shape, size: int):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


class _QMoment(NamedTuple):
    q: jnp.ndarray
    scale: jnp.ndarray


def init_state(params, cfg: AdamWConfig) -> Dict[str, Any]:
    def zeros_like_moment(p):
        z = jnp.zeros(p.shape, jnp.float32)
        if cfg.quantize_moments:
            q, s = _q8(z, cfg.moment_block)
            return _QMoment(q, s)
        return z

    float_params = jax.tree.map(lambda p: p, params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_moment, float_params),
        "v": jax.tree.map(zeros_like_moment, float_params),
    }


def _global_norm(grads) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"]
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        if cfg.quantize_moments:
            m_f = _dq8(m.q, m.scale, p.shape, p.size)
            v_f = _dq8(v.q, v.scale, p.shape, p.size)
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        mh = m_f / bc1
        vh = v_f / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        decay = cfg.weight_decay * (p.ndim >= 2)  # no decay on norms/biases
        new_p = (p.astype(jnp.float32) * (1 - lr * decay) - lr * delta).astype(p.dtype)
        if cfg.quantize_moments:
            m_out = _QMoment(*_q8(m_f, cfg.moment_block))
            v_out = _QMoment(*_q8(v_f, cfg.moment_block))
        else:
            m_out, v_out = m_f, v_f
        return new_p, m_out, v_out

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_state = {"step": step + 1, "m": new_m, "v": new_v}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
