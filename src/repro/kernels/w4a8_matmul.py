"""Pallas TPU kernel: fused INT4-dequant matmul — the ITA MAC, TPU-native.

Paper §IV-C hardwires INT4 weights into shift-add trees so no weight ever
crosses a memory hierarchy.  The TPU analogue (DESIGN.md §2): keep weights as
INT4 codes in HBM (4x less traffic than bf16), stream each (bk, bn) tile into
VMEM **once**, dequantize in-register, and feed the MXU directly.  The
activation side is INT8 with per-row scales, matching the paper's W4A8
datapath; accumulation is exact int32 on the integer path.

Grid: (M/bm, N/bn, K/bk), K innermost so the fp32 scratch accumulator in
VMEM is revisited; the output tile is written once on the final K step.
Block shapes are MXU-aligned (multiples of 128 on the contracting/lane dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = 256, 256, 512

# renamed TPUCompilerParams -> CompilerParams across pallas releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(x_ref, xs_ref, w_ref, ws_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; accumulate over the K grid dimension."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]          # (bm, bk) int8
    w = w_ref[...]          # (bk, bn) int8 (int4 codes)
    # int8 x int4 -> int32 exact on the MXU
    acc_ref[...] += jax.lax.dot_general(
        x.astype(jnp.int32), w.astype(jnp.int32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32,
    ).astype(jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _done():
        xs = xs_ref[...]    # (bm, 1) f32 activation scales
        ws = ws_ref[...]    # (1, bn) f32 weight scales
        o_ref[...] = (acc_ref[...] * xs * ws).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"),
)
def w4a8_matmul(qx: jnp.ndarray, x_scale: jnp.ndarray, codes: jnp.ndarray,
                w_scale: jnp.ndarray, *, bm: int = DEFAULT_BM,
                bn: int = DEFAULT_BN, bk: int = DEFAULT_BK,
                out_dtype=jnp.bfloat16, interpret: bool = True) -> jnp.ndarray:
    """qx (M,K) int8, x_scale (M,1) f32, codes (K,N) int8, w_scale (N,) f32."""
    M, K = qx.shape
    _, N = codes.shape
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    ws2d = w_scale.reshape(1, N)

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qx, x_scale, codes, ws2d)
