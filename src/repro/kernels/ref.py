"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against, and they double
as the CPU/dry-run execution path of the framework (``use_pallas=False``).
The chunked attention reference is written with ``lax.scan`` so that lowering
never materializes a (T x T) score matrix — required for the 32k-prefill
dry-run cells.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ----------------------------------------------------------------------------
# W4A8 matmul (the ITA MAC datapath)
# ----------------------------------------------------------------------------
def w4a8_matmul(qx: jnp.ndarray, x_scale: jnp.ndarray, codes: jnp.ndarray,
                w_scale: jnp.ndarray, out_dtype=jnp.bfloat16) -> jnp.ndarray:
    """int8 activations (M,K) x int4 codes (K,N) -> scaled (M,N).

    Bit-exact int32 accumulation, then rescale by per-row activation scale
    and per-column weight scale.
    """
    # int8 operands go STRAIGHT into the dot (preferred_element_type=int32):
    # the MXU widens in the datapath, so the weights stream at 1 byte/param.
    # (Casting operands to int32 first would materialize 4-byte weights —
    # measured 4x worse than bf16 on the decode cells; §Perf H3 log.)
    acc = jax.lax.dot_general(
        qx, codes, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    return (acc.astype(jnp.float32) * x_scale * w_scale).astype(out_dtype)


# ----------------------------------------------------------------------------
# Attention
# ----------------------------------------------------------------------------
def _soft_cap(logits: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def mha(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
        causal: bool = True, window: Optional[int] = None,
        softcap: Optional[float] = None, scale: Optional[float] = None,
        kv_offset: int = 0) -> jnp.ndarray:
    """Naive full-materialization attention. Oracle only — O(Tq*Tk) memory.

    q: (B, Hq, Tq, D); k, v: (B, Hkv, Tk, D) with Hq % Hkv == 0 (GQA).
    ``kv_offset`` is the absolute position of q[0] minus that of k[0]
    (used for decode, where Tq=1 sits at the end of the KV cache).
    """
    B, Hq, Tq, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = (scale if scale is not None else D ** -0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)) * s
    logits = _soft_cap(logits, softcap)
    qpos = jnp.arange(Tq)[:, None] + kv_offset
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((Tq, k.shape[2]), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def mha_chunked(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                causal: bool = True, window: Optional[int] = None,
                softcap: Optional[float] = None, scale: Optional[float] = None,
                kv_offset: int = 0, q_chunk: int = 512, kv_chunk: int = 1024,
                skip_masked_blocks: bool = True) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure jnp (lax.scan x2).

    Memory is O(q_chunk * kv_chunk); this is the lowering-safe path for 32k
    sequences.  With ``skip_masked_blocks`` (and causal masking), fully
    masked KV blocks are skipped via ``lax.cond`` so the compiled FLOPs count
    ~T^2/2 instead of T^2 — one of the §Perf optimizations.
    """
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    s = (scale if scale is not None else D ** -0.5)

    Tq_pad = (-Tq) % q_chunk
    Tk_pad = (-Tk) % kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Tq_pad), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Tk_pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Tk_pad), (0, 0)))
    nq, nk = qp.shape[2] // q_chunk, kp.shape[2] // kv_chunk
    qs = qp.reshape(B, Hq, nq, q_chunk, D).transpose(2, 0, 1, 3, 4)
    ks = kp.reshape(B, Hkv, nk, kv_chunk, D).transpose(2, 0, 1, 3, 4)
    vs = vp.reshape(B, Hkv, nk, kv_chunk, D).transpose(2, 0, 1, 3, 4)

    kv_valid = jnp.arange(kp.shape[2]) < Tk

    def q_step(_, qi_qblk):
        qi, qblk = qi_qblk
        qpos = qi * q_chunk + jnp.arange(q_chunk) + kv_offset

        def kv_step(carry, ki_kv):
            m, l, acc = carry
            ki, kblk, vblk = ki_kv
            kpos = ki * kv_chunk + jnp.arange(kv_chunk)

            def compute(m, l, acc):
                # GQA via grouped einsum — no jnp.repeat of K/V (group x less
                # HBM traffic), and bf16 operands feed the MXU directly with
                # f32 accumulation (preferred_element_type) instead of
                # explicit converts (§Perf global optimization G1).
                B_, Hq_, qc, D_ = qblk.shape
                qg = qblk.reshape(B_, Hkv, group, qc, D_)
                logits = jnp.einsum(
                    "bhgqd,bhkd->bhgqk", qg, kblk,
                    preferred_element_type=jnp.float32) * s
                logits = _soft_cap(logits, softcap)
                msk = kv_valid[ki * kv_chunk + jnp.arange(kv_chunk)][None, :]
                if causal:
                    msk = msk & (kpos[None, :] <= qpos[:, None])
                if window is not None:
                    msk = msk & (kpos[None, :] > qpos[:, None] - window)
                logits = jnp.where(msk[None, None, None], logits, NEG_INF)
                logits = logits.reshape(B_, Hq_, qc, -1)
                m_new = jnp.maximum(m, logits.max(-1))
                p = jnp.exp(logits - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + p.sum(-1)
                pg = p.reshape(B_, Hkv, group, qc, -1).astype(vblk.dtype)
                pv = jnp.einsum("bhgqk,bhkd->bhgqd", pg, vblk,
                                preferred_element_type=jnp.float32)
                acc_new = acc * corr[..., None] + pv.reshape(B_, Hq_, qc, D_)
                return m_new, l_new, acc_new

            if causal and skip_masked_blocks:
                # whole block in the future -> skip (saves ~half the FLOPs)
                block_needed = ki * kv_chunk <= qpos[-1]
                m, l, acc = jax.lax.cond(
                    block_needed, compute, lambda m, l, acc: (m, l, acc), m, l, acc)
            else:
                m, l, acc = compute(m, l, acc)
            return (m, l, acc), None

        m0 = jnp.full((B, Hq, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hq, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, Hq, q_chunk, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qs))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, Hq, nq * q_chunk, D)
    return out[:, :, :Tq]


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len, *, window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     scale: Optional[float] = None) -> jnp.ndarray:
    """Single-position attention against a (possibly padded) KV cache.

    q: (B, Hq, 1, D); caches: (B, Hkv, S, D); cache_len: (B,) valid lengths.
    """
    B, Hq, _, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    s = (scale if scale is not None else D ** -0.5)
    kr = jnp.repeat(k_cache, group, axis=1).astype(jnp.float32)
    vr = jnp.repeat(v_cache, group, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr) * s
    logits = _soft_cap(logits, softcap)
    pos = jnp.arange(S)[None, :]
    valid = pos < cache_len[:, None]
    if window is not None:
        valid &= pos > (cache_len[:, None] - 1 - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)


def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           cache_len, *, window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None,
                           k_scale: Optional[jnp.ndarray] = None,
                           v_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Single-position attention computed THROUGH the page table.

    The gather-free oracle: a ``lax.scan`` over the page-table columns with
    flash-decode online-softmax accumulation — pages are the split-K axis.
    Each step touches one ``(B, page_size, ...)`` block of the pool, so no
    ``(B, S, ...)`` dense-view transient is ever materialized (the
    memory-wall copy ``gather_view`` + :func:`decode_attention` pays).

    q: (B, Hq, 1, D); pools: (num_pages, page_size, Hkv, D);
    page_table: (B, P) physical page ids (unallocated entries may point at
    the scratch page — masked positions never contribute); cache_len: (B,)
    valid lengths.  Token position t of slot b lives at
    ``(page_table[b, t // page_size], t % page_size)``.

    ``k_scale``/``v_scale`` (num_pages, Hkv) f32, when given, dequantize a
    QUANTIZED pool (int8/fp8 codes, DESIGN.md §13) at page-fetch time:
    each fetched page block is multiplied by its per-page, per-kv-head
    scale before entering the online softmax — the oracle for the fused
    dequant in the Pallas kernel.
    """
    B, Hq, _, D = q.shape
    ps, Hkv = k_pool.shape[1], k_pool.shape[2]
    P = page_table.shape[1]
    group = Hq // Hkv
    s = (scale if scale is not None else D ** -0.5)
    qg = q[:, :, 0, :].reshape(B, Hkv, group, D).astype(jnp.float32)
    cache_len = jnp.asarray(cache_len, jnp.int32)

    def page_step(carry, inputs):
        m, l, acc = carry
        pi, pid = inputs                     # page column index, (B,) phys ids
        kb = k_pool[pid].astype(jnp.float32)             # (B, ps, Hkv, D)
        vb = v_pool[pid].astype(jnp.float32)
        if k_scale is not None:
            kb = kb * k_scale[pid][:, None, :, None]     # (B,1,Hkv,1)
        if v_scale is not None:
            vb = vb * v_scale[pid][:, None, :, None]
        logits = jnp.einsum("bhgd,bshd->bhgs", qg, kb) * s
        logits = _soft_cap(logits, softcap)
        pos = pi * ps + jnp.arange(ps)                   # absolute positions
        valid = pos[None, :] < cache_len[:, None]
        if window is not None:
            valid &= pos[None, :] > (cache_len[:, None] - 1 - window)
        logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        # all-masked-so-far rows (m_new still NEG_INF) contribute nothing:
        # exp(NEG_INF - NEG_INF) would be 1, which for a cache_len of 0
        # (every page masked) would average raw pool V rows instead of
        # returning the Pallas kernel's zeros
        live = m_new > NEG_INF
        p = jnp.where(live[..., None], jnp.exp(logits - m_new[..., None]),
                      0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bhgs,bshd->bhgd", p, vb)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, group), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group), jnp.float32)
    a0 = jnp.zeros((B, Hkv, group, D), jnp.float32)
    # unroll the (short) page loop: straight-line per-page blocks keep the
    # transient at O(B x page_size) while avoiding the sequential while-loop
    # dispatch overhead that would otherwise lose to the one-shot gather on
    # CPU; capped so a long table doesn't blow up compile time
    (m, l, acc), _ = jax.lax.scan(
        page_step, (m0, l0, a0),
        (jnp.arange(P), page_table.T.astype(jnp.int32)),
        unroll=min(P, 16))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, Hq, 1, D).astype(q.dtype)


def chunk_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                    v_cache: jnp.ndarray, q_pos: jnp.ndarray, *,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None) -> jnp.ndarray:
    """Attention of a chunk already written into a linear KV cache.

    The chunked-prefill primitive: the chunk's own K/V sit in the cache at
    absolute positions ``q_pos`` (per query row), preceded by the cached
    prefix.  Query row r may see key slot s iff ``s <= q_pos[b, r]`` (and
    within ``window`` if set) — causal over absolute positions, so bucket
    padding rows and garbage past the written region are masked out.

    q: (B, Hq, W, D); caches: (B, Hkv, S, D) linear (non-ring) layout;
    q_pos: (B, W) absolute positions of the chunk rows.
    """
    B, Hq, W, D = q.shape
    Hkv, S = k_cache.shape[1], k_cache.shape[2]
    group = Hq // Hkv
    s = (scale if scale is not None else D ** -0.5)
    kr = jnp.repeat(k_cache, group, axis=1).astype(jnp.float32)
    vr = jnp.repeat(v_cache, group, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr) * s
    logits = _soft_cap(logits, softcap)
    key_pos = jnp.arange(S)[None, None, :]
    valid = key_pos <= q_pos[:, :, None]                   # (B, W, S)
    if window is not None:
        valid &= key_pos > (q_pos[:, :, None] - window)
    logits = jnp.where(valid[:, None, :, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)


# ----------------------------------------------------------------------------
# RWKV6 (Finch) WKV recurrence with data-dependent decay
# ----------------------------------------------------------------------------
def rwkv6_scan(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               w: jnp.ndarray, u: jnp.ndarray,
               state: Optional[jnp.ndarray] = None):
    """RWKV6 recurrence.

    r,k,v: (B, H, T, D); w: (B, H, T, D) data-dependent decay in (0,1);
    u: (H, D) bonus. state: (B, H, D, D) mapping k-dim -> v-dim.

      S_t   = diag(w_t) S_{t-1} + k_t v_t^T
      out_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

    Returns (out (B,H,T,D), final_state).
    """
    B, H, T, D = r.shape
    if state is None:
        state = jnp.zeros((B, H, D, D), jnp.float32)

    def step(S, rkvw):
        rt, kt, vt, wt = rkvw  # each (B, H, D)
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,D,D)
        out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[..., :, None] * kv)
        S_new = wt[..., :, None] * S + kv
        return S_new, out

    rs = r.transpose(2, 0, 1, 3).astype(jnp.float32)
    ks = k.transpose(2, 0, 1, 3).astype(jnp.float32)
    vs = v.transpose(2, 0, 1, 3).astype(jnp.float32)
    ws = w.transpose(2, 0, 1, 3).astype(jnp.float32)
    final, outs = jax.lax.scan(step, state, (rs, ks, vs, ws))
    return outs.transpose(1, 2, 0, 3).astype(r.dtype), final


# ----------------------------------------------------------------------------
# Mamba-style selective scan (used by Hymba's SSM heads)
# ----------------------------------------------------------------------------
def selective_scan(x: jnp.ndarray, delta: jnp.ndarray, A: jnp.ndarray,
                   Bm: jnp.ndarray, Cm: jnp.ndarray,
                   state: Optional[jnp.ndarray] = None):
    """S4/Mamba selective state-space scan.

    x, delta: (B, T, D); A: (D, N); Bm, Cm: (B, T, N); state: (B, D, N).
      h_t = exp(delta_t * A) h_{t-1} + delta_t * B_t * x_t
      y_t = (h_t C_t^T)
    Returns (y (B,T,D), final_state (B,D,N)).
    """
    Bsz, T, D = x.shape
    N = A.shape[1]
    if state is None:
        state = jnp.zeros((Bsz, D, N), jnp.float32)

    dA = jnp.exp(delta[..., None] * A[None, None])                # (B,T,D,N)
    dBx = delta[..., None] * Bm[:, :, None, :] * x[..., None]     # (B,T,D,N)

    def step(h, inputs):
        dAt, dBxt, Ct = inputs
        h = dAt * h + dBxt
        y = jnp.einsum("bdn,bn->bd", h, Ct)
        return h, y

    final, ys = jax.lax.scan(
        step, state,
        (dA.transpose(1, 0, 2, 3).astype(jnp.float32),
         dBx.transpose(1, 0, 2, 3).astype(jnp.float32),
         Cm.transpose(1, 0, 2).astype(jnp.float32)))
    return ys.transpose(1, 0, 2).astype(x.dtype), final


def rwkv6_scan_chunked(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                       w: jnp.ndarray, u: jnp.ndarray,
                       state: Optional[jnp.ndarray] = None, chunk: int = 64):
    """Chunked (matmul-form) RWKV6 — §Perf hillclimb H1.

    The naive recurrence materializes the (B,H,D,D) state every timestep
    (O(T) HBM round-trips of a D^2 tensor — the worst cell in the baseline
    roofline table).  This reformulation materializes state once per CHUNK
    and turns the within-chunk work into three (C,C)/(C,D) matmuls
    (MXU-friendly), exactly the GLA/flash-linear-attention trick applied to
    RWKV6's data-dependent decay:

      with A_t = sum_{j<=t} log w_j (inclusive cumsum within the chunk):
        inter_t = (r_t * e^{A_{t-1}}) . S_0
        intra_t = sum_{s<t} [(r_t e^{A_{t-1}}) . (k_s e^{-A_s})] v_s
                  + (r_t . (u * k_t)) v_t
        S_end   = diag(e^{A_C}) S_0 + sum_s (k_s e^{A_C - A_s}) v_s^T

    Exactness: algebraically identical to the recurrence; floating-point
    differences come only from exp/cumsum reassociation (validated to ~1e-4
    against the naive scan in tests/test_kernels.py).
    """
    B, H, T, D = r.shape
    C = min(chunk, T)
    assert T % C == 0, (T, C)
    n = T // C
    if state is None:
        state = jnp.zeros((B, H, D, D), jnp.float32)

    f32 = jnp.float32
    rs = r.reshape(B, H, n, C, D).transpose(2, 0, 1, 3, 4).astype(f32)
    ks = k.reshape(B, H, n, C, D).transpose(2, 0, 1, 3, 4).astype(f32)
    vs = v.reshape(B, H, n, C, D).transpose(2, 0, 1, 3, 4).astype(f32)
    logw = jnp.log(jnp.maximum(w.astype(f32), 1e-30))
    As = jnp.cumsum(logw.reshape(B, H, n, C, D), axis=3)  # inclusive, per chunk
    As = As.transpose(2, 0, 1, 3, 4)

    mask = jnp.tril(jnp.ones((C, C), bool), -1)  # strict lower: s < t

    def chunk_step(S, inputs):
        rc, kc, vc, Ac = inputs                  # (B,H,C,D)
        A_ex = Ac - logw_chunk(Ac)               # exclusive prefix
        q_t = rc * jnp.exp(A_ex)                 # (B,H,C,D)
        k_s = kc * jnp.exp(-Ac)
        inter = jnp.einsum("bhtd,bhdv->bhtv", q_t, S)
        scores = jnp.einsum("bhtd,bhsd->bhts", q_t, k_s)
        scores = jnp.where(mask[None, None], scores, 0.0)
        diag = jnp.einsum("bhtd,bhtd->bht", rc, u[None, :, None, :] * kc)
        intra = jnp.einsum("bhts,bhsv->bhtv", scores, vc) + diag[..., None] * vc
        A_last = Ac[:, :, -1:, :]                # (B,H,1,D)
        S_new = (jnp.exp(A_last[:, :, 0, :, None]) * S
                 + jnp.einsum("bhsd,bhsv->bhdv", kc * jnp.exp(A_last - Ac), vc))
        return S_new, inter + intra

    def logw_chunk(Ac):
        # recover per-step log w from the inclusive cumsum: logw_t = A_t - A_{t-1}
        return jnp.concatenate([Ac[:, :, :1], jnp.diff(Ac, axis=2)], axis=2)

    final, outs = jax.lax.scan(chunk_step, state, (rs, ks, vs, As))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(B, H, T, D)
    return out.astype(r.dtype), final


def selective_scan_assoc(x: jnp.ndarray, delta: jnp.ndarray, A: jnp.ndarray,
                         Bm: jnp.ndarray, Cm: jnp.ndarray,
                         state: Optional[jnp.ndarray] = None):
    """Associative-scan selective scan — §Perf hillclimb H5.

    The sequential form steps a (B,D,N) carry T times through a while loop
    (XLA materializes carry copies and per-step slices; measured 369 s/step
    memory term on hymba train_4k).  The recurrence h_t = a_t*h_{t-1} + b_t
    is associative under (a1,b1)∘(a2,b2) = (a1*a2, b2 + a2*b1), so
    ``jax.lax.associative_scan`` computes all h_t in ~log2(T) vectorized
    passes — no division, no log-space overflow (unlike the cumprod-ratio
    chunk form, which overflows exp(-cumsum log a) for strong decays).
    Matches ``selective_scan`` to fp tolerance (tests).
    """
    Bsz, T, D = x.shape
    N = A.shape[1]
    a = jnp.exp(delta[..., None].astype(jnp.float32) * A[None, None])  # (B,T,D,N)
    b = (delta[..., None] * Bm[:, :, None, :] * x[..., None]).astype(jnp.float32)
    if state is not None:
        # fold the incoming state into the first step: b_0 += a_0 * h_0
        b = b.at[:, 0].add(a[:, 0] * state)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = jnp.einsum("btdn,btn->btd", h, Cm.astype(jnp.float32))
    return y.astype(x.dtype), h[:, -1]
