"""Pallas TPU paged decode attention: flash-decode over the page pool.

The gather-free counterpart of ``serve/pages.py::gather_view`` +
``ref.decode_attention``: one decode query per slot attends to its KV
directly THROUGH the page table, so the ``(B, max_len, ...)`` dense-view
transient of the reference paged decode step never exists and steady-state
HBM reads drop from O(max_len) to O(live tokens) per slot.

Grid: ``(B, Hkv, P)`` — slots × kv-heads × page-blocks, with the page axis
innermost ("arbitrary" semantics) as the split-K axis of a flash-decode
online softmax: running max / denominator / accumulator live in VMEM
scratch and are revisited across page steps.  The page table and the
per-slot lengths ride in as **scalar-prefetch** operands
(``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec index maps resolve
``table[b, p]`` BEFORE the kernel body runs and the DMA engine fetches
exactly one physical page per grid step — the paged analogue of
``flash_attention.py``'s GQA-via-index-map trick (q is laid out
``(B, Hkv, group, D)`` so every KV page is read once per kv head, never
per q head).

Pages past a slot's ``cache_len`` (and pages wholly below its sliding
window) skip their compute with ``pl.when``.  For the dead TAIL the
allocator's table entries additionally point at the scratch page, so even
the prefetch touches only a single hot page; wholly-below-window pages
are real allocated pages, so their grid steps still fetch one page each
(compute-free — in the serving stack this case never arises, since
window-capped cache leaves stay dense ring buffers and never page).
Sliding-window and softcap semantics match ``flash_attention.py`` /
``ref.decode_attention`` exactly.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

# renamed TPUCompilerParams -> CompilerParams across pallas releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(*refs, scale: float, window: Optional[int],
            softcap: Optional[float], ps: int, n_pages: int, group: int,
            quant: bool = False, with_lse: bool = False):
    if quant:
        # per-page, per-kv-head dequant scales ride as two extra
        # scalar-prefetch operands (DESIGN.md §13) — grid unchanged
        table_ref, len_ref, ks_ref, vs_ref, q_ref, k_ref, v_ref, o_ref, \
            *rest = refs
    else:
        table_ref, len_ref, q_ref, k_ref, v_ref, o_ref, *rest = refs
        ks_ref = vs_ref = None
    if with_lse:
        lse_ref, m_ref, l_ref, acc_ref = rest
    else:
        (m_ref, l_ref, acc_ref), lse_ref = rest, None
    b = pl.program_id(0)
    h = pl.program_id(1)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[b]
    start = p * ps
    # a page is needed iff it overlaps [max(0, length - window), length);
    # the overlap is never empty, so a computed block always has >= 1 valid
    # position (no all-masked softmax corner)
    needed = start < length
    if window is not None:
        needed = jnp.logical_and(needed, start + ps > length - window)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (group, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # (ps, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)     # (ps, D)
        if ks_ref is not None:
            # fused dequant: the fetched page block is int8/fp8 codes;
            # multiply by this page×kv-head's scale before the softmax
            pid = table_ref[b, p]
            k = k * ks_ref[pid, h]
            v = v * vs_ref[pid, h]
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        pos = start + jax.lax.broadcasted_iota(jnp.int32, (group, ps), 1)
        mask = pos < length
        if window is not None:
            mask &= pos > length - 1 - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(-1, keepdims=True))
        pexp = jnp.exp(logits - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + pexp.sum(-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            pexp, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(p == n_pages - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        if lse_ref is not None:
            # log-sum-exp of this invocation's logits, for cross-shard
            # combination (a slot with no local pages reports ~ -inf and
            # drops out of the merge)
            lse_ref[0, 0] = m_ref[..., 0] + jnp.log(l[..., 0])


@functools.partial(
    jax.jit,
    static_argnames=("window", "softcap", "scale", "interpret", "return_lse"),
)
def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, page_table: jnp.ndarray,
                           cache_len: jnp.ndarray, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None,
                           k_scale: Optional[jnp.ndarray] = None,
                           v_scale: Optional[jnp.ndarray] = None,
                           interpret: bool = True,
                           return_lse: bool = False):
    """q (B, Hq, 1, D); pools (num_pages, page_size, Hkv, D);
    page_table (B, P) int32 physical page ids; cache_len (B,) valid lengths.
    Hq % Hkv == 0.  Token position t of slot b lives at
    ``(page_table[b, t // page_size], t % page_size)``.

    ``k_scale``/``v_scale`` (num_pages, Hkv) f32 dequantize QUANTIZED pools
    (int8/fp8 codes) at page-fetch time: they ride in as two more
    scalar-prefetch operands and the kernel multiplies each fetched page
    block by ``scale[table[b, p], h]`` before the online softmax — the
    split-K grid structure is unchanged and the pages stream at 1 byte per
    element (DESIGN.md §13).

    ``return_lse=True`` additionally returns the per-head log-sum-exp
    (B, Hkv, group) f32 of the computed logits, so partial results over a
    SPLIT page axis can be exactly combined across TP shards
    (``distributed.collectives.tp_paged_decode_attention_merge``).
    """
    B, Hq, _, D = q.shape
    ps, Hkv = k_pool.shape[1], k_pool.shape[2]
    P = page_table.shape[1]
    group = Hq // Hkv
    s = scale if scale is not None else D ** -0.5
    quant = k_scale is not None
    # GQA layout: the group dim rides inside the q/out block, so each KV
    # page is fetched once per KV head (not once per q head)
    qg = q[:, :, 0, :].reshape(B, Hkv, group, D)

    # index maps take the scalar-prefetch refs as trailing args — varargs
    # keeps one set of maps valid for both the 2- and 4-operand layouts
    out_specs = pl.BlockSpec((1, 1, group, D),
                             lambda b, h, p, *_: (b, h, 0, 0))
    out_shape = jax.ShapeDtypeStruct((B, Hkv, group, D), q.dtype)
    if return_lse:
        out_specs = [out_specs,
                     pl.BlockSpec((1, 1, group),
                                  lambda b, h, p, *_: (b, h, 0))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((B, Hkv, group), jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        # page_table, cache_len (+ k/v page scales when quantized)
        num_scalar_prefetch=4 if quant else 2,
        grid=(B, Hkv, P),
        in_specs=[
            pl.BlockSpec((1, 1, group, D),
                         lambda b, h, p, *_: (b, h, 0, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, p, tbl, *_: (tbl[b, p], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, D),
                         lambda b, h, p, tbl, *_: (tbl[b, p], 0, h, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
    )
    prefetch = (page_table.astype(jnp.int32),
                jnp.asarray(cache_len, jnp.int32))
    if quant:
        prefetch += (k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32))
    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=s, window=window, softcap=softcap, ps=ps,
            n_pages=P, group=group, quant=quant, with_lse=return_lse),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*prefetch, qg, k_pool, v_pool)
    if return_lse:
        out, lse = out
        return out.reshape(B, Hq, 1, D), lse
    return out.reshape(B, Hq, 1, D)
