"""Jit'd public wrappers around the Pallas kernels with oracle fallback.

Every op takes ``use_pallas``; on CPU (this container) the Pallas kernels run
in interpret mode for validation only, so the framework defaults to the
pure-jnp references (which are lowering-safe, chunked implementations).
On a real TPU runtime set ``repro.kernels.ops.USE_PALLAS = True`` (or the
``kernels.use_pallas`` config flag) to dispatch the compiled kernels.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import paged_attention as _pa
from repro.kernels import ref
from repro.kernels import rwkv_scan as _rwkv
from repro.kernels import w4a8_matmul as _w4a8

USE_PALLAS = False  # module default; configs override per-call
_ON_TPU = any(d.platform == "tpu" for d in jax.devices()) if jax.devices() else False


def _dispatch(use_pallas: Optional[bool]) -> bool:
    return USE_PALLAS if use_pallas is None else use_pallas


def w4a8_matmul(qx, x_scale, codes, w_scale, *, out_dtype=jnp.bfloat16,
                use_pallas: Optional[bool] = None):
    if _dispatch(use_pallas):
        return _w4a8.w4a8_matmul(qx, x_scale, codes, w_scale,
                                 out_dtype=out_dtype, interpret=not _ON_TPU)
    return ref.w4a8_matmul(qx, x_scale, codes, w_scale, out_dtype)


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              softcap: Optional[float] = None, scale: Optional[float] = None,
              kv_offset: int = 0, use_pallas: Optional[bool] = None):
    if _dispatch(use_pallas):
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   kv_offset=kv_offset, interpret=not _ON_TPU)
    return ref.mha_chunked(q, k, v, causal=causal, window=window,
                           softcap=softcap, scale=scale, kv_offset=kv_offset)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     scale: Optional[float] = None,
                     use_pallas: Optional[bool] = None,
                     dist_axis: Optional[str] = None,
                     batch_axes: tuple = ()):
    del use_pallas  # decode uses the reference path (tiny q; bandwidth-bound)
    if dist_axis is not None and window is None:
        # §Perf H2: LSE-combined flash decode over the seq-sharded cache.
        from repro.distributed import collectives, runtime
        mesh = runtime.ambient_mesh()
        if mesh is not None and dist_axis in mesh.axis_names:
            S = k_cache.shape[2]
            valid = jnp.arange(S)[None, :] < cache_len[:, None]
            fn = collectives.distributed_decode_attention(
                mesh, dist_axis, softcap=softcap, scale=scale,
                batch_axes=batch_axes)
            return fn(q, k_cache, v_cache, valid)
    return ref.decode_attention(q, k_cache, v_cache, cache_len,
                                window=window, softcap=softcap, scale=scale)


def paged_decode_attention(q, k_pool, v_pool, page_table, cache_len, *,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           scale: Optional[float] = None,
                           use_pallas: Optional[bool] = None,
                           model_axis: Optional[str] = None,
                           batch_axes: tuple = ()):
    """Gather-free decode attention THROUGH the page table: no dense-view
    transient (serve/pages.py::gather_view) is ever materialized.  The
    Pallas kernel walks ``pool[table]`` page-block-wise (flash-decode over
    the split-K page axis, DESIGN.md §6); the reference is a ``lax.scan``
    over pages with the same online-softmax accumulation.

    On a TP serving mesh (``model_axis`` names a >1-sized mesh axis) the
    Pallas branch dispatches per shard (DESIGN.md §11): divisible head
    counts run the unchanged grid on each shard's head-cut pool slice with
    no collective; an indivisible Hkv replicates heads and splits the page
    axis instead, merging partials in log-sum-exp space.  The jnp reference
    needs no routing — XLA partitions it under GSPMD directly.

    QUANTIZED pools arrive as ``QuantizedLeaf`` (int8/fp8 codes + per-page,
    per-kv-head scales, DESIGN.md §13): both backends dequantize at
    page-fetch time (the Pallas kernel via two extra scalar-prefetch
    operands).  The TP shard-dispatch collectives are not scale-aware, so
    quantized + tp>1 + Pallas falls back to the jnp reference, which GSPMD
    partitions like any other program."""
    from repro.core.quant import QuantizedLeaf
    k_scale = v_scale = None
    if isinstance(k_pool, QuantizedLeaf):
        k_pool, k_scale = k_pool.codes, k_pool.scales
        v_pool, v_scale = v_pool.codes, v_pool.scales
    if _dispatch(use_pallas):
        if model_axis is not None:
            from repro.distributed import collectives, runtime
            mesh = runtime.ambient_mesh()
            tp = (int(mesh.shape[model_axis])
                  if mesh is not None and model_axis in mesh.axis_names
                  else 1)
            Hq, Hkv = q.shape[1], k_pool.shape[2]
            if tp > 1 and k_scale is not None:
                return ref.paged_decode_attention(
                    q, k_pool, v_pool, page_table, cache_len, window=window,
                    softcap=softcap, scale=scale, k_scale=k_scale,
                    v_scale=v_scale)
            if tp > 1 and Hq % tp == 0:
                if Hkv % tp == 0:
                    fn = collectives.tp_paged_decode_attention(
                        mesh, model_axis, window=window, softcap=softcap,
                        scale=scale, batch_axes=batch_axes,
                        interpret=not _ON_TPU)
                    return fn(q, k_pool, v_pool, page_table, cache_len)
                if window is None and page_table.shape[1] % tp == 0:
                    fn = collectives.tp_paged_decode_attention_merge(
                        mesh, model_axis, softcap=softcap, scale=scale,
                        batch_axes=batch_axes, interpret=not _ON_TPU)
                    return fn(q, k_pool, v_pool, page_table, cache_len)
        return _pa.paged_decode_attention(q, k_pool, v_pool, page_table,
                                          cache_len, window=window,
                                          softcap=softcap, scale=scale,
                                          k_scale=k_scale, v_scale=v_scale,
                                          interpret=not _ON_TPU)
    return ref.paged_decode_attention(q, k_pool, v_pool, page_table,
                                      cache_len, window=window,
                                      softcap=softcap, scale=scale,
                                      k_scale=k_scale, v_scale=v_scale)


def chunk_attention(q, k_cache, v_cache, q_pos, *,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    use_pallas: Optional[bool] = None):
    """Chunked-prefill attention: a chunk written in place into a linear KV
    cache attends causally over absolute positions (serve-path paged/chunked
    prefill).  Reference path only — like decode, the W-row chunk is
    bandwidth-bound, so there is no Pallas variant."""
    del use_pallas
    return ref.chunk_attention(q, k_cache, v_cache, q_pos, window=window,
                               softcap=softcap, scale=scale)


def rwkv6_chunked(r, k, v, w, u, state=None, *, chunk: int = 64):
    return ref.rwkv6_scan_chunked(r, k, v, w, u, state, chunk=chunk)


def rwkv6(r, k, v, w, u, state=None, *, use_pallas: Optional[bool] = None):
    if _dispatch(use_pallas) and state is None:
        return _rwkv.rwkv6_scan(r, k, v, w, u, interpret=not _ON_TPU)
    return ref.rwkv6_scan(r, k, v, w, u, state)


def selective_scan(x, delta, A, B, C, state=None, *,
                   use_pallas: Optional[bool] = None,
                   algorithm: str = "sequential"):
    del use_pallas
    if algorithm == "associative" and x.shape[1] > 1:
        return ref.selective_scan_assoc(x, delta, A, B, C, state)
    return ref.selective_scan(x, delta, A, B, C, state)
