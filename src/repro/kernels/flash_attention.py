"""Pallas TPU flash attention (forward) with GQA, sliding window, softcap.

Covers the host-side attention hot-spot of the split-brain design and the
prefill path of every assigned transformer arch (gemma2's logit softcap and
local/global alternation included).

Grid: (B, Hq, Tq/bq, Tk/bk) with the KV dimension innermost ("arbitrary"
semantics); online-softmax running max/denominator/accumulator live in VMEM
scratch and are revisited across KV steps.  GQA is expressed in the K/V
BlockSpec index maps (q head h reads kv head h // group) — no repeat/copy of
KV in HBM.  Fully-masked causal blocks are skipped with ``pl.when``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ, DEFAULT_BK = 512, 512

# renamed TPUCompilerParams -> CompilerParams across pallas releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            softcap: Optional[float], kv_offset: int, n_kv: int,
            bq: int, bk: int, tk_valid: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos0 = qi * bq + kv_offset
    block_needed = True
    if causal:
        block_needed = ki * bk <= qpos0 + bq - 1
    if window is not None:
        block_needed = jnp.logical_and(
            block_needed, (ki + 1) * bk - 1 > qpos0 - window)

    @pl.when(block_needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, D)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < tk_valid
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "scale", "kv_offset",
                     "bq", "bk", "interpret"),
)
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None, kv_offset: int = 0,
                    bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                    interpret: bool = True) -> jnp.ndarray:
    """q (B,Hq,Tq,D); k,v (B,Hkv,Tk,D); Hq % Hkv == 0."""
    B, Hq, Tq, D = q.shape
    _, Hkv, Tk, _ = k.shape
    group = Hq // Hkv
    s = scale if scale is not None else D ** -0.5
    bq_, bk_ = min(bq, Tq), min(bk, Tk)
    pad_q, pad_k = (-Tq) % bq_, (-Tk) % bk_
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq, nk = q.shape[2] // bq_, k.shape[2] // bk_

    out = pl.pallas_call(
        functools.partial(
            _kernel, scale=s, causal=causal, window=window, softcap=softcap,
            kv_offset=kv_offset, n_kv=nk, bq=bq_, bk=bk_, tk_valid=Tk),
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq_, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk_, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk_, D), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, 1), jnp.float32),
            pltpu.VMEM((bq_, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Tq]
