"""Pallas TPU kernel: RWKV6 (Finch) WKV recurrence with data-dependent decay.

The recurrence S_t = diag(w_t) S_{t-1} + k_t v_t^T is the inference hot-spot
of the attention-free archs (rwkv6-7b) and maps poorly to plain XLA (a long
scalar scan).  Here the time axis is blocked: grid (B, H, T/bt) with the
(D, D) state carried in VMEM scratch across time blocks ("arbitrary"
semantics), and a ``fori_loop`` stepping through the block entirely in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BT = 256

# renamed TPUCompilerParams -> CompilerParams across pallas releases
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sfinal_ref, s_ref, *,
            bt: int, n_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0].astype(jnp.float32)  # (D,)

    def step(t, _):
        rt = r_ref[0, 0, t].astype(jnp.float32)   # (D,)
        kt = k_ref[0, 0, t].astype(jnp.float32)
        vt = v_ref[0, 0, t].astype(jnp.float32)
        wt = w_ref[0, 0, t].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]            # (D, D)
        out = jnp.sum(rt[:, None] * (s_ref[...] + u[:, None] * kv), axis=0)
        o_ref[0, 0, t] = out.astype(o_ref.dtype)
        s_ref[...] = wt[:, None] * s_ref[...] + kv
        return ()

    jax.lax.fori_loop(0, bt, step, ())

    @pl.when(ti == n_t - 1)
    def _done():
        sfinal_ref[0, 0] = s_ref[...]


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def rwkv6_scan(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               w: jnp.ndarray, u: jnp.ndarray, *, bt: int = DEFAULT_BT,
               interpret: bool = True):
    """r,k,v,w: (B,H,T,D); u: (H,D). Returns (out (B,H,T,D), state (B,H,D,D))."""
    B, H, T, D = r.shape
    bt_ = min(bt, T)
    assert T % bt_ == 0, (T, bt_)
    n_t = T // bt_

    out, sfinal = pl.pallas_call(
        functools.partial(_kernel, bt=bt_, n_t=n_t),
        grid=(B, H, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, bt_, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt_, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt_, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, bt_, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, D), lambda b, h, t: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bt_, D), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, D, D), lambda b, h, t: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, D), r.dtype),
            jax.ShapeDtypeStruct((B, H, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
    return out, sfinal
