"""Collective building blocks beyond what pjit inserts automatically.

``compressed_psum_mean`` — INT8-quantized gradient all-reduce (shard_map):
each DP shard blockwise-quantizes its local gradient to int8 + f32 scales,
all-reduces the int8 payload (4x less wire traffic than f32, 2x less than
bf16), then dequantizes.  Intended for the *cross-pod* (DCI) hop of the
gradient reduction where bandwidth is scarcest; within-pod reductions stay
full precision.  Error is bounded by the per-block scale (tested).

``dp_train_step_compressed`` — a data-parallel train step wrapper that
computes per-shard grads inside ``shard_map`` and combines them with the
compressed reduction; used where DP dominates (small models / many pods).

``distributed_decode_attention`` — flash-decode over a sequence-sharded KV
cache: each shard computes a partial attention + log-sum-exp over its cache
chunk, then combines with two tiny psums (B x H scalars) instead of
all-gathering logits.  This is the §Perf optimization for collective-bound
decode cells.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


# ---------------------------------------------------------------- compression
def _q8_block(x: jnp.ndarray, block: int):
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), 1, keepdims=True) / 127.0,
                        1e-20)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq8_block(q, scale, shape, size):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:size].reshape(shape)


def compressed_psum_mean(tree, axis_name: str, block: int = 256):
    """Mean-reduce a pytree over ``axis_name`` with int8 wire format.

    Must be called inside shard_map.  The int32 accumulation of int8 payloads
    is exact; quantization error is only the local rounding (<= scale/2).
    """
    n = jax.lax.psum(1, axis_name)

    def reduce_leaf_int8_wire(g):
        g32 = g.astype(jnp.float32)
        flat = g32.reshape(-1)
        pad = (-flat.size) % block
        blocks = jnp.pad(flat, (0, pad)).reshape(-1, block)
        local_scale = jnp.maximum(
            jnp.max(jnp.abs(blocks), 1, keepdims=True) / 127.0, 1e-20)
        # agree on a shared per-block scale (tiny pmax), then the int8
        # payload psum is exact and dequantizes with one scale
        scale = jax.lax.pmax(local_scale, axis_name)
        q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int8-width payload
        return _dq8_block(q_sum, scale, g.shape, g.size) / n

    return jax.tree.map(reduce_leaf_int8_wire, tree)


def dp_train_step_compressed(loss_fn, mesh: Mesh, axis_name: str = "data",
                             block: int = 256):
    """Build a data-parallel grad fn with int8-compressed reduction."""

    def per_shard(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        grads = compressed_psum_mean(grads, axis_name, block)
        loss = jax.lax.pmean(loss, axis_name)
        return loss, grads

    in_specs = (P(), P(axis_name))
    out_specs = (P(), P())
    return shard_map(per_shard, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# ------------------------------------------------- distributed decode attention
def distributed_decode_attention(mesh: Mesh, axis_name: str = "model",
                                 softcap: Optional[float] = None,
                                 scale: Optional[float] = None,
                                 batch_axes: tuple = ()):
    """Flash-decode with the KV cache sharded on the sequence dim.

    q: (B, H, 1, D) replicated over ``axis_name``;
    k_cache/v_cache: (B, Hkv, S, D) sharded on dim 2;
    valid: (B, S) mask sharded on dim 1.
    Combines shard-local (out, lse) with psum — wire cost O(B*H*D), vs
    O(cache bytes) for XLA's all-gather fallback when q arrives sharded on
    heads (§Perf hillclimb H2).
    """

    def local(q, k, v, valid):
        B, Hq, _, D = q.shape
        Hkv = k.shape[1]
        group = Hq // Hkv
        s = scale if scale is not None else D ** -0.5
        qg = q.reshape(B, Hkv, group, D)
        logits = jnp.einsum("bhgd,bhkd->bhgk", qg, k,
                            preferred_element_type=jnp.float32) * s
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        logits = jnp.where(valid[:, None, None, :], logits, -1e30)
        m = logits.max(-1, keepdims=True)                       # local max
        p = jnp.exp(logits - m)
        l = p.sum(-1, keepdims=True)
        o = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        # combine across shards in log-sum-exp space
        m_g = jax.lax.pmax(m[..., 0], axis_name)[..., None]
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, axis_name)
        o_g = jax.lax.psum(o * corr, axis_name)
        out = (o_g / jnp.maximum(l_g, 1e-30))
        return out.reshape(B, Hq, 1, D).astype(q.dtype)

    b = tuple(a for a in batch_axes if a in mesh.axis_names) or None
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(b), P(b, None, axis_name, None),
                  P(b, None, axis_name, None), P(b, axis_name)),
        out_specs=P(b), check_rep=False)


# --------------------------------------------- TP paged decode attention
def tp_paged_decode_attention(mesh: Mesh, axis_name: str = "model", *,
                              window: Optional[int] = None,
                              softcap: Optional[float] = None,
                              scale: Optional[float] = None,
                              batch_axes: tuple = (),
                              interpret: bool = True):
    """Per-shard Pallas paged flash-decode over the HEAD-CUT pool
    (DESIGN.md §11).

    q: (B, Hq, 1, D) cut on heads over ``axis_name``;
    pools: (num_pages, ps, Hkv, D) cut on KV heads;
    page_table (B, P) / cache_len (B,): host-owned, replicated.

    Requires Hq % tp == 0 and Hkv % tp == 0.  Contiguous head blocks keep
    GQA alignment in-shard — q heads [i*Hq/tp, ...) attend exactly the kv
    heads [i*Hkv/tp, ...) their column-sharded wk/wv produced — so each
    shard runs the UNCHANGED flash-decode grid on its (N, ps, Hkv/tp, D)
    slice and NO collective is needed at all: the output comes back cut on
    heads, ready for the row-sharded wo.
    """
    from repro.kernels import paged_attention as _pa

    def local(q, k_pool, v_pool, table, length):
        return _pa.paged_decode_attention(
            q, k_pool, v_pool, table, length, window=window,
            softcap=softcap, scale=scale, interpret=interpret)

    b = tuple(a for a in batch_axes if a in mesh.axis_names) or None
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(b, axis_name, None, None),
                  P(None, None, axis_name, None),
                  P(None, None, axis_name, None),
                  P(b, None), P(b)),
        out_specs=P(b, axis_name, None, None), check_rep=False)


def tp_paged_decode_attention_merge(mesh: Mesh, axis_name: str = "model", *,
                                    softcap: Optional[float] = None,
                                    scale: Optional[float] = None,
                                    batch_axes: tuple = (),
                                    interpret: bool = True):
    """The Hkv < tp fallback: heads replicate, the PAGE axis splits.

    When the TP degree does not divide the KV head count the pool stays
    replicated (sharding rules auto-drop the axis), so the head-cut path
    has nothing to cut.  Instead each shard walks a 1/tp slice of every
    slot's page-table columns — its local flash-decode sees lengths
    rebased to its page window — and the per-shard partial (out, lse)
    pairs combine exactly in log-sum-exp space with two tiny psums
    (O(B*Hq*D) wire), the paged twin of ``distributed_decode_attention``.
    Sliding-window leaves never page (serve/pages.py), so the merge only
    covers the window-free case.
    """
    from repro.kernels import paged_attention as _pa

    def local(q, k_pool, v_pool, table, length):
        B, Hq, _, D = q.shape
        ps = k_pool.shape[1]
        span = table.shape[1] * ps          # positions this shard covers
        off = jax.lax.axis_index(axis_name) * span
        # rebase: local position p corresponds to global off + p, so the
        # kernel's `pos < length` masking is exact under the clipped length
        len_loc = jnp.clip(length - off, 0, span)
        out, lse = _pa.paged_decode_attention(
            q, k_pool, v_pool, table, len_loc, softcap=softcap,
            scale=scale, interpret=interpret, return_lse=True)
        lse = lse.reshape(B, Hq, 1)          # (B, Hkv, group) -> head order
        m = jax.lax.pmax(lse, axis_name)
        w = jnp.exp(lse - m)                 # empty shards drop out (w ~ 0)
        num = jax.lax.psum(out.astype(jnp.float32) * w[..., None], axis_name)
        den = jax.lax.psum(w, axis_name)
        return (num / jnp.maximum(den[..., None], 1e-30)).astype(q.dtype)

    b = tuple(a for a in batch_axes if a in mesh.axis_names) or None
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(b), P(), P(), P(b, axis_name), P(b)),
        out_specs=P(b), check_rep=False)
