"""GPipe-style pipeline parallelism via shard_map + collective_permute.

The layer stack is split into ``S`` stages along a "pipe" mesh axis; a
microbatched schedule streams activations stage-to-stage with
``ppermute``.  Running ``M + S - 1`` ticks drains the pipe; bubble fraction
is (S-1)/(M+S-1).

This is the optional PP dimension of the framework (DESIGN.md §4): the
production mesh keeps DP x TP because scan-over-layers + FSDP covers the
assigned models, but long-skinny models (94-layer qwen3) can trade the
"data" axis for "pipe" with this module.  Correctness is tested on 8
virtual devices in tests/test_distributed_multidev.py.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(mesh: Mesh, stage_fn: Callable, num_microbatches: int,
                   axis_name: str = "pipe"):
    """Build a pipelined apply: y = stage_{S-1}(...stage_0(x)).

    stage_fn(stage_params, x_mb) -> y_mb applies ONE stage to ONE microbatch
    (same activation shape in/out).

    The returned callable takes
      stage_params: pytree with leading dim S (sharded over the pipe axis),
      x: (M, mb, ...) microbatched input (replicated),
    and returns y: (M, mb, ...) (replicated output of the last stage).
    """
    S = mesh.shape[axis_name]
    M = num_microbatches

    def per_stage(stage_params, x):
        stage_params = jax.tree.map(lambda a: a[0], stage_params)
        idx = jax.lax.axis_index(axis_name)
        mb_shape = x.shape[1:]
        state = jnp.zeros(mb_shape, x.dtype)
        outputs = jnp.zeros_like(x)
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(carry, t):
            state, outputs = carry
            inject = x[jnp.minimum(t, M - 1)]
            state = jnp.where(idx == 0, inject, state)
            state = stage_fn(stage_params, state)
            slot = jnp.clip(t - (S - 1), 0, M - 1)
            take = (idx == S - 1) & (t >= S - 1)
            outputs = jnp.where(
                take,
                jax.lax.dynamic_update_index_in_dim(outputs, state, slot, 0),
                outputs)
            state = jax.lax.ppermute(state, axis_name, perm)
            return (state, outputs), None

        (_, outputs), _ = jax.lax.scan(
            tick, (state, outputs), jnp.arange(M + S - 1))
        return outputs[None]  # (1, M, mb...) -> stacked over stages

    stacked = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(axis_name), check_rep=False)

    def apply(stage_params, x):
        out = stacked(stage_params, x)      # (S, M, mb...)
        return out[-1]                      # last stage holds the results

    return apply


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
