"""Sharding-rules engine: pytree paths -> PartitionSpecs on (pod, data, model).

Strategy (DESIGN.md §4):
  * batch            -> ("pod", "data")     (DP across pods and within)
  * TP (heads/ffn)   -> "model"             (Megatron column/row pattern)
  * EP (experts)     -> "model"
  * FSDP (ZeRO-3)    -> "data"              (weights/opt-state sharded; XLA
                                             inserts all-gather at use)
  * decode KV seq    -> "model"             (context parallelism for caches)

Every rule is *shape-checked*: an axis is only applied when the dim is
divisible by the mesh axis size (e.g. 4 KV heads never shard over 16-way
"model"; a batch of 1 never shards).  This keeps one rule set valid for all
10 archs x 4 shapes.
"""
from __future__ import annotations

import re
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.quant import QuantizedLeaf

# param-name -> logical spec on the trailing dims (stacked leading dims get None)
_COL = ("fsdp", "model")     # (d_in, out): out split over TP
_ROW = ("model", "fsdp")     # (in, d_out): in split over TP
_PARAM_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    # EP: experts over "model"; the per-expert matrices FSDP-shard over "data"
    # (both expert dims on "model" would double-map the axis)
    (r".*moe/w[13]$", ("expert", "fsdp", None)),
    (r".*moe/w2$", ("expert", None, "fsdp")),
    (r".*moe/router$", ("fsdp", None)),
    (r".*/(wq|wk|wv|w1|w3|cm_k|w_in|w_delta|wg|wr|w_lora_a|w_B|w_C)$", _COL),
    (r".*/(wo|w2|cm_v|w_out|w_delta_up|w_lora_b)$", _ROW),
    (r".*/A_log$", ("model", None)),
    (r"^embed$", ("model", "fsdp")),
    (r"^lm_head$", ("fsdp", "model")),
    # split-brain stacked weights name the unembedding "head" (not lm_head)
    (r"(^|.*/)head$", ("fsdp", "model")),
    (r".*/u$", (None, None)),
)

# cache-entry rules keyed by leaf name; trailing-dim specs (leading dims None-padded)
_CACHE_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    # transformer/encdec KV: (..., B, kv_heads, S, hd)
    (r".*(^|/)(k|v|cross_k|cross_v)(/\d+)?$", ("batch", None, "seq", None)),
    (r".*wkv$", ("batch", "model", None, None)),      # rwkv state (L,B,H,D,D)
    (r".*x_(tm|cm)$", ("batch", "model")),             # rwkv shift state (L,B,d)
    (r".*ssm$", ("batch", "model", None)),             # hymba ssm (L,B,d,N)
    (r".*len$", ("batch",)),
)

# Serve-path slot-cache rules (DESIGN.md §11): the TP serving mesh cuts the
# KV cache on HEADS, not sequence — each model shard owns the Hkv/tp heads
# its column-sharded wk/wv produce, so decode attention needs no KV
# collective at all.  Shape-checking (`_fit`) still applies: an indivisible
# Hkv (or head count) silently replicates, which IS the Hkv < tp fallback.
_SERVE_CACHE_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    # dense / ring KV: (..., B, kv_heads, S|W, hd) — heads over "model"
    (r".*(^|/)(k|v|cross_k|cross_v)(/\d+)?$", ("batch", "model", None, None)),
    (r".*wkv$", ("batch", "model", None, None)),      # rwkv state (L,B,H,D,D)
    (r".*x_(tm|cm)$", ("batch", "model")),             # rwkv shift state (L,B,d)
    (r".*ssm$", ("batch", "model", None)),             # hymba ssm (L,B,d,N)
    (r".*len$", ("batch",)),
)

# Page-pool leaf rules: trailing (num_pages, page_size, Hkv, hd) — the pool
# is cut on KV heads so each shard owns a (N, ps, Hkv/tp, hd) slice and the
# paged flash-decode grid is unchanged per shard.  Page ids stay global
# (tables replicated), so HostPager/CoW/prefix logic needs no distribution
# awareness.
_POOL_CACHE_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    (r".*(^|/)(k|v|cross_k|cross_v)(/\d+)?$", (None, None, "model", None)),
)


class MeshAxes:
    """Resolve logical axes against a concrete mesh."""

    def __init__(self, mesh: Mesh, cfg: ModelConfig):
        names = mesh.axis_names
        self.mesh = mesh
        self.batch: Tuple[str, ...] = tuple(
            a for a in cfg.parallel.batch_axes if a in names)
        self.model: Optional[str] = (
            cfg.parallel.model_axis if cfg.parallel.model_axis in names else None)
        self.fsdp: Optional[str] = (
            cfg.parallel.fsdp_axis if (cfg.parallel.fsdp_axis or "") in names else None)
        self.seq: Optional[str] = (
            cfg.parallel.seq_axis if (cfg.parallel.seq_axis or "") in names else None)

    def resolve(self, logical: Optional[str]):
        return {
            None: None,
            "batch": self.batch if self.batch else None,
            "model": self.model,
            "expert": self.model,
            "fsdp": self.fsdp,
            "seq": self.seq,
        }[logical]

    def size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.mesh.shape[a] for a in axes]))


def _fit(spec_tail: Tuple[Optional[str], ...], shape: Tuple[int, ...],
         ax: MeshAxes) -> P:
    """Pad spec to ndim and drop axes that don't divide the dim."""
    ndim = len(shape)
    tail = list(spec_tail[-ndim:]) if len(spec_tail) > ndim else list(spec_tail)
    full = [None] * (ndim - len(tail)) + tail
    out = []
    for dim, logical in zip(shape, full):
        resolved = ax.resolve(logical)
        if resolved is None or dim % ax.size(resolved) != 0:
            out.append(None)
        else:
            out.append(resolved)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def _match(rules, key: str):
    for pattern, spec in rules:
        if re.match(pattern, key):
            return spec
    return None


def _param_pspecs_impl(params, cfg: ModelConfig, mesh: Mesh, transform=None):
    ax = MeshAxes(mesh, cfg)

    def spec(path, leaf):
        key = _path_str(path)
        # QuantizedLinear leaves: codes shard like the weight; scales like out dim
        key = re.sub(r"/(codes)$", "", key)
        is_scales = key.endswith("/scales")
        key = re.sub(r"/scales$", "", key)
        # optimizer moment trees mirror params under m/ and v/ prefixes
        key = re.sub(r"^(m|v)/", "", key)
        key = re.sub(r"/(q|scale)$", "", key)  # int8 moment codec leaves
        matched = _match(_PARAM_RULES, key)
        if matched is None:
            return P()
        if is_scales:
            matched = matched[-1:]  # per-out-channel scales
        if not hasattr(leaf, "shape"):
            return P()
        if transform is not None:
            matched = transform(matched)
        return _fit(matched, leaf.shape, ax)

    return jax.tree_util.tree_map_with_path(spec, params)


def param_pspecs(params, cfg: ModelConfig, mesh: Mesh):
    """PartitionSpec pytree for params (works for raw or LAQ-quantized trees
    and for AdamW moment trees that mirror them)."""
    return _param_pspecs_impl(params, cfg, mesh)


def serve_param_pspecs(params, cfg: ModelConfig, mesh: Mesh):
    """Column-ONLY tensor parallelism for FLOAT serving params
    (DESIGN.md §11): the model axis survives only on a weight's OUTPUT
    (last) dim; row-parallel (contraction-dim) cuts are dropped.

    Why: a row cut splits the contraction, so XLA psums partial float sums
    in a different association than the single-device dot — a ~1-ULP
    perturbation that bf16 rounding turns into KV-cache bit flips, and the
    serve contract is TOKEN IDENTITY with single-device greedy, not
    allclose.  Column cuts only ever all-gather exact per-shard results
    (no arithmetic collectives), so the math is bitwise unchanged.  The
    quantized split-brain path keeps the full Megatron column/row rules:
    its matmuls accumulate in int32, where partial-sum order cannot change
    the result."""
    def column_only(matched):
        last = len(matched) - 1
        return tuple(
            None if (log in ("model", "expert") and i != last) else log
            for i, log in enumerate(matched))

    return _param_pspecs_impl(params, cfg, mesh, transform=column_only)


def cache_pspecs(cache, cfg: ModelConfig, mesh: Mesh):
    ax = MeshAxes(mesh, cfg)

    def spec(path, leaf):
        key = _path_str(path)
        matched = _match(_CACHE_RULES, key)
        if matched is None or not hasattr(leaf, "shape"):
            return P()
        return _fit(matched, leaf.shape, ax)

    return jax.tree_util.tree_map_with_path(spec, cache)


def serve_cache_pspecs(cache, cfg: ModelConfig, mesh: Mesh):
    """PartitionSpec pytree for a DENSE serve slot cache (head-cut TP
    layout).  Works on arrays or ShapeDtypeStructs."""
    ax = MeshAxes(mesh, cfg)

    def spec(path, leaf):
        key = _path_str(path)
        matched = _match(_SERVE_CACHE_RULES, key)
        if matched is None or not hasattr(leaf, "shape"):
            return P()
        return _fit(matched, leaf.shape, ax)

    return jax.tree_util.tree_map_with_path(spec, cache)


def pool_pspecs(pcache, cfg: ModelConfig, mesh: Mesh, sa):
    """PartitionSpec pytree for a PAGED serve slot cache.

    ``sa`` is the per-leaf sequence-axis tree (``serve.pages.seq_axes``):
    leaves with ``s_ax >= 0`` are in pool layout (trailing
    ``(num_pages, page_size, Hkv, hd)``) and cut on KV heads; the rest keep
    their dense slot layout and take the serve rules.  Shape-checked like
    every rule here — an Hkv that ``tp`` does not divide replicates.

    Quantized pools (``QuantizedLeaf`` leaves, DESIGN.md §13) get a
    QuantizedLeaf of specs back: codes shard like the dense pool leaf and
    the per-page scales — trailing ``(num_pages, Hkv)`` — put the model
    axis on their own Hkv dim so they follow the KV-head cut.

    ``sa`` is mapped FIRST so QuantizedLeaf subtrees arrive whole at the
    leaf fn instead of being flattened into codes/scales.
    """
    ax = MeshAxes(mesh, cfg)

    def spec(path, s_ax, leaf):
        key = _path_str(path)
        paged = s_ax is not None and s_ax >= 0
        matched = _match(_POOL_CACHE_RULES if paged else _SERVE_CACHE_RULES,
                         key)
        if isinstance(leaf, QuantizedLeaf):
            if matched is None:
                return QuantizedLeaf(P(), P(), leaf.kv_dtype, leaf.out_dtype)
            return QuantizedLeaf(
                _fit(matched, leaf.codes.shape, ax),
                _fit((None, "model"), leaf.scales.shape, ax),
                leaf.kv_dtype, leaf.out_dtype)
        if matched is None or not hasattr(leaf, "shape"):
            return P()
        return _fit(matched, leaf.shape, ax)

    return jax.tree_util.tree_map_with_path(spec, sa, pcache)


def pool_kv_cut(pool_specs, sa, tp: int, model_axis: str) -> int:
    """The pool's effective KV head cut: ``tp`` when EVERY paged leaf
    actually sharded over the model axis (divisible Hkv), else 1 — a
    replicated leaf would break per-shard byte exactness."""
    if tp <= 1:
        return 1

    def cut(s_ax, sp):
        if s_ax < 0:
            return True
        if isinstance(sp, QuantizedLeaf):
            return (model_axis in tuple(sp.codes)
                    and model_axis in tuple(sp.scales))
        return model_axis in tuple(sp)

    flags = jax.tree.map(cut, sa, pool_specs,
                         is_leaf=lambda x: isinstance(x, P))
    return tp if all(jax.tree.leaves(flags)) else 1


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, kind: str):
    ax = MeshAxes(mesh, cfg)
    b = ax.resolve("batch")
    if kind == "decode":
        specs = {"tokens": P(b)}
    else:
        specs = {"tokens": P(b, None)}
        if kind == "train":
            specs["labels"] = P(b, None)
            specs["mask"] = P(b, None)
    if cfg.frontend_tokens:
        specs["frontend"] = P(b, None, None)
    return specs


def with_sharding(mesh: Mesh, pspec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def logits_pspec(cfg: ModelConfig, mesh: Mesh, kind: str) -> P:
    ax = MeshAxes(mesh, cfg)
    b = ax.resolve("batch")
    v = ax.resolve("model") if cfg.vocab_size % ax.size(ax.resolve("model")) == 0 else None
    if kind == "decode":
        return P(b, v)
    return P(b, None, v)


def gather_fsdp(tree, cfg: ModelConfig):
    """ZeRO-3 weight gather (§Perf H4): constrain per-layer weights to their
    no-FSDP sharding before use, so XLA all-gathers the (small) weight shard
    over "data" and keeps the batch sharded — instead of its fallback of
    un-sharding the batch to run contraction-parallel dots with multi-GB f32
    partial-sum all-reduces (measured 6 TB/chip/step on gemma2-27b train).
    The constraint's transpose makes weight grads reduce-scatter back to the
    FSDP shard — exactly the ZeRO-3 dataflow.
    """
    import dataclasses as _dc

    from repro.distributed import runtime

    mesh = runtime.ambient_mesh()
    if mesh is None or not cfg.parallel.fsdp_axis             or cfg.parallel.fsdp_axis not in mesh.axis_names:
        return tree
    cfg_nofsdp = _dc.replace(
        cfg, parallel=_dc.replace(cfg.parallel, fsdp_axis=None))
    specs = param_pspecs(tree, cfg_nofsdp, mesh)
    fsdp_specs = param_pspecs(tree, cfg, mesh)

    def constrain(path, a, sp, fsp):
        if not hasattr(a, "ndim") or a.ndim < 2:
            return a
        # MoE experts stay FSDP-sharded: they are already EP-split over
        # "model" and gathering the (huge) expert stack per layer costs more
        # all-gather than the contraction-parallel dots it avoids (measured:
        # qwen3 train went collective-bound).  Batch pinning still applies.
        if "moe/" in _path_str(path):
            return jax.lax.with_sharding_constraint(a, NamedSharding(mesh, fsp))
        return jax.lax.with_sharding_constraint(a, NamedSharding(mesh, sp))

    return jax.tree_util.tree_map_with_path(constrain, tree, specs, fsdp_specs)


def pin_tp_exact(x, cfg: ModelConfig):
    """All-gather a model-axis-sharded activation (DESIGN.md §11).

    Applied to the INPUT of every down-projection (``o @ wo``, ``h @ w2``,
    recurrent out-projections) when ``cfg.parallel.exact_tp`` is set: the
    activation is column-cut output (attention heads / d_ff), and letting
    XLA run the following dot contraction-parallel would psum float partial
    sums in a different association than the single-device matmul — a
    1-ULP perturbation that bf16 KV rounding amplifies into greedy-token
    flips.  Constraining to replicated forces an ALL-GATHER (exact bit
    movement, no arithmetic) and a redundant but bitwise-single-device dot
    on every shard.  Up-projections and attention stay genuinely
    tensor-parallel; only the cheap (d_model-output) dots are replicated.
    No-op outside a mesh context or when ``exact_tp`` is False (training
    keeps the Megatron row-parallel dataflow)."""
    from repro.distributed import runtime

    if not cfg.parallel.exact_tp:
        return x
    mesh = runtime.ambient_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*([None] * x.ndim))))


def pin_batch(x, cfg: ModelConfig):
    """Pin the residual stream's batch sharding (§Perf H4b): without this,
    XLA's sharding propagation may flip the layer-scan carry to a
    replicated-batch / head-sharded layout (observed on gemma2 train:
    (256, H_local, ...) attention buffers, 6 TB/chip partial-sum
    all-reduces).  One constraint per scan body keeps DP batch parallelism
    through the whole stack."""
    from repro.distributed import runtime

    mesh = runtime.ambient_mesh()
    if mesh is None:
        return x
    ax = MeshAxes(mesh, cfg)
    b = ax.resolve("batch")
    if b is None or x.shape[0] % ax.size(b) != 0:
        return x
    spec = P(b, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
