"""repro.distributed"""
