"""Ambient-mesh access for ops that want shard_map fast paths inside jit.

The mesh entered via ``with mesh:`` (Mesh context manager) is visible at
trace time; ops consult it to decide whether a distributed implementation
(e.g. LSE-combined decode attention) is available.
"""
from __future__ import annotations

from typing import Optional

from jax.sharding import Mesh


def ambient_mesh() -> Optional[Mesh]:
    try:
        from jax.interpreters import pxla
        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    return None
