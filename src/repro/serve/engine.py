"""Production serving engine: batched prefill + decode for every arch family.

Wraps the jitted ``prefill``/``serve_step`` callables (the same ones the
multi-pod dry-run compiles) behind a request-batch API.  On real hardware the
mesh is the production mesh; on CPU it serves reduced configs for tests and
examples.

The default (``fused=True``) path compiles the whole request into two
programs: one bucketed ``api.prefill_bucketed`` call that fills the KV cache
with the entire prompt, and one ``lax.scan``-fused decode loop that emits
every generated token in a single dispatch (DESIGN.md §1).  ``fused=False``
keeps the original one-dispatch-per-token reference loop for parity testing.

Every compiled shape is bucketed to a power of two (prompt width, decode
steps, batch), so the jit caches stay O(log max_len) no matter how ragged
the request mix is.  ``eos_id`` enables per-request stop tokens with exact
generated-length reporting.

For the continuous-batching scheduler (serve/scheduler.py) the engine also
exposes the slot protocol: ``init_slot_cache`` / ``prefill_slot`` /
``insert_slot`` / ``decode_slots`` — a fixed ``(max_slots, ...)`` cache
pytree where each slot is an independent request stream, admitted mid-flight
by a bucketed B=1 prefill and advanced by ONE persistent masked decode step.
Interface-traffic accounting (``meter``) replays eq. 7-10 bytes per *active*
token (DESIGN.md §4).

``page_size=N`` switches the slot cache to the paged layout (serve/pages.py,
DESIGN.md §5-6): sequence-growing cache leaves live in a shared page pool
with a host-owned per-slot page table, allocated on demand and freed on EOS,
so resident KV bytes track actual occupancy instead of max_slots × max_len.
The default paged decode step (``paged_attn="inplace"``) appends each active
slot's token to its page and computes attention DIRECTLY through the traced
table (``api.paged_decode_step`` -> ``ops.paged_decode_attention``), so no
dense-view transient exists and steady-state KV reads are O(live tokens)
per slot; ``paged_attn="gather"`` keeps the PR-3 reference discipline
(gather dense view -> same family decode math -> scatter one token) as the
fallback/oracle.  Either way: fixed shapes throughout, zero steady-state
recompiles.  Leaves that do not scale with ``max_len`` (recurrent state,
window ring buffers) pass through dense — the recurrent families' no-op
page table.  ``prefill_chunk_slot`` feeds a prompt as fixed-width chunks so
the scheduler can interleave prefill with decode (chunked prefill).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.splitbrain import TrafficMeter, TrafficModel
from repro.distributed import sharding as shd
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.serve import pages as pages_mod
from repro.serve.errors import InvalidRequestError
from repro.serve import slots as slots_mod
from repro.train import step as step_mod


class ServeEngine(pages_mod.PagedEngineMixin):
    def __init__(self, cfg: ModelConfig, params, mesh=None, max_len: int = 128,
                 fused: bool = True, page_size: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 paged_attn: str = "inplace", prefix_cache: str = "off",
                 kv_dtype: str = "bf16"):
        # Serve programs trace with exact_tp: every down-projection input is
        # gathered before its contraction (shd.pin_tp_exact), so the sharded
        # step is BITWISE identical to single-device greedy — the serve
        # token-identity contract (DESIGN.md §11).  No-op on a 1-device mesh.
        cfg = dataclasses.replace(
            cfg, parallel=dataclasses.replace(cfg.parallel, exact_tp=True))
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else make_test_mesh()
        # tensor-parallel degree of the serving mesh (DESIGN.md §11): the
        # stacked params shard COLUMN-only (serve_param_pspecs — row cuts
        # would split contraction sums and break bf16 token identity) and
        # the slot KV state cuts on heads.  tp == 1 (the 1-device test
        # mesh) reproduces the single-device layout exactly.
        self._tp = (int(self.mesh.shape[cfg.parallel.model_axis])
                    if cfg.parallel.model_axis in self.mesh.axis_names else 1)
        self._param_sh = shd.with_sharding(
            self.mesh, shd.serve_param_pspecs(params, cfg, self.mesh))
        with self.mesh:
            self.params = jax.device_put(params, self._param_sh)
        self.max_len = max_len
        self.fused = fused
        self.meter = TrafficMeter()
        self._traffic = TrafficModel.for_config(cfg)
        # slot decode runs requests at ragged positions: the lockstep
        # scalar-index cache write (Perf H2) is wrong there, so the slot
        # programs compile against this variant of the config.
        self._ragged_cfg = dataclasses.replace(
            cfg, parallel=dataclasses.replace(cfg.parallel,
                                              aligned_decode=False))
        self._serve_step = None
        self._prefill_jit: Dict[int, Any] = {}         # keyed by bucket width
        self._loop_jit: Dict[Tuple[int, Optional[int]], Any] = {}
        self._slot_step_jit: Dict[int, Any] = {}       # keyed by n_slots
        self._slot_insert = None
        self._axes = None
        # ---- paged slot cache (page_size=None keeps the dense slot layout)
        self.page_size = page_size
        self.num_pages = num_pages
        self._pager = (pages_mod.HostPager(page_size, num_pages, max_len)
                       if page_size is not None else None)
        self._paged_attn = self.check_paged_attn(paged_attn)
        self._prefix_cache_on = self.check_prefix_cache(prefix_cache)
        # pool storage format (DESIGN.md §13): int8/fp8 pages quantize on
        # write and dequantize inside the decode kernel's page fetch
        self._kv_dtype = pages_mod.check_kv_dtype(kv_dtype, page_size)
        self._fq_jit = None                    # post-prefill fake-quant pass
        self._paging_active = False            # set by init_slot_cache
        self._seq_ax = None
        self._paged_step = None
        self._b1_shape = None                  # B=1 request-cache eval_shape
        self._chunk_jit: Dict[int, Any] = {}   # keyed by chunk width
        # the lm fused chunk path needs every cache slot linear (non-ring)
        self._chunk_block_ok = (
            cfg.family == "lm" and not cfg.cross_attn_every
            and all(s.window is None or s.window >= max_len
                    for s in cfg.layer_pattern))

    # -------------------------------------------------------- jitted programs
    def _get_serve_step(self, cache):
        if self._serve_step is None:
            self._serve_step = step_mod.make_serve_step(
                self.cfg, self.mesh, self.params, cache, donate=False,
                param_spec_fn=shd.serve_param_pspecs,
                cache_spec_fn=shd.serve_cache_pspecs)
        return self._serve_step

    def _get_prefill(self, cache, width: int):
        """Bucketed prefill program; ``width`` must be a power-of-two bucket.
        One entry per bucket -> O(log max_len) compiles total."""
        if width not in self._prefill_jit:
            self._prefill_jit[width] = step_mod.make_bucketed_prefill(
                self.cfg, self.mesh, self.params, cache,
                cache_spec_fn=shd.serve_cache_pspecs,
                param_spec_fn=shd.serve_param_pspecs)
        return self._prefill_jit[width]

    # ------------------------------------------------- TP serving placements
    def _cache_shardings(self, tree_like):
        """NamedSharding pytree for a dense cache under the serve rules
        (head-cut KV; identical to replicated on a 1-device mesh)."""
        return shd.with_sharding(
            self.mesh,
            shd.serve_cache_pspecs(tree_like, self._ragged_cfg, self.mesh))

    def _b1_shardings(self):
        if self._b1_sh is None:
            if self._b1_shape is None:
                self._b1_shape = jax.eval_shape(
                    lambda: api.init_cache(self.cfg, 1, self.max_len))
            self._b1_sh = self._cache_shardings(self._b1_shape)
        return self._b1_sh

    def _vec_shardings(self, n: int) -> NamedSharding:
        """Placement of a per-slot (n,) vector (tokens / active mask)."""
        ax = shd.MeshAxes(self.mesh, self.cfg)
        b = ax.resolve("batch")
        if b is None or n % ax.size(b) != 0:
            return NamedSharding(self.mesh, P())
        return NamedSharding(self.mesh, P(b))

    def _get_decode_loop(self, cache, steps: int, eos_id: Optional[int]):
        key = (steps, eos_id)
        if key not in self._loop_jit:
            self._loop_jit[key] = step_mod.make_decode_loop(
                self.cfg, self.mesh, self.params, cache, steps, eos_id=eos_id,
                param_spec_fn=shd.serve_param_pspecs,
                cache_spec_fn=shd.serve_cache_pspecs)
        return self._loop_jit[key]

    def jit_cache_sizes(self) -> Dict[str, int]:
        """Compiled-program census (bench/test introspection)."""
        return {
            "prefill_buckets": len(self._prefill_jit),
            "loop_buckets": len(self._loop_jit),
            "slot_steps": len(self._slot_step_jit),
            "chunk_widths": len(self._chunk_jit),
        }

    # ----------------------------------------------------- traffic accounting
    @property
    def traffic_shards(self) -> int:
        """How many ways the boundary-traffic accounting splits per token.

        Equals the mesh's TP degree when every counted channel width
        (d_model, kv_dim, vocab) divides exactly — each shard then crosses
        ``full/tp`` bytes and the per-shard entries sum to the single-device
        analytical model TO THE BYTE (DESIGN.md §11).  Any indivisible
        width falls back to 1 (single aggregate entry), because an
        approximate split would break the exactness contract."""
        tp, tm = self._tp, self._traffic
        if (tp > 1 and tm.d_model % tp == 0 and tm.kv_dim % tp == 0
                and tm.vocab_size % tp == 0):
            return tp
        return 1

    def meter_tokens(self, n: int) -> None:
        """Replay ``n`` active tokens' boundary crossings on the meter.

        Aggregate form of the split-brain per-token log (same names, same
        eq. 7-10 widths, bytes == n * TrafficModel.bytes_per_token()); the
        accounting rule for masked decode is that ONLY active slots cross
        the interface (DESIGN.md §4).  On a TP mesh the replay logs ONE
        entry per model shard at ``width/tp`` (``traffic_shards``): the
        host scatters each shard its input slice and collects its KV/logit
        slice, so boundary bytes do not duplicate across shards and the
        totals — hence every exactness assertion — are unchanged.
        """
        n = int(n)
        if n <= 0:
            return
        tm = self._traffic
        shards = self.traffic_shards
        for _ in range(shards):
            self.meter.h2d("x_qkv_in", (n, tm.num_layers,
                                        tm.d_model // shards))
            self.meter.d2h("kv_out", (n, tm.num_layers, 2,
                                      tm.kv_dim // shards))
            self.meter.h2d("attn_in", (n, tm.num_layers,
                                       tm.d_model // shards))
            self.meter.d2h("logits", (n, tm.vocab_size // shards))

    def measured_bytes(self, count_q: bool = False) -> Dict[str, int]:
        """Total metered boundary bytes (paper accounting: K/V + attention +
        logits; ``count_q=True`` adds the QKV input activations)."""
        return self.meter.measured_bytes(count_q)

    # --------------------------------------------------------------- generate
    def generate(self, prompts: np.ndarray, max_new: int = 16,
                 frontend: Optional[jnp.ndarray] = None,
                 fused: Optional[bool] = None,
                 eos_id: Optional[int] = None) -> Dict[str, Any]:
        """Greedy-decode a batch. prompts: (B, T0) int32 (right-aligned).

        ``eos_id``: per-request stop token.  Output rows are padded with
        ``eos_id`` past each request's stop, and ``gen_len`` reports the
        exact generated length (EOS inclusive, capped at ``max_new``).
        """
        if fused is None:
            fused = self.fused
        cfg = self.cfg
        B, T0 = prompts.shape
        if T0 - 1 + max_new > self.max_len:
            raise ValueError(
                f"request does not fit the cache: prompt_len={T0} + "
                f"max_new={max_new} needs {T0 - 1 + max_new} positions but "
                f"max_len={self.max_len}")
        with self.mesh:
            if not fused:
                cache = api.init_cache(cfg, B, self.max_len, frontend=frontend,
                                       params=self.params)
                return self._generate_stepwise(cache, prompts, max_new, eos_id)
            # bucket the batch too: pad with copies of row 0, slice outputs
            Bb = slots_mod.bucket(B)
            prompts_j = jnp.asarray(prompts, jnp.int32)
            if Bb > B:
                prompts_j = jnp.concatenate(
                    [prompts_j, jnp.broadcast_to(prompts_j[:1],
                                                 (Bb - B, T0))], axis=0)
                if frontend is not None:
                    frontend = jnp.concatenate(
                        [frontend, jnp.broadcast_to(
                            frontend[:1], (Bb - B,) + frontend.shape[1:])],
                        axis=0)
            cache = api.init_cache(cfg, Bb, self.max_len, frontend=frontend,
                                   params=self.params)
            tok = prompts_j[:, -1]
            tp0 = time.perf_counter()
            if T0 > 1:
                # one bucketed api.prefill_bucketed pass fills the cache with
                # the whole prompt (no T0 Python-loop decode steps)
                width = slots_mod.bucket(T0 - 1)
                body = prompts_j[:, :-1]
                if width > T0 - 1:
                    body = jnp.pad(body, ((0, 0), (0, width - (T0 - 1))))
                prefill = self._get_prefill(cache, width)
                _, cache = prefill(self.params, cache, body,
                                   np.int32(T0 - 1))
            prefill_s = time.perf_counter() - tp0
            # bucketed step count: run the bucket, slice to max_new (greedy
            # decode is prefix-stable, so the extra steps change nothing)
            steps = slots_mod.bucket(max_new)
            loop = self._get_decode_loop(cache, steps, eos_id)
            t0 = time.perf_counter()
            toks, _, cache, gen_len = loop(self.params, cache, tok)
            toks = jax.block_until_ready(toks)
            dt = time.perf_counter() - t0
        toks = np.asarray(toks)[:B, :max_new]
        gen_len = np.minimum(np.asarray(gen_len)[:B], max_new)
        self.meter_tokens(B * (T0 - 1) + int(gen_len.sum()))
        return {"tokens": toks,
                "gen_len": gen_len,
                "tokens_per_s": int(gen_len.sum()) / dt,
                "decode_s": dt,
                "prefill_s": prefill_s}

    def _generate_stepwise(self, cache, prompts: np.ndarray, max_new: int,
                           eos_id: Optional[int] = None):
        """Reference loop: one jitted dispatch per token (prefill included).

        EOS semantics mirror the fused loop exactly: finished rows keep
        stepping in lockstep but emit (and are fed) ``eos_id``; the loop may
        break early once every row has stopped, padding the remainder.
        """
        step = self._get_serve_step(cache)
        B = prompts.shape[0]
        tok = jnp.asarray(prompts[:, 0], jnp.int32)
        tp0 = time.perf_counter()
        for t in range(1, prompts.shape[1]):
            _, _, cache = step(self.params, cache, tok)
            tok = jnp.asarray(prompts[:, t], jnp.int32)
        prefill_s = time.perf_counter() - tp0
        out = []
        alive = np.ones((B,), bool)
        gen_len = np.zeros((B,), np.int32)
        t0 = time.perf_counter()
        for _ in range(max_new):
            tok, logits, cache = step(self.params, cache, tok)
            emitted = np.asarray(tok)
            gen_len += alive
            if eos_id is not None:
                emitted = np.where(alive, emitted, eos_id)
                alive &= emitted != eos_id
                tok = jnp.asarray(emitted, jnp.int32)
            out.append(emitted)
            if eos_id is not None and not alive.any():
                break
        dt = time.perf_counter() - t0
        while len(out) < max_new:
            out.append(np.full((B,), eos_id, np.int32))
        tokens = np.stack(out, axis=1)
        self.meter_tokens(B * (prompts.shape[1] - 1) + int(gen_len.sum()))
        return {"tokens": tokens,
                "gen_len": gen_len,
                "tokens_per_s": int(gen_len.sum()) / dt,
                "decode_s": dt,
                "prefill_s": prefill_s}

    # ---------------------------------------------------------- slot protocol
    # Consumed by serve/scheduler.py: a fixed (max_slots, ...) cache pytree
    # where every slot is an independent request stream.
    def _slot_axes(self):
        if self._axes is None:
            a = jax.eval_shape(lambda: api.init_cache(self.cfg, 1, self.max_len))
            b = jax.eval_shape(lambda: api.init_cache(self.cfg, 2, self.max_len))
            self._axes = slots_mod.batch_axes(a, b)
        return self._axes

    def _slot_seq_axes(self):
        """Per-leaf sequence axis (-1 = does not page), by shape diffing two
        ``max_len`` builds — mirrors the batch-axis discovery above.  Dense
        engines discover with an arbitrary delta (the answer is delta-free
        for any delta no window equals); the result also feeds the KV-read
        byte accounting, which applies to every layout."""
        if self._seq_ax is None:
            ps = self.page_size or 8
            a = jax.eval_shape(lambda: api.init_cache(self.cfg, 2, self.max_len))
            b = jax.eval_shape(
                lambda: api.init_cache(self.cfg, 2, self.max_len + ps))
            self._seq_ax = pages_mod.seq_axes(a, b, ps)
        return self._seq_ax

    def init_slot_cache(self, n_slots: int):
        """Fixed-shape batched cache, one slot per concurrent stream.

        With ``page_size`` set, sequence-growing leaves are allocated as a
        shared page pool instead (serve/pages.py) and a fresh host-side
        :class:`~repro.serve.pages.PagePool` tracks the per-slot page
        tables; everything else keeps the dense ``(n_slots, ...)`` layout.
        """
        if self.cfg.frontend_tokens or self.cfg.cross_attn_every:
            raise ValueError(
                "continuous batching covers the text-only families "
                "(frontend_tokens / cross-attention configs are not "
                "slot-servable)")
        shape = jax.eval_shape(
            lambda: api.init_cache(self.cfg, n_slots, self.max_len))
        ba, sa = self._slot_axes(), self._slot_seq_axes()
        self._note_slot_cache(n_slots, shape, ba, sa)
        if not self.will_page():
            if self._kv_dtype != "bf16":
                raise ValueError(
                    f"kv_dtype={self._kv_dtype!r} requires a paging family: "
                    f"no cache leaf of this config scales with max_len, so "
                    f"there is no page pool to quantize")
            # recurrent/ring-only families have nothing that scales with
            # max_len: the page table is a no-op and the dense layout IS
            # the occupancy-proportional one — skip pool bookkeeping.
            self._paging_active = False
            with self.mesh:
                cache = api.init_cache(self.cfg, n_slots, self.max_len)
                return jax.device_put(cache, self._cache_shardings(shape))
        if (self._paged_attn == "inplace"
                and self.cfg.parallel.decode_attn == "shard_map"):
            # ops.paged_decode_attention has no seq-sharded (dist_axis)
            # variant: refuse when paging actually engages rather than
            # silently dropping the sharding the config asked for
            # (DESIGN.md §6); never-paging families take the dense
            # fallback above and keep working.
            raise ValueError(
                "paged_attn='inplace' does not support "
                "parallel.decode_attn='shard_map' (the page pool is not "
                "sequence-sharded); serve this config with "
                "paged_attn='gather' or the dense slot cache")
        self._paging_active = True
        pool = self._pager.reset(n_slots)
        # head-cut pool placement (DESIGN.md §11): each model shard owns a
        # (num_pages, ps, Hkv/tp, hd) slice; the rules auto-replicate any
        # leaf whose Hkv the TP degree does not divide (the Hkv < tp
        # fallback), in which case the per-shard byte accounting stays 1-way
        pshape = pages_mod.pool_shape(shape, ba, sa, pool.num_pages,
                                      self.page_size, self._kv_dtype)
        pool_specs = shd.pool_pspecs(pshape, self._ragged_cfg, self.mesh, sa)
        self._pool_sh = shd.with_sharding(self.mesh, pool_specs)
        self._b1_sh = None
        self._b1_shardings()
        self._note_slot_cache(n_slots, shape, ba, sa,
                              self._kv_cut(pool_specs, sa))
        self._kv_quant_tok_bytes = (
            pages_mod.kv_token_bytes_quant(shape, ba, sa, self.page_size,
                                           self._kv_dtype)
            if self._kv_dtype != "bf16" else None)
        self._pager.prefix_on = self.prefix_sharing_active()
        with self.mesh:
            return pages_mod.make_pool(shape, ba, sa, pool.num_pages,
                                       self.page_size,
                                       shardings=self._pool_sh,
                                       kv_dtype=self._kv_dtype)

    def _kv_cut(self, pool_specs, sa) -> int:
        return shd.pool_kv_cut(pool_specs, sa, self._tp,
                               self.cfg.parallel.model_axis)

    # reserve_slot / can_ever_admit / free_slot / cache_stats come from
    # pages_mod.PagedEngineMixin (dense engines admit everything, no-ops).
    def _stats_seq_axes(self):
        return self._slot_seq_axes()

    def prefill_slot(self, prompt: np.ndarray):
        """Prefill ONE request into a fresh B=1 cache (bucketed width).

        prompt (T0,) -> (single-request cache, input token for the next
        decode step).  The returned cache is slot-shaped: insert_slot writes
        it into the batched cache without reshaping.
        """
        prompt = np.asarray(prompt, np.int32)
        T0 = prompt.shape[0]
        if T0 < 1:
            raise InvalidRequestError(
                "prefill_slot needs a non-empty prompt (the last token "
                "seeds decoding)")
        with self.mesh:
            cache = api.init_cache(self.cfg, 1, self.max_len)
            if T0 > 1:
                width = slots_mod.bucket(T0 - 1)
                body = np.zeros((1, width), np.int32)
                body[0, :T0 - 1] = prompt[:-1]
                prefill = self._get_prefill(cache, width)
                _, cache = prefill(self.params, cache, jnp.asarray(body),
                                   np.int32(T0 - 1))
                if self._kv_dtype != "bf16":
                    cache = self._fake_quant_b1(cache)
        return cache, int(prompt[-1])

    def _fake_quant_b1(self, cache):
        """Round-trip the completed pages of a B=1 request cache through the
        page quantizer (pages_mod.fake_quant_tree): dense prefill values
        become exactly the values pool insertion will store, so the decode
        tokens that follow match the quantized pool bit-for-bit — the knob's
        token-identity story for prefix on/off (DESIGN.md §13)."""
        if self._fq_jit is None:
            sa = self._slot_seq_axes()
            ps, kvd = self.page_size, self._kv_dtype

            def fq(cache):
                return pages_mod.fake_quant_tree(cache, cache["len"][0], sa,
                                                 ps, kvd)

            b1_sh = self._b1_shardings()
            self._fq_jit = jax.jit(fq, donate_argnums=(0,),
                                   in_shardings=(b1_sh,), out_shardings=b1_sh)
        return self._fq_jit(cache)

    def new_request_cache(self):
        """Fresh B=1 cache for chunked prefill (slot-shaped, empty)."""
        with self.mesh:
            cache = api.init_cache(self.cfg, 1, self.max_len)
            return jax.device_put(cache, self._b1_shardings())

    def seed_request_cache(self, cache, slot: int, cached_len: int):
        """Prefix-aware prefill entry: B=1 request cache seeded with the
        slot's matched prefix pages gathered from the pool, ``len`` set to
        ``cached_len`` — the tail chunk stream continues from there."""
        if self._b1_shape is None:
            self._b1_shape = jax.eval_shape(
                lambda: api.init_cache(self.cfg, 1, self.max_len))
        with self.mesh:
            return self.paged_seed(cache, slot, cached_len,
                                   self._slot_axes(), self._slot_seq_axes(),
                                   self._b1_shape)

    def prefill_chunk_slot(self, cache, chunk: np.ndarray, true_w: int):
        """Advance a B=1 request cache by one right-padded prompt chunk.

        chunk (W,) with W the FIXED chunk width (one compiled program per
        width, donated cache); only the first ``true_w`` tokens are real.
        The scheduler interleaves these with batched decode steps so a long
        prompt never head-of-line-blocks the decoding slots (DESIGN.md §5).
        """
        chunk = np.asarray(chunk, np.int32)
        W = chunk.shape[0]
        pages_mod.check_chunk_width(W, self.max_len)
        if W not in self._chunk_jit:
            block = self._chunk_block_ok
            sa = self._slot_seq_axes()
            ps, kvd = self.page_size, self._kv_dtype

            def chunk_fn(params, cache, tokens, true_len):
                cache = api.prefill_chunk(params, cache, tokens, true_len,
                                          self.cfg, block=block)
                if kvd != "bf16":
                    # fused fake-quant (DESIGN.md §13): completed pages
                    # round-trip through the page quantizer so the next
                    # chunk attends to exactly what the pool will store
                    cache = pages_mod.fake_quant_tree(cache, cache["len"][0],
                                                      sa, ps, kvd)
                return cache

            b1_sh = self._b1_shardings()
            self._chunk_jit[W] = jax.jit(
                chunk_fn, donate_argnums=(1,),
                in_shardings=(self._param_sh, b1_sh, None, None),
                out_shardings=b1_sh)
        with self.mesh:
            return self._chunk_jit[W](self.params, cache, chunk[None, :],
                                      jnp.int32(true_w))

    def insert_slot(self, batched_cache, slot_cache, slot: int):
        """Write a prefilled request into slot ``slot`` (donated, traced
        index: ONE compiled program covers every slot).  On the paged
        layout the host allocates the slot's pages first, then the B=1
        dense cache is scattered block-wise onto them (excess logical pages
        land on the scratch page — fixed write count, no recompiles)."""
        if self._paging_active:
            n_tok = int(np.asarray(slot_cache["len"])[0])
            with self.mesh:
                return self.paged_insert(batched_cache, slot_cache, slot,
                                         self._slot_axes(),
                                         self._slot_seq_axes(), n_tok)
        if self._slot_insert is None:
            self._slot_insert = slots_mod.make_slot_insert(
                self._slot_axes(),
                batched_sh=self._cache_shardings(jax.eval_shape(
                    lambda: api.init_cache(self.cfg, self._slot_count,
                                           self.max_len))),
                single_sh=self._b1_shardings())
        with self.mesh:
            return self._slot_insert(batched_cache, slot_cache,
                                     jnp.int32(slot))

    def decode_slots(self, cache, tokens, active, corrupt=None):
        """One masked batched decode step: every slot computes, only active
        slots advance (inactive cache leaves frozen).  Fixed shapes — the
        steady-state loop re-dispatches one compiled program forever.

        Returns ``(next_tokens, ok, cache)`` where ``ok`` is the per-slot
        finite-logits sentinel: False means that slot's logits went
        non-finite this step and its token is garbage — the scheduler
        quarantines it instead of appending.  ``corrupt`` (optional
        ``(n,)`` bool) is the fault-injection input: True slots get their
        logits NaN-poisoned inside the jitted step (all-False by default;
        fixed shape, zero extra recompiles).

        Paged layout: the host allocates any page the step will write into
        (position ``len``); then ``paged_attn="inplace"`` (default) appends
        each active slot's token to its page and attends DIRECTLY through
        the traced table (``api.paged_decode_step`` — no dense-view
        transient), while ``paged_attn="gather"`` keeps the reference
        discipline: gather the dense view, run the SAME family decode
        math, scatter the one new token per active slot back to its page.
        """
        n = int(tokens.shape[0])
        if corrupt is None:
            corrupt = np.zeros((n,), bool)
        if self._paging_active:
            act = np.asarray(active, bool)
            with self.mesh:
                cache = self.paged_pre_step(cache, act, self._slot_axes(),
                                            self._slot_seq_axes())
            if self._paged_step is None:
                ba, sa = self._slot_axes(), self._slot_seq_axes()
                rcfg = self._ragged_cfg

                if self._paged_attn == "inplace":
                    def paged_step(params, pcache, table, toks, act_m, bad):
                        logits, pc = api.paged_decode_step(
                            params, pcache, table, toks, rcfg, write=act_m,
                            seq_axes=sa)
                        logits = slots_mod.corrupt_logits(logits, bad)
                        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                        ok = slots_mod.finite_logits(logits)
                        return nxt, ok, pc
                else:
                    def paged_step(params, pcache, table, toks, act_m, bad):
                        view = pages_mod.gather_tree(pcache, table, ba, sa)
                        pos = view["len"]
                        logits, new = api.decode_step(params, view, toks,
                                                      rcfg)
                        logits = slots_mod.corrupt_logits(logits, bad)
                        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                        ok = slots_mod.finite_logits(logits)
                        new = slots_mod.select_slots(act_m, new, view, ba)
                        pc = pages_mod.scatter_token_tree(
                            pcache, new, table, pos, act_m, ba, sa)
                        return nxt, ok, pc

                # explicit placements: pool head-cut, page table replicated
                # (host-owned), per-slot vectors on the batch axis — the
                # sharded jit cache stays keyed on ONE layout, so the
                # steady state never recompiles on a TP mesh either
                vec = self._vec_shardings(n)
                repl = NamedSharding(self.mesh, P())
                self._paged_step = jax.jit(
                    paged_step, donate_argnums=(1,),
                    in_shardings=(self._param_sh, self._pool_sh, repl,
                                  vec, vec, vec),
                    out_shardings=(vec, vec, self._pool_sh))
            with self.mesh:
                out = self._paged_step(self.params, cache,
                                       self._pager.table(),
                                       jnp.asarray(tokens, jnp.int32),
                                       jnp.asarray(active, bool),
                                       jnp.asarray(corrupt, bool))
            self._pager.post_decode(act)
            return out
        self._meter_kv_read(np.asarray(active, bool))
        if n not in self._slot_step_jit:
            self._slot_step_jit[n] = step_mod.make_slot_step(
                self._ragged_cfg, self.mesh, self.params, cache,
                self._slot_axes(), cache_spec_fn=shd.serve_cache_pspecs,
                param_spec_fn=shd.serve_param_pspecs)
        with self.mesh:
            return self._slot_step_jit[n](
                self.params, cache, jnp.asarray(tokens, jnp.int32),
                jnp.asarray(active, bool), jnp.asarray(corrupt, bool))

    def rebuild(self, n_slots: int):
        """Re-materialise every device-side byte from host state after a
        device loss: params re-placed from the host copy, a fresh page pool
        (or dense slot cache) allocated, and the host pager reset.

        What is deliberately NOT touched: the jit caches.  Compiled
        programs are immutable host artifacts under the split-brain
        contract — a device failure invalidates *buffers*, never code — so
        the rebuilt pool re-enters the SAME compiled step and recovery
        costs zero recompiles (serve_bench gates this).  The radix prefix
        index dies with the pool (its device bytes are gone); recovered
        requests republish as they re-prefill, so sharing re-forms among
        the survivors.
        """
        with self.mesh:
            self.params = jax.device_put(self.params, self._param_sh)
        return self.init_slot_cache(n_slots)

