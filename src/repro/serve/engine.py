"""Production serving engine: batched prefill + decode for every arch family.

Wraps the jitted ``prefill``/``serve_step`` callables (the same ones the
multi-pod dry-run compiles) behind a request-batch API.  On real hardware the
mesh is the production mesh; on CPU it serves reduced configs for tests and
examples.

The default (``fused=True``) path compiles the whole request into two
programs: one ``api.prefill`` call that fills the KV cache with the entire
prompt, and one ``lax.scan``-fused decode loop that emits every generated
token in a single dispatch (DESIGN.md §1).  ``fused=False`` keeps the
original one-dispatch-per-token reference loop for parity testing.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.train import step as step_mod


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, mesh=None, max_len: int = 128,
                 fused: bool = True):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh if mesh is not None else make_test_mesh()
        self.max_len = max_len
        self.fused = fused
        self._serve_step = None
        self._prefill_jit: Dict[int, Any] = {}   # keyed by prompt_len
        self._loop_jit: Dict[int, Any] = {}      # keyed by steps

    def _get_serve_step(self, cache):
        if self._serve_step is None:
            self._serve_step = step_mod.make_serve_step(
                self.cfg, self.mesh, self.params, cache, donate=False)
        return self._serve_step

    def _get_prefill(self, cache, prompt_len: int):
        if prompt_len not in self._prefill_jit:
            self._prefill_jit[prompt_len] = step_mod.make_cache_prefill(
                self.cfg, self.mesh, self.params, cache)
        return self._prefill_jit[prompt_len]

    def _get_decode_loop(self, cache, steps: int):
        if steps not in self._loop_jit:
            self._loop_jit[steps] = step_mod.make_decode_loop(
                self.cfg, self.mesh, self.params, cache, steps)
        return self._loop_jit[steps]

    def generate(self, prompts: np.ndarray, max_new: int = 16,
                 frontend: Optional[jnp.ndarray] = None,
                 fused: Optional[bool] = None) -> Dict[str, Any]:
        """Greedy-decode a batch. prompts: (B, T0) int32 (right-aligned)."""
        if fused is None:
            fused = self.fused
        cfg = self.cfg
        B, T0 = prompts.shape
        with self.mesh:
            cache = api.init_cache(cfg, B, self.max_len, frontend=frontend,
                                   params=self.params)
            if not fused:
                return self._generate_stepwise(cache, prompts, max_new)
            prompts_j = jnp.asarray(prompts, jnp.int32)
            tok = prompts_j[:, -1]
            tp0 = time.perf_counter()
            if T0 > 1:
                # one fused api.forward-style pass fills the cache with the
                # whole prompt (no T0 Python-loop decode steps)
                prefill = self._get_prefill(cache, T0 - 1)
                _, cache = prefill(self.params, cache, prompts_j[:, :-1])
            prefill_s = time.perf_counter() - tp0
            loop = self._get_decode_loop(cache, max_new)
            t0 = time.perf_counter()
            toks, _, cache = loop(self.params, cache, tok)
            toks = jax.block_until_ready(toks)
            dt = time.perf_counter() - t0
        return {"tokens": np.asarray(toks),
                "tokens_per_s": B * max_new / dt,
                "decode_s": dt,
                "prefill_s": prefill_s}

    def _generate_stepwise(self, cache, prompts: np.ndarray, max_new: int):
        """Reference loop: one jitted dispatch per token (prefill included)."""
        step = self._get_serve_step(cache)
        tok = jnp.asarray(prompts[:, 0], jnp.int32)
        tp0 = time.perf_counter()
        for t in range(1, prompts.shape[1]):
            _, _, cache = step(self.params, cache, tok)
            tok = jnp.asarray(prompts[:, t], jnp.int32)
        prefill_s = time.perf_counter() - tp0
        out = []
        t0 = time.perf_counter()
        for _ in range(max_new):
            tok, logits, cache = step(self.params, cache, tok)
            out.append(np.asarray(tok))
        dt = time.perf_counter() - t0
        tokens = np.stack(out, axis=1)
        return {"tokens": tokens,
                "tokens_per_s": tokens.shape[0] * max_new / dt,
                "decode_s": dt,
                "prefill_s": prefill_s}
