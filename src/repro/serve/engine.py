"""Production serving engine: batched prefill + decode for every arch family.

Wraps the jitted ``prefill``/``serve_step`` callables (the same ones the
multi-pod dry-run compiles) behind a request-batch API.  On real hardware the
mesh is the production mesh; on CPU it serves reduced configs for tests and
examples.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.train import step as step_mod


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, mesh=None, max_len: int = 128):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh if mesh is not None else make_test_mesh()
        self.max_len = max_len
        self._serve_step = None

    def _get_serve_step(self, cache):
        if self._serve_step is None:
            self._serve_step = step_mod.make_serve_step(
                self.cfg, self.mesh, self.params, cache, donate=False)
        return self._serve_step

    def generate(self, prompts: np.ndarray, max_new: int = 16,
                 frontend: Optional[jnp.ndarray] = None) -> Dict[str, Any]:
        """Greedy-decode a batch. prompts: (B, T0) int32 (right-aligned)."""
        cfg = self.cfg
        B, T0 = prompts.shape
        with self.mesh:
            cache = api.init_cache(cfg, B, self.max_len, frontend=frontend,
                                   params=self.params)
            step = self._get_serve_step(cache)
            tok = jnp.asarray(prompts[:, 0], jnp.int32)
            # prefill via repeated decode (KV append); the one-shot
            # api.forward prefill path is exercised by the dry-run cells
            for t in range(1, T0):
                _, _, cache = step(self.params, cache, tok)
                tok = jnp.asarray(prompts[:, t], jnp.int32)
            out = []
            t0 = time.perf_counter()
            for _ in range(max_new):
                tok, logits, cache = step(self.params, cache, tok)
                out.append(np.asarray(tok))
            dt = time.perf_counter() - t0
        tokens = np.stack(out, axis=1)
        return {"tokens": tokens,
                "tokens_per_s": B * max_new / dt,
                "decode_s": dt}
