"""Online serving front end: a thread-queue server over the open-loop
scheduler, with per-token streaming, cancellation and deadlines.

The split-brain contract says ONE host thread owns all dynamic state — the
scheduler, the page tables, the jitted decode step.  ``OnlineServer`` keeps
that true while accepting requests from anywhere: ``submit()`` / ``cancel()``
are thread-safe and merely enqueue operations; a single background loop
thread drains them, runs ``scheduler.step()`` iterations while there is
work (briefly parking when idle), and fans terminal results out to
:class:`RequestHandle` futures.  No caller thread ever touches the
scheduler or JAX.

  caller threads                 loop thread (sole scheduler owner)
  ──────────────                 ───────────────────────────────────
  submit(prompt, ...) ──op──▶    drain ops: sched.submit()/cancel()
  handle.cancel()     ──op──▶    sched.step()      (one iteration)
  handle.stream()  ◀──tokens──   per-token callbacks (scheduler-side)
  handle.result()  ◀──future──   sched.poll() -> resolve handles

Streaming rides the scheduler's per-token callback: each generated token is
pushed into the handle's queue the same iteration it was decoded, so
``for tok in handle.stream()`` yields tokens live while other requests keep
batching.  A consumer that stops reading loses nothing downstream — the
queue is unbounded and the terminal sentinel always arrives; a consumer
whose callback *throws* gets its request cancelled (scheduler policy),
never the loop killed.

Deadlines are wall-clock-relative at submit time (``deadline_s=2.0`` means
"2 seconds from now"), translated onto the scheduler's loop clock.
Rejections (validation failures, mid-flight prefill failures) resolve the
handle with a ``REJECTED`` result carrying the reason, so every submitted
request terminates exactly once — nothing hangs.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.serve.scheduler import (ContinuousBatchingScheduler, Request,
                                   RequestResult, RequestState)

__all__ = ["OnlineServer", "RequestHandle", "ServerClosed"]

_SENTINEL = object()


class ServerClosed(RuntimeError):
    """submit() after stop(): the loop thread is gone."""


class RequestHandle:
    """Caller-side view of one submitted request: a future for the terminal
    :class:`RequestResult` plus a live token stream."""

    def __init__(self, server: "OnlineServer", uid: int):
        self._server = server
        self.uid = uid
        self._done = threading.Event()
        self._result: Optional[RequestResult] = None
        self._tokens: "queue.Queue" = queue.Queue()

    # ---- loop-thread side -------------------------------------------------
    def _push_token(self, tok: int) -> None:
        self._tokens.put(tok)

    def _resolve(self, result: RequestResult) -> None:
        self._result = result
        self._tokens.put(_SENTINEL)
        self._done.set()

    # ---- caller side ------------------------------------------------------
    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> RequestResult:
        """Block until the request reaches a terminal state.  Raises
        ``TimeoutError`` if it hasn't within ``timeout`` seconds (the
        request keeps running — this is a wait bound, not a deadline)."""
        if not self._done.wait(timeout):
            raise TimeoutError(
                f"request uid={self.uid} not finished within {timeout}s")
        assert self._result is not None
        return self._result

    def cancel(self) -> None:
        """Ask the loop to cancel this request; its slot and pages are
        freed within one scheduler iteration.  The handle still resolves
        (state CANCELLED, or an earlier terminal state if it won the race)."""
        self._server._enqueue(("cancel", self.uid))

    def stream(self) -> Iterator[int]:
        """Yield generated tokens as they are decoded; ends when the
        request reaches a terminal state.  Safe to call once per handle."""
        while True:
            tok = self._tokens.get()
            if tok is _SENTINEL:
                return
            yield tok


class OnlineServer:
    """Thread-queue online server over a :class:`ContinuousBatchingScheduler`.

    The scheduler (and transitively the engine, page pool and jitted
    programs) must not be driven by anyone else while the server is
    running.  Use as a context manager::

        with OnlineServer(sched) as srv:
            h = srv.submit(prompt, max_new=32, priority=1, deadline_s=5.0)
            for tok in h.stream():
                ...
            res = h.result()

    ``idle_wait_s`` is how long the loop parks when it has neither ops nor
    work (an op arrival wakes it immediately).

    ``watchdog_s`` arms the step heartbeat watchdog (DESIGN.md §12): the
    loop stamps a heartbeat every iteration, and a daemon thread trips when
    the heartbeat goes stale for ``watchdog_s`` seconds while requests are
    outstanding — a wedged decode dispatch.  The watchdog only *flags*; the
    recovery itself (``scheduler.recover()``) runs on the loop thread at
    its next safe point, because that thread is the sole owner of the
    scheduler and JAX state.  Consecutive watchdog recoveries back off
    exponentially (``recover_backoff_s`` doubling up to
    ``recover_backoff_cap_s``) so a persistently sick device cannot spin
    the loop in rebuild storms.
    """

    def __init__(self, scheduler: ContinuousBatchingScheduler,
                 idle_wait_s: float = 0.001,
                 watchdog_s: Optional[float] = None,
                 recover_backoff_s: float = 0.05,
                 recover_backoff_cap_s: float = 2.0):
        self.scheduler = scheduler
        self.idle_wait_s = float(idle_wait_s)
        self.watchdog_s = None if watchdog_s is None else float(watchdog_s)
        self.recover_backoff_s = float(recover_backoff_s)
        self.recover_backoff_cap_s = float(recover_backoff_cap_s)
        self._ops: List[Tuple] = []
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._handles: Dict[int, RequestHandle] = {}
        self._uid = 0
        self._thread: Optional[threading.Thread] = None
        self._watchdog_thread: Optional[threading.Thread] = None
        self._loop_error: Optional[BaseException] = None
        self._heartbeat = time.monotonic()
        self._watchdog_trips = 0
        self._recover_flag = False
        self._recover_streak = 0
        self._recover_wait = 0.0
        self._last_recover_t = 0.0

    # ------------------------------------------------------------ lifecycle
    def start(self, warmup: bool = False) -> "OnlineServer":
        if self._thread is not None:
            raise RuntimeError("server already started")
        if warmup:
            # compile on the caller's thread, before the loop owns the
            # scheduler — keeps first-request latency honest
            self.scheduler.warmup()
        self.scheduler.begin()
        self._heartbeat = time.monotonic()
        self._thread = threading.Thread(target=self._loop,
                                        name="serve-loop", daemon=True)
        self._thread.start()
        if self.watchdog_s is not None:
            self._watchdog_thread = threading.Thread(
                target=self._watchdog, name="serve-watchdog", daemon=True)
            self._watchdog_thread.start()
        return self

    def stop(self, drain: bool = True, timeout: Optional[float] = None
             ) -> None:
        """Shut the loop down.  ``drain=True`` serves everything already
        submitted first; ``drain=False`` cancels all outstanding requests
        (handles resolve CANCELLED)."""
        if self._thread is None:
            return
        if not drain:
            with self._lock:
                uids = list(self._handles)
            for uid in uids:
                self._enqueue(("cancel", uid))
        self._stop.set()
        self._wake.set()
        self._thread.join(timeout)
        self._thread = None
        if self._watchdog_thread is not None:
            self._watchdog_thread.join(timeout=5.0)
            self._watchdog_thread = None
        if self._loop_error is not None:
            raise RuntimeError("serve loop died") from self._loop_error

    def __enter__(self) -> "OnlineServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=not any(exc))

    # ------------------------------------------------------------ submission
    def submit(self, prompt, max_new: int = 16, priority: int = 0,
               deadline_s: Optional[float] = None) -> RequestHandle:
        """Thread-safe submission.  ``deadline_s`` is relative to NOW
        (wall clock at submit); ``priority`` is the SLA class (higher wins
        admission and may preempt lower).  Returns immediately with a
        handle — validation happens on the loop thread, and a malformed
        request resolves its handle as REJECTED rather than raising here."""
        if self._thread is None or self._stop.is_set():
            raise ServerClosed("submit() on a stopped server")
        with self._lock:
            uid = self._uid
            self._uid += 1
            handle = RequestHandle(self, uid)
            self._handles[uid] = handle
        self._enqueue(("submit", uid, np.asarray(prompt, np.int32),
                       int(max_new), int(priority),
                       None if deadline_s is None else float(deadline_s)))
        return handle

    def _enqueue(self, op: Tuple) -> None:
        with self._lock:
            self._ops.append(op)
        self._wake.set()

    # ------------------------------------------------------------- the loop
    def _drain_ops(self) -> None:
        sched = self.scheduler
        with self._lock:
            ops, self._ops = self._ops, []
        for op in ops:
            if op[0] == "submit":
                _, uid, prompt, max_new, priority, deadline_s = op
                handle = self._handles[uid]
                now = sched.clock()
                req = Request(
                    uid=uid, prompt=prompt, max_new=max_new,
                    arrival_s=now, priority=priority,
                    deadline_s=None if deadline_s is None
                    else now + deadline_s,
                    stream=handle._push_token)
                sched.submit(req)
            elif op[0] == "cancel":
                sched.cancel(op[1])

    def _publish_terminal(self) -> None:
        sched = self.scheduler
        for res in sched.poll():
            h = self._handles.pop(res.uid, None)
            if h is not None:
                h._resolve(res)
        for rej in sched.poll_rejected():
            h = self._handles.pop(rej.uid, None)
            if h is not None:
                h._resolve(RequestResult(
                    uid=rej.uid, tokens=np.zeros((0,), np.int32),
                    gen_len=0, prompt_len=0, admitted_s=-1.0,
                    finished_s=sched.clock(),
                    state=RequestState.REJECTED.value))
                h.reject_reason = rej.reason

    # ---------------------------------------------------------- the watchdog
    def _watchdog(self) -> None:
        """Heartbeat monitor: trips when the loop has outstanding requests
        but has not stamped a heartbeat for ``watchdog_s`` seconds.  Runs
        on its own daemon thread; never touches scheduler state — it only
        raises the recover flag and rearms."""
        interval = max(self.watchdog_s / 4.0, 0.005)
        while not self._stop.is_set():
            time.sleep(interval)
            if self._thread is None or not self._thread.is_alive():
                return
            with self._lock:
                busy = bool(self._handles)
            if not busy:
                # idle loop: nothing can be wedged, keep the clock fresh
                self._heartbeat = time.monotonic()
                continue
            if time.monotonic() - self._heartbeat > self.watchdog_s:
                self._watchdog_trips += 1
                self._recover_flag = True
                self._heartbeat = time.monotonic()   # rearm, don't re-trip
                self._wake.set()

    def _maybe_recover(self) -> None:
        """Loop-thread half of the watchdog: apply the flagged recovery at
        a safe point, with bounded exponential backoff between consecutive
        recoveries.  A quiet period of 2x the watchdog window resets the
        backoff streak."""
        if not self._recover_flag:
            return
        self._recover_flag = False
        now = time.monotonic()
        if (self._recover_streak
                and now - self._last_recover_t
                > 2.0 * (self.watchdog_s or 0.0) + self._recover_wait):
            self._recover_streak = 0
            self._recover_wait = 0.0
        wait = self._recover_wait - (now - self._last_recover_t)
        if self._recover_streak and wait > 0:
            time.sleep(wait)
        self.scheduler.recover(reason="watchdog: step heartbeat lost")
        self._last_recover_t = time.monotonic()
        self._recover_streak += 1
        self._recover_wait = min(
            self.recover_backoff_s * (2 ** (self._recover_streak - 1)),
            self.recover_backoff_cap_s)

    def _loop(self) -> None:
        sched = self.scheduler
        try:
            while True:
                self._heartbeat = time.monotonic()
                self._drain_ops()
                self._maybe_recover()
                if sched.has_work():
                    sched.step(realtime=False)
                    self._publish_terminal()
                    continue
                self._publish_terminal()
                if self._stop.is_set():
                    with self._lock:
                        pending_ops = bool(self._ops)
                    if not pending_ops and not sched.has_work():
                        break
                    continue
                self._wake.wait(self.idle_wait_s)
                self._wake.clear()
        except BaseException as e:   # noqa: BLE001 — resolve waiters first
            self._loop_error = e
            with self._lock:
                handles = list(self._handles.values())
                self._handles.clear()
            for h in handles:
                h._resolve(RequestResult(
                    uid=h.uid, tokens=np.zeros((0,), np.int32),
                    gen_len=0, prompt_len=0, admitted_s=-1.0,
                    finished_s=0.0,
                    state=RequestState.REJECTED.value))

    # ------------------------------------------------------------- inspection
    def stats(self) -> Dict[str, Any]:
        """Point-in-time loop counters (reads scheduler attributes the loop
        thread also touches — informational, not transactional)."""
        s = self.scheduler
        return {
            "iterations": getattr(s, "_iterations", 0),
            "decoded_tokens": getattr(s, "_decoded_tokens", 0),
            "prefill_tokens": getattr(s, "_prefill_tokens", 0),
            "preemptions": getattr(s, "_preempt_count", 0),
            "quarantines": getattr(s, "_quarantines", 0),
            "failed": getattr(s, "_failed_count", 0),
            "recoveries": getattr(s, "_recoveries", 0),
            "last_recovery_s": getattr(s, "_last_recovery_s", 0.0),
            "watchdog_trips": self._watchdog_trips,
            "outstanding": len(self._handles),
        }
