"""Continuous-batching serve loop: slot-based KV cache, zero-recompile
steady state.

The paper's Split-Brain protocol (§IV-B) makes the ITA device stateless so
the host can multiplex many streams over one immutable datapath; this module
is that host.  It keeps ONE persistent jitted batched decode step alive and
feeds it from a fixed ``(max_slots, ...)`` slot cache:

  admit ──> reserve pages ──> prefill (whole or CHUNKED) ──> insert_slot
    │                                         │
    └── free slot + pages <── EOS / max_new <── masked batched decode
                                               (1 dispatch per token for
                                                ALL active slots)

Slot lifecycle (DESIGN.md §4): a finished request frees its slot in place —
no reallocation, no shape change — and the next pending request is prefilled
into it mid-flight while the other slots keep decoding.  Every compiled
shape is a power-of-two bucket (serve/slots.py), so after warmup the steady
state dispatches exactly one fixed-shape program per token and NEVER
recompiles (asserted with a compile counter in benchmarks/serve_bench.py).

``prefill_chunk=C`` enables *chunked prefill* (DESIGN.md §5): a prompt body
is fed as fixed-width-C chunks, AT MOST ONE chunk per loop iteration, so a
long prompt adds bounded latency to each batched decode step instead of
head-of-line-blocking every decoding slot with a monolithic prefill.

Works with any engine exposing the slot protocol (``init_slot_cache`` /
``prefill_slot`` / ``insert_slot`` / ``decode_slots`` / ``meter_tokens``,
plus the optional paging hooks ``reserve_slot`` / ``free_slot`` and the
chunked-prefill pair ``new_request_cache`` / ``prefill_chunk_slot``):
serve/engine.py (all text families) and serve/splitbrain_engine.py (the
paper's LM configs).  With a paged engine (``page_size=...``), admission
additionally reserves worst-case KV pages and EOS returns them to the
shared pool, so resident KV bytes track live tokens (DESIGN.md §5).

With the engine's prefix cache armed (``prefix_cache="on"``), admission
goes through ``admit_slot``: the prompt is radix-matched against the
pool's block-hash index, matched full pages map into the slot with zero
prefill work (reservation counts only NEW pages), and the unmatched tail
streams through chunked prefill from a seeded B=1 cache; completed full
pages publish back to the index at insert (DESIGN.md §7).  Per-request
``cached_tokens``, ``queue_wait_s`` and ``ttft_s`` ship on every
``RequestResult``.

TrafficMeter accounting stays byte-exact per *active* token: a request
admitted at T0 and stopped after g tokens crosses the boundary exactly
(T0 - 1 + g) times, the same count the fused one-request ``generate()``
replays — that equality is a test (tests/test_scheduler.py).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["Request", "RequestResult", "RejectedRequest",
           "ContinuousBatchingScheduler"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (T0,) int32
    max_new: int = 16
    arrival_s: float = 0.0        # offset from serve-loop start


@dataclasses.dataclass
class RequestResult:
    uid: int
    tokens: np.ndarray            # (gen_len,) int32 — exactly what was generated
    gen_len: int
    prompt_len: int
    admitted_s: float
    finished_s: float
    cached_tokens: int = 0        # prompt tokens served from the prefix cache
    queue_wait_s: float = 0.0     # arrival (or loop start) -> admission
    ttft_s: float = 0.0           # arrival (or loop start) -> first token


@dataclasses.dataclass
class RejectedRequest:
    uid: int
    reason: str


@dataclasses.dataclass
class _SlotState:
    req: Request
    tokens: List[int]
    admitted_s: float
    cached: int = 0
    first_token_s: Optional[float] = None


@dataclasses.dataclass
class _PrefillJob:
    """A request whose prompt is being fed chunk-by-chunk into a B=1 cache
    (the slot is held but inactive until the last chunk is inserted).
    ``cached`` prompt tokens were served from the prefix cache: the B=1
    cache was seeded with them and the chunk stream starts there."""
    slot: int
    req: Request
    cache: Any
    consumed: int
    admitted_s: float
    cached: int = 0


class ContinuousBatchingScheduler:
    """Slot-based continuous batching over one persistent decode program.

    ``realtime=True`` honours ``Request.arrival_s`` against the wall clock
    (Poisson-arrival benchmarking); ``realtime=False`` treats arrivals as an
    admission ORDER only and admits as fast as slots free up (deterministic,
    used by the parity tests).

    ``prefill_chunk=C`` feeds prompt bodies as width-C chunks interleaved
    with decode steps (at most one chunk per iteration).  C must divide the
    engine's ``max_len``.  ``max_prefill_jobs`` bounds how many in-flight
    chunked prefills may exist at once — each holds a dense B=1 request
    cache until insertion, so the cap also bounds that resident memory
    (1/max_slots of the dense slot cache per job).
    """

    def __init__(self, engine, max_slots: int = 8,
                 eos_id: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 max_prefill_jobs: int = 2):
        self.engine = engine
        self.max_slots = int(max_slots)
        self.eos_id = eos_id
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be a positive chunk width, "
                f"got {prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        if max_prefill_jobs < 1:
            raise ValueError(
                f"max_prefill_jobs must be >= 1, got {max_prefill_jobs}")
        self.max_prefill_jobs = int(max_prefill_jobs)
        self.cache = None

    def warmup(self, prompt_len: int = 4, max_new: int = 2) -> None:
        """Compile the steady-state programs (prefill bucket / chunk,
        insert, slot step) before timing starts; leaves the TrafficMeter
        untouched.

        With an engine whose prefix cache is armed, the warm trace also
        exercises the sharing programs: a page-aligned prompt is published,
        then a whole-prefix repeat of it forces the seed gather AND the CoW
        page copy (its decode append lands inside the shared last page).
        ``max_prefill_jobs`` is pinched to 1 for the warm run so the
        publisher's insert lands before the repeat is admitted — otherwise
        both would miss the index and nothing prefix-specific compiles.
        """
        eng = self.engine
        ps = getattr(eng, "page_size", None)
        reqs = [Request(uid=-1, prompt=np.ones((prompt_len,), np.int32),
                        max_new=max_new)]
        prefix_armed = (hasattr(eng, "prefix_cache_armed")
                        and eng.prefix_cache_armed())
        if prefix_armed and 2 * ps + max_new <= eng.max_len:
            # publisher: body = 2*ps (two publishable full pages);
            # repeat: its full prompt is a strict prefix of the published
            # body -> whole-body match overshooting into the last page
            long = np.arange(1, 2 * ps + 2, dtype=np.int32)   # T0 = 2ps+1
            reqs = [Request(uid=-3, prompt=long, max_new=max_new),
                    Request(uid=-2, prompt=long[:2 * ps].copy(),
                            max_new=max_new)]
        jobs = self.max_prefill_jobs
        try:
            if prefix_armed:
                self.max_prefill_jobs = 1
            self.run(reqs)
        finally:
            self.max_prefill_jobs = jobs
        self.engine.meter.reset()

    # ------------------------------------------------------------- admission
    def _validate(self, requests: List[Request]):
        """Per-request validation: oversized or empty requests are rejected
        individually (with a readable reason) instead of aborting the whole
        batch; the survivors are served normally."""
        ok: List[Request] = []
        rejected: List[RejectedRequest] = []
        max_len = self.engine.max_len
        for r in requests:
            T0 = len(r.prompt)
            if T0 < 1:
                rejected.append(RejectedRequest(
                    r.uid, "empty prompt: a request needs at least one "
                           "token to seed decoding"))
            elif r.max_new < 1:
                rejected.append(RejectedRequest(
                    r.uid, f"max_new={r.max_new} asks for no output tokens"))
            elif T0 - 1 + r.max_new > max_len:
                rejected.append(RejectedRequest(
                    r.uid,
                    f"request does not fit the cache: prompt_len={T0} + "
                    f"max_new={r.max_new} needs {T0 - 1 + r.max_new} "
                    f"positions but max_len={max_len}"))
            else:
                ok.append(r)
        return ok, rejected

    # ------------------------------------------------------------ serve loop
    def run(self, requests: List[Request],
            realtime: bool = False) -> Dict[str, Any]:
        """Serve every request to completion; returns results + loop stats.

        ``wall_s`` includes realtime arrival sleeps; ``busy_s`` counts only
        time spent doing work, and both tokens/s figures are reported so an
        idle-heavy Poisson run can't masquerade as an efficient one.
        """
        eng = self.engine
        n_slots = self.max_slots
        chunk = self.prefill_chunk
        reqs, rejected = self._validate(requests)
        pending = deque(sorted(reqs, key=lambda r: (r.arrival_s, r.uid)))
        cache = eng.init_slot_cache(n_slots)
        tokens = np.zeros((n_slots,), np.int32)
        active = np.zeros((n_slots,), bool)
        states: Dict[int, _SlotState] = {}
        prefilling: deque = deque()           # _PrefillJob FIFO
        free = list(range(n_slots - 1, -1, -1))
        results: List[RequestResult] = []
        steps = 0
        decoded_tokens = 0
        prefill_tokens = 0
        cached_tokens = 0
        slept_s = 0.0
        t_start = time.perf_counter()

        def now() -> float:
            return time.perf_counter() - t_start

        def in_flight() -> bool:
            return bool(states) or bool(prefilling)

        def activate(slot: int, req: Request, tok: int, admitted_s: float,
                     cached: int) -> None:
            tokens[slot] = tok
            active[slot] = True
            states[slot] = _SlotState(req, [], admitted_s, cached)

        def start(req: Request, slot: int, cached: int = 0) -> None:
            nonlocal cache, prefill_tokens, cached_tokens
            body = len(req.prompt) - 1
            cached_tokens += cached
            if cached > 0:
                # prefix hit: seed a B=1 request cache with the matched
                # pages gathered from the pool; only the unmatched tail is
                # prefilled (chunk stream continuing at position ``cached``)
                seeded = eng.seed_request_cache(cache, slot, cached)
                if cached < body:
                    prefilling.append(_PrefillJob(
                        slot, req, seeded, cached, now(), cached))
                    return
                # whole-body hit: nothing to prefill, go straight to decode
                cache = eng.insert_slot(cache, seeded, slot)
                eng.publish_prefix(slot, req.prompt)
                activate(slot, req, int(req.prompt[-1]), now(), cached)
                return
            if chunk is not None and body > 0:
                prefilling.append(_PrefillJob(
                    slot, req, eng.new_request_cache(), 0, now()))
                return
            slot_cache, tok = eng.prefill_slot(req.prompt)
            cache = eng.insert_slot(cache, slot_cache, slot)
            if hasattr(eng, "publish_prefix"):
                eng.publish_prefix(slot, req.prompt)
            prefill_tokens += body
            activate(slot, req, tok, now(), 0)

        def finish(slot: int, st: _SlotState) -> None:
            t = now()
            results.append(RequestResult(
                uid=st.req.uid,
                tokens=np.asarray(st.tokens, np.int32),
                gen_len=len(st.tokens),
                prompt_len=len(st.req.prompt),
                admitted_s=st.admitted_s,
                finished_s=t,
                cached_tokens=st.cached,
                queue_wait_s=max(0.0, st.admitted_s - st.req.arrival_s),
                ttft_s=max(0.0, (st.first_token_s if st.first_token_s
                                 is not None else t) - st.req.arrival_s)))
            active[slot] = False
            free.append(slot)
            del states[slot]
            if hasattr(eng, "free_slot"):
                eng.free_slot(slot)

        def reject_pool(req: Request) -> None:
            pending.popleft()
            rejected.append(RejectedRequest(
                req.uid,
                "request does not fit the KV page pool even with every "
                f"slot idle (prompt_len={len(req.prompt)}, "
                f"max_new={req.max_new})"))

        while pending or in_flight():
            # ---- admit: reserve pages + start prefill into free slots
            while free and pending and (not realtime
                                        or pending[0].arrival_s <= now()):
                req = pending[0]
                slot = free[-1]
                if (chunk is not None and len(req.prompt) > 1
                        and len(prefilling) >= self.max_prefill_jobs):
                    break   # bound the resident B=1 prefill caches
                if hasattr(eng, "can_ever_admit") and not eng.can_ever_admit(
                        len(req.prompt), req.max_new):
                    # statically impossible (exceeds the pool itself):
                    # reject NOW instead of head-of-line blocking the
                    # queue behind a request no amount of frees can admit
                    reject_pool(req)
                    continue
                cached = 0
                if hasattr(eng, "admit_slot"):
                    # prefix-aware admission: radix-match the prompt, map
                    # shared pages into the slot, reserve only NEW pages
                    cached = eng.admit_slot(slot, req.prompt, req.max_new,
                                            chunk)
                    if cached is None:
                        if not in_flight():
                            reject_pool(req)
                            continue
                        break         # wait for running requests to free
                elif hasattr(eng, "reserve_slot") and not eng.reserve_slot(
                        slot, len(req.prompt), req.max_new):
                    if not in_flight():
                        # backstop (engines without can_ever_admit): an
                        # idle pool that still refuses can never admit
                        reject_pool(req)
                        continue
                    break                 # wait for running requests to free
                pending.popleft()
                free.pop()
                start(req, slot, cached)
            # ---- chunked prefill: at most ONE chunk per iteration, so a
            #      long prompt adds bounded latency per decode step
            if prefilling:
                job = prefilling[0]
                body = len(job.req.prompt) - 1
                w = min(chunk, body - job.consumed)
                buf = np.zeros((chunk,), np.int32)
                buf[:w] = job.req.prompt[job.consumed:job.consumed + w]
                job.cache = eng.prefill_chunk_slot(job.cache, buf, w)
                job.consumed += w
                if job.consumed == body:
                    prefilling.popleft()
                    cache = eng.insert_slot(cache, job.cache, job.slot)
                    if hasattr(eng, "publish_prefix"):
                        eng.publish_prefix(job.slot, job.req.prompt)
                    prefill_tokens += body - job.cached
                    activate(job.slot, job.req, int(job.req.prompt[-1]),
                             job.admitted_s, job.cached)
            if not active.any():
                if not prefilling and realtime and pending:
                    t0 = time.perf_counter()
                    time.sleep(max(0.0, pending[0].arrival_s - now()))
                    slept_s += time.perf_counter() - t0
                continue
            # ---- one masked batched decode step for every active stream
            n_active = int(active.sum())
            nxt, cache = eng.decode_slots(cache, tokens, active)
            steps += 1
            decoded_tokens += n_active
            nxt = np.asarray(nxt)
            t_step = now()
            for slot in np.flatnonzero(active):
                st = states[slot]
                tok = int(nxt[slot])
                if st.first_token_s is None:
                    st.first_token_s = t_step
                st.tokens.append(tok)
                done = (len(st.tokens) >= st.req.max_new
                        or (self.eos_id is not None and tok == self.eos_id))
                if done:
                    finish(slot, st)
                else:
                    tokens[slot] = tok

        wall_s = now()
        busy_s = wall_s - slept_s
        # Boundary accounting, replayed ONCE per run so the steady-state
        # loop's meter log stays O(1): only active slots ever cross, so the
        # total is exactly sum over requests of (T0 - 1 - cached + gen)
        # tokens — byte-identical to per-step replay (crossings are linear
        # in count).  Prefix-cached prompt tokens never cross: their K/V
        # was neither recomputed nor re-shipped (the saved bytes land on
        # the excluded "prefix_prefill_saved" host channel instead, so the
        # eq. 7-10 exactness contract holds with the cache on or off).
        eng.meter_tokens(prefill_tokens + decoded_tokens)
        self.cache = cache
        results.sort(key=lambda r: r.uid)
        return {
            "results": results,
            "rejected": rejected,
            "steps": steps,
            "decoded_tokens": decoded_tokens,
            "prefill_tokens": prefill_tokens,
            "cached_prompt_tokens": cached_tokens,
            "wall_s": wall_s,
            "busy_s": busy_s,
            "slept_s": slept_s,
            "tokens_per_s": decoded_tokens / wall_s if wall_s else 0.0,
            "requests_per_s": len(results) / wall_s if wall_s else 0.0,
            "tokens_per_s_busy":
                decoded_tokens / busy_s if busy_s else 0.0,
            "requests_per_s_busy":
                len(results) / busy_s if busy_s else 0.0,
        }
