"""Continuous-batching serve loop: slot-based KV cache, zero-recompile
steady state.

The paper's Split-Brain protocol (§IV-B) makes the ITA device stateless so
the host can multiplex many streams over one immutable datapath; this module
is that host.  It keeps ONE persistent jitted batched decode step alive and
feeds it from a fixed ``(max_slots, ...)`` slot cache:

  admit ──> bucketed B=1 prefill ──> insert_slot (donated, traced index)
    │                                         │
    └── free slot <── EOS / max_new <── masked batched decode (1 dispatch
                                            per token for ALL active slots)

Slot lifecycle (DESIGN.md §4): a finished request frees its slot in place —
no reallocation, no shape change — and the next pending request is prefilled
into it mid-flight while the other slots keep decoding.  Every compiled
shape is a power-of-two bucket (serve/slots.py), so after warmup the steady
state dispatches exactly one fixed-shape program per token and NEVER
recompiles (asserted with a compile counter in benchmarks/serve_bench.py).

Works with any engine exposing the slot protocol (``init_slot_cache`` /
``prefill_slot`` / ``insert_slot`` / ``decode_slots`` / ``meter_tokens``):
serve/engine.py (all text families) and serve/splitbrain_engine.py (the
paper's LM configs).  TrafficMeter accounting stays byte-exact per *active*
token: a request admitted at T0 and stopped after g tokens crosses the
boundary exactly (T0 - 1 + g) times, the same count the fused one-request
``generate()`` replays — that equality is a test (tests/test_scheduler.py).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np

__all__ = ["Request", "RequestResult", "ContinuousBatchingScheduler"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (T0,) int32
    max_new: int = 16
    arrival_s: float = 0.0        # offset from serve-loop start


@dataclasses.dataclass
class RequestResult:
    uid: int
    tokens: np.ndarray            # (gen_len,) int32 — exactly what was generated
    gen_len: int
    prompt_len: int
    admitted_s: float
    finished_s: float


@dataclasses.dataclass
class _SlotState:
    req: Request
    tokens: List[int]
    admitted_s: float


class ContinuousBatchingScheduler:
    """Slot-based continuous batching over one persistent decode program.

    ``realtime=True`` honours ``Request.arrival_s`` against the wall clock
    (Poisson-arrival benchmarking); ``realtime=False`` treats arrivals as an
    admission ORDER only and admits as fast as slots free up (deterministic,
    used by the parity tests).
    """

    def __init__(self, engine, max_slots: int = 8,
                 eos_id: Optional[int] = None):
        self.engine = engine
        self.max_slots = int(max_slots)
        self.eos_id = eos_id
        self.cache = None

    def warmup(self, prompt_len: int = 4, max_new: int = 2) -> None:
        """Compile the steady-state programs (prefill bucket, insert, slot
        step) before timing starts; leaves the TrafficMeter untouched."""
        prompt = np.ones((prompt_len,), np.int32)
        req = Request(uid=-1, prompt=prompt, max_new=max_new)
        self.run([req])
        self.engine.meter.reset()

    def run(self, requests: List[Request],
            realtime: bool = False) -> Dict[str, Any]:
        """Serve every request to completion; returns results + loop stats."""
        eng = self.engine
        n_slots = self.max_slots
        for r in requests:
            assert len(r.prompt) - 1 + r.max_new <= eng.max_len, \
                (r.uid, len(r.prompt), r.max_new, eng.max_len)
        pending = deque(sorted(requests, key=lambda r: (r.arrival_s, r.uid)))
        cache = eng.init_slot_cache(n_slots)
        tokens = np.zeros((n_slots,), np.int32)
        active = np.zeros((n_slots,), bool)
        states: Dict[int, _SlotState] = {}
        free = list(range(n_slots - 1, -1, -1))
        results: List[RequestResult] = []
        steps = 0
        decoded_tokens = 0
        prefill_tokens = 0
        t_start = time.perf_counter()

        def now() -> float:
            return time.perf_counter() - t_start

        while pending or active.any():
            # ---- admit: prefill new requests into free slots mid-flight
            while free and pending and (not realtime
                                        or pending[0].arrival_s <= now()):
                req = pending.popleft()
                slot = free.pop()
                slot_cache, tok = eng.prefill_slot(req.prompt)
                cache = eng.insert_slot(cache, slot_cache, slot)
                prefill_tokens += len(req.prompt) - 1
                tokens[slot] = tok
                active[slot] = True
                states[slot] = _SlotState(req, [], now())
            if not active.any():
                if realtime and pending:
                    time.sleep(max(0.0, pending[0].arrival_s - now()))
                continue
            # ---- one masked batched decode step for every active stream
            n_active = int(active.sum())
            nxt, cache = eng.decode_slots(cache, tokens, active)
            steps += 1
            decoded_tokens += n_active
            nxt = np.asarray(nxt)
            for slot in np.flatnonzero(active):
                st = states[slot]
                tok = int(nxt[slot])
                st.tokens.append(tok)
                done = (len(st.tokens) >= st.req.max_new
                        or (self.eos_id is not None and tok == self.eos_id))
                if done:
                    results.append(RequestResult(
                        uid=st.req.uid,
                        tokens=np.asarray(st.tokens, np.int32),
                        gen_len=len(st.tokens),
                        prompt_len=len(st.req.prompt),
                        admitted_s=st.admitted_s,
                        finished_s=now()))
                    active[slot] = False
                    free.append(slot)
                    del states[slot]
                else:
                    tokens[slot] = tok

        wall_s = now()
        # Boundary accounting, replayed ONCE per run so the steady-state
        # loop's meter log stays O(1): only active slots ever cross, so the
        # total is exactly sum over requests of (T0 - 1 + gen) tokens —
        # byte-identical to per-step replay (crossings are linear in count).
        eng.meter_tokens(prefill_tokens + decoded_tokens)
        self.cache = cache
        results.sort(key=lambda r: r.uid)
        return {
            "results": results,
            "steps": steps,
            "decoded_tokens": decoded_tokens,
            "wall_s": wall_s,
            "tokens_per_s": decoded_tokens / wall_s if wall_s else 0.0,
            "requests_per_s": len(results) / wall_s if wall_s else 0.0,
        }
