"""Continuous-batching serve loop: slot-based KV cache, zero-recompile
steady state, and the ONLINE request lifecycle the serving runtime builds on.

The paper's Split-Brain protocol (§IV-B) makes the ITA device stateless so
the host can multiplex many streams over one immutable datapath; this module
is that host.  It keeps ONE persistent jitted batched decode step alive and
feeds it from a fixed ``(max_slots, ...)`` slot cache:

  admit ──> reserve pages ──> prefill (whole or CHUNKED) ──> insert_slot
    │                                         │
    └── free slot + pages <── EOS / max_new <── masked batched decode
                                               (1 dispatch per token for
                                                ALL active slots)

Slot lifecycle (DESIGN.md §4): a finished request frees its slot in place —
no reallocation, no shape change — and the next pending request is prefilled
into it mid-flight while the other slots keep decoding.  Every compiled
shape is a power-of-two bucket (serve/slots.py), so after warmup the steady
state dispatches exactly one fixed-shape program per token and NEVER
recompiles (asserted with a compile counter in benchmarks/serve_bench.py).

``prefill_chunk=C`` enables *chunked prefill* (DESIGN.md §5): a prompt body
is fed as fixed-width-C chunks, AT MOST ONE chunk per loop iteration, so a
long prompt adds bounded latency to each batched decode step instead of
head-of-line-blocking every decoding slot with a monolithic prefill.

Request lifecycle (DESIGN.md §8) — every request walks the state machine

  QUEUED ─> PREFILL ─> DECODE ─> DONE
     │          │          ├────> CANCELLED   (cancel(uid), ≤ 1 iteration)
     │          │          ├────> TIMEOUT     (deadline_s exceeded)
     │          │          ├────> FAILED      (quarantined max_strikes times)
     │          │          └────> EVICTED ──> QUEUED   (preemption or
     │          └───> REJECTED                 quarantine, bounded backoff)
     └──> REJECTED / CANCELLED / TIMEOUT

driven by the OPEN-LOOP api: ``submit()`` enqueues, ``step()`` runs one
scheduler iteration (cancellations, deadlines, admission incl. preemption,
one prefill chunk, one masked decode step), ``poll()`` drains terminal
results, ``cancel()`` requests mid-flight cancellation — the slot and its
pages are freed within ONE iteration.  ``run()`` is the closed-loop wrapper
(submit all, step until drained) the offline benchmarks and parity tests
use; serve/server.py wraps the open loop in a thread-queue front end with
per-token streaming.

SLA-aware preemption (``preemption=True``): when the highest-priority
waiting request cannot be admitted — no free slot, or the page pool refuses
— the scheduler evicts a strictly-lower-priority victim (lowest priority
class first, most recently admitted within it: least work lost).  Eviction
publishes the victim's completed full pages into the radix prefix index
FIRST (prefix-armed engines), so re-admission re-prefills almost nothing,
then frees the slot and pages (shared pages only lose one refcount — the
PR-5 CoW rule means eviction can never corrupt another stream) and
re-queues the victim with bounded exponential backoff
(``backoff_steps * 2**(evictions-1)`` iterations, capped).  A resumed
victim re-enters admission with prompt+generated-so-far as its effective
prompt, so greedy decode continues token-identically.

Failures are RECOVERABLE per request: any ``SchedulerError``
(serve/errors.py) raised while admitting or prefilling one request —
including faults injected by serve/faults.py — releases its slot, reserved
pages and radix refcounts and degrades that one request to a REJECTED
entry; every other stream keeps decoding.  Unknown exceptions still
propagate after the same cleanup.

TrafficMeter accounting stays byte-exact per *active* token: every token
that actually crosses the boundary — prefill (minus prefix-cached), decode,
re-prefill after eviction, even chunks computed by a job that later failed
— is replayed on the meter, so measured bytes always equal
``(prefill_tokens + decoded_tokens) * bytes_per_token`` and, for runs with
no eviction/abort, the classic per-request identity
``sum(T0 - 1 - cached + gen)`` (tests/test_scheduler.py).
"""
from __future__ import annotations

import dataclasses
import enum
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.serve.errors import DeviceError, SchedulerError

__all__ = ["Request", "RequestResult", "RejectedRequest", "RequestState",
           "ContinuousBatchingScheduler"]


class RequestState(str, enum.Enum):
    """The request lifecycle's states (DESIGN.md §8).  Terminal states are
    DONE / CANCELLED / TIMEOUT / REJECTED / FAILED; EVICTED is transient
    (the victim re-queues) and shows up only as
    ``RequestResult.preemptions > 0``.  FAILED is the quarantine terminal:
    a request whose decode step kept producing non-finite logits through
    ``max_strikes`` retries (DESIGN.md §12)."""
    QUEUED = "QUEUED"
    PREFILL = "PREFILL"
    DECODE = "DECODE"
    DONE = "DONE"
    CANCELLED = "CANCELLED"
    EVICTED = "EVICTED"
    TIMEOUT = "TIMEOUT"
    REJECTED = "REJECTED"
    FAILED = "FAILED"


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (T0,) int32
    max_new: int = 16
    arrival_s: float = 0.0        # offset from serve-loop start
    priority: int = 0             # higher = more important (SLA class)
    deadline_s: Optional[float] = None   # absolute loop-clock deadline
    stream: Optional[Callable[[int], None]] = None  # per-token callback


@dataclasses.dataclass
class RequestResult:
    uid: int
    tokens: np.ndarray            # (gen_len,) int32 — exactly what was generated
    gen_len: int
    prompt_len: int
    admitted_s: float             # first admission (-1.0 if never admitted)
    finished_s: float
    cached_tokens: int = 0        # prompt tokens served from the prefix cache
    queue_wait_s: float = 0.0     # arrival (or loop start) -> first admission
    ttft_s: float = 0.0           # arrival (or loop start) -> first token
    state: str = "DONE"           # terminal RequestState value
    preemptions: int = 0          # times evicted + resumed on the way here


@dataclasses.dataclass
class RejectedRequest:
    uid: int
    reason: str


@dataclasses.dataclass
class _ReqRecord:
    """Per-request lifetime record, persistent across evictions: generated
    tokens accumulate here, so a resumed victim's effective prompt is
    ``prompt + tokens`` and its remaining budget ``max_new - len(tokens)``."""
    req: Request
    tokens: List[int] = dataclasses.field(default_factory=list)
    cached: int = 0               # cumulative prefix-cache hits (tokens)
    preemptions: int = 0
    strikes: int = 0              # quarantines (non-finite logits) so far
    not_before: int = 0           # earliest re-admission ITERATION (backoff)
    admitted_s: Optional[float] = None    # first admission
    first_token_s: Optional[float] = None


@dataclasses.dataclass
class _SlotState:
    rec: _ReqRecord
    tenure_s: float               # THIS tenure's admission (victim ordering)


@dataclasses.dataclass
class _PrefillJob:
    """A request whose (effective) prompt is being fed chunk-by-chunk into
    a B=1 cache (the slot is held but inactive until the last chunk is
    inserted).  ``cached`` prompt tokens were served from the prefix cache:
    the B=1 cache was seeded with them and the chunk stream starts there."""
    slot: int
    rec: _ReqRecord
    prompt: np.ndarray            # effective prompt (original + resumed)
    cache: Any
    consumed: int
    tenure_s: float
    cached: int = 0


class ContinuousBatchingScheduler:
    """Slot-based continuous batching over one persistent decode program.

    ``realtime=True`` honours ``Request.arrival_s`` against the wall clock
    (Poisson-arrival benchmarking); ``realtime=False`` treats arrivals as an
    admission ORDER only and admits as fast as slots free up (deterministic,
    used by the parity tests).

    ``prefill_chunk=C`` feeds prompt bodies as width-C chunks interleaved
    with decode steps (at most one chunk per iteration).  C must divide the
    engine's ``max_len``.  ``max_prefill_jobs`` bounds how many in-flight
    chunked prefills may exist at once — each holds a dense B=1 request
    cache until insertion, so the cap also bounds that resident memory
    (1/max_slots of the dense slot cache per job).

    ``preemption=True`` arms SLA-aware eviction (module docstring);
    ``backoff_steps``/``backoff_cap`` bound the evicted victim's
    exponential re-admission backoff in scheduler iterations.  ``faults``
    takes a :class:`repro.serve.faults.FaultInjector` whose seeded failure
    points the loop must absorb gracefully.
    """

    def __init__(self, engine, max_slots: int = 8,
                 eos_id: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 max_prefill_jobs: int = 2,
                 preemption: bool = False,
                 backoff_steps: int = 2,
                 backoff_cap: int = 32,
                 max_strikes: int = 3,
                 faults=None):
        self.engine = engine
        self.max_slots = int(max_slots)
        self.eos_id = eos_id
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be a positive chunk width, "
                f"got {prefill_chunk}")
        self.prefill_chunk = prefill_chunk
        if max_prefill_jobs < 1:
            raise ValueError(
                f"max_prefill_jobs must be >= 1, got {max_prefill_jobs}")
        self.max_prefill_jobs = int(max_prefill_jobs)
        self.preemption = bool(preemption)
        if backoff_steps < 1 or backoff_cap < backoff_steps:
            raise ValueError(
                f"backoff must satisfy 1 <= backoff_steps <= backoff_cap, "
                f"got {backoff_steps}/{backoff_cap}")
        self.backoff_steps = int(backoff_steps)
        self.backoff_cap = int(backoff_cap)
        if max_strikes < 1:
            raise ValueError(f"max_strikes must be >= 1, got {max_strikes}")
        self.max_strikes = int(max_strikes)
        self.faults = faults
        self.cache = None
        self._began = False

    # ----------------------------------------------------------- loop state
    def begin(self) -> None:
        """(Re)initialize the serving state: fresh slot cache, empty queues,
        zeroed counters, loop clock anchored NOW.  ``run()`` calls this
        itself; the open-loop api (``submit``/``step``/``poll``) calls it
        lazily on first use — call it explicitly to drop leftover state."""
        eng = self.engine
        n = self.max_slots
        self.cache = eng.init_slot_cache(n)
        self._tokens = np.zeros((n,), np.int32)
        self._active = np.zeros((n,), bool)
        self._states: Dict[int, _SlotState] = {}
        self._prefilling: deque = deque()          # _PrefillJob FIFO
        self._free = list(range(n - 1, -1, -1))
        self._pending: List[_ReqRecord] = []
        self._results: List[RequestResult] = []
        self._rejected: List[RejectedRequest] = []
        self._cancels: set = set()
        self._iterations = 0          # every step() (backoff clock)
        self._decode_steps = 0        # decode dispatches only
        self._decoded_tokens = 0
        self._prefill_tokens = 0
        self._cached_tokens = 0
        self._preempt_count = 0
        self._quarantines = 0
        self._failed_count = 0
        self._recoveries = 0
        self._last_recovery_s = 0.0
        self.recovery_log: List[Dict[str, Any]] = []
        self._unmetered = 0
        self._slept_s = 0.0
        self._t_start = time.perf_counter()
        self._began = True

    def _ensure_began(self) -> None:
        if not self._began:
            self.begin()

    def _now(self) -> float:
        return time.perf_counter() - self._t_start

    def clock(self) -> float:
        """The loop clock (seconds since ``begin``): the timebase of
        ``arrival_s`` and ``deadline_s``."""
        self._ensure_began()
        return self._now()

    def has_work(self) -> bool:
        """Anything queued, prefilling or decoding."""
        if not self._began:
            return False
        return bool(self._pending or self._states or self._prefilling)

    def decoding_uids(self) -> List[int]:
        """Uids currently in DECODE, slot order (fault-burst targeting)."""
        return [self._states[s].rec.req.uid
                for s in sorted(self._states) if self._active[s]]

    # ------------------------------------------------------------- admission
    def submit(self, req: Request) -> bool:
        """Enqueue one request (open-loop entry).  Malformed requests are
        rejected immediately with a readable reason (False); accepted ones
        enter QUEUED (True) and terminate through ``poll()``."""
        self._ensure_began()
        reason = self._invalid_reason(req)
        if reason is not None:
            self._rejected.append(RejectedRequest(req.uid, reason))
            return False
        self._pending.append(_ReqRecord(req))
        return True

    def cancel(self, uid: int) -> None:
        """Request cancellation of ``uid``: honoured within ONE scheduler
        iteration, whatever state the request is in — queued, prefilling or
        decoding — and its slot + pages are freed there and then.  Unknown
        or already-terminal uids are ignored."""
        self._ensure_began()
        self._cancels.add(int(uid))

    def poll(self) -> List[RequestResult]:
        """Drain terminal results produced since the last poll (flushes the
        pending meter replay so open-loop traffic accounting stays exact)."""
        self._ensure_began()
        self._flush_meter()
        out = self._results
        self._results = []
        return out

    def poll_rejected(self) -> List[RejectedRequest]:
        """Drain rejections (validation failures and mid-flight REJECTED)."""
        self._ensure_began()
        out = self._rejected
        self._rejected = []
        return out

    def _invalid_reason(self, r: Request) -> Optional[str]:
        try:
            prompt = np.asarray(r.prompt)
            T0 = int(prompt.shape[0]) if prompt.ndim == 1 else -1
        except Exception:
            return "prompt is not array-like"
        if prompt.ndim != 1:
            return f"prompt must be 1-D, got shape {prompt.shape}"
        if T0 < 1:
            return ("empty prompt: a request needs at least one token to "
                    "seed decoding")
        if r.max_new < 1:
            return f"max_new={r.max_new} asks for no output tokens"
        if T0 - 1 + r.max_new > self.engine.max_len:
            return (f"request does not fit the cache: prompt_len={T0} + "
                    f"max_new={r.max_new} needs {T0 - 1 + r.max_new} "
                    f"positions but max_len={self.engine.max_len}")
        return None

    def _effective(self, rec: _ReqRecord):
        """The (prompt, max_new) a record admits with: a resumed victim
        re-prefills its original prompt PLUS everything it already
        generated, so greedy decode continues token-identically."""
        if not rec.tokens:
            return np.asarray(rec.req.prompt, np.int32), rec.req.max_new
        prompt = np.concatenate([np.asarray(rec.req.prompt, np.int32),
                                 np.asarray(rec.tokens, np.int32)])
        return prompt, rec.req.max_new - len(rec.tokens)

    # --------------------------------------------------------- terminalizers
    def _make_result(self, rec: _ReqRecord, state: RequestState
                     ) -> RequestResult:
        t = self._now()
        first = rec.first_token_s
        return RequestResult(
            uid=rec.req.uid,
            tokens=np.asarray(rec.tokens, np.int32),
            gen_len=len(rec.tokens),
            prompt_len=len(rec.req.prompt),
            admitted_s=rec.admitted_s if rec.admitted_s is not None else -1.0,
            finished_s=t,
            cached_tokens=rec.cached,
            queue_wait_s=max(0.0, (rec.admitted_s if rec.admitted_s
                                   is not None else t) - rec.req.arrival_s),
            ttft_s=(max(0.0, first - rec.req.arrival_s)
                    if first is not None else 0.0),
            state=state.value,
            preemptions=rec.preemptions)

    def _finish_record(self, rec: _ReqRecord, state: RequestState) -> None:
        self._results.append(self._make_result(rec, state))

    def _release_slot(self, slot: int) -> None:
        """Return a slot (and its pages) to the free pool — the single
        release point every terminal path funnels through, so pages can
        never leak past the iteration that retired the request."""
        self._active[slot] = False
        if slot not in self._free:
            self._free.append(slot)
        if hasattr(self.engine, "free_slot"):
            self.engine.free_slot(slot)

    def _finish_slot(self, slot: int, state: RequestState) -> None:
        st = self._states.pop(slot)
        self._release_slot(slot)
        self._finish_record(st.rec, state)

    def _abort_job(self, job: _PrefillJob, state: RequestState,
                   reason: Optional[str] = None) -> None:
        """Tear down an in-flight prefill job: account the chunks it DID
        compute (they crossed the boundary), release the slot, reserved
        pages and radix refcounts, and terminalize the record."""
        try:
            self._prefilling.remove(job)
        except ValueError:
            pass
        computed = job.consumed - job.cached
        self._prefill_tokens += computed
        self._unmetered += computed
        self._release_slot(job.slot)
        if state is RequestState.REJECTED:
            self._rejected.append(RejectedRequest(
                job.rec.req.uid, reason or "prefill failed"))
        else:
            self._finish_record(job.rec, state)

    def _reject_record(self, rec: _ReqRecord, reason: str) -> None:
        self._rejected.append(RejectedRequest(rec.req.uid, reason))

    def _reject_pool(self, rec: _ReqRecord) -> None:
        prompt, max_new = self._effective(rec)
        self._pending.remove(rec)
        self._reject_record(
            rec,
            "request does not fit the KV page pool even with every "
            f"slot idle (prompt_len={len(prompt)}, max_new={max_new})")

    # ------------------------------------------------- cancellation/deadline
    def _apply_cancellations(self) -> None:
        if not self._cancels:
            return
        uids = self._cancels
        self._cancels = set()
        for rec in [r for r in self._pending if r.req.uid in uids]:
            self._pending.remove(rec)
            self._finish_record(rec, RequestState.CANCELLED)
        for job in [j for j in list(self._prefilling)
                    if j.rec.req.uid in uids]:
            self._abort_job(job, RequestState.CANCELLED)
        for slot in [s for s, st in self._states.items()
                     if st.rec.req.uid in uids]:
            self._finish_slot(slot, RequestState.CANCELLED)

    def _expire_deadlines(self) -> None:
        now = self._now()

        def expired(req: Request) -> bool:
            return req.deadline_s is not None and now > req.deadline_s

        for rec in [r for r in self._pending if expired(r.req)]:
            self._pending.remove(rec)
            self._finish_record(rec, RequestState.TIMEOUT)
        for job in [j for j in list(self._prefilling) if expired(j.rec.req)]:
            self._abort_job(job, RequestState.TIMEOUT)
        for slot in [s for s, st in self._states.items()
                     if expired(st.rec.req)]:
            self._finish_slot(slot, RequestState.TIMEOUT)

    # ------------------------------------------------- preemption (SLA-aware)
    def _preempt_for(self, rec: _ReqRecord) -> bool:
        """Evict ONE victim of strictly lower priority than ``rec`` —
        lowest priority class first, most recently admitted within it
        (least work lost).  Returns True if a victim was evicted (its slot
        and pages are free now)."""
        if not self.preemption:
            return False
        prio = rec.req.priority
        best = None
        for slot, st in self._states.items():
            if st.rec.req.priority < prio:
                key = (st.rec.req.priority, -st.tenure_s)
                if best is None or key < best[0]:
                    best = (key, ("slot", slot))
        for job in self._prefilling:
            if job.rec.req.priority < prio:
                key = (job.rec.req.priority, -job.tenure_s)
                if best is None or key < best[0]:
                    best = (key, ("job", job))
        if best is None:
            return False
        kind, target = best[1]
        if kind == "slot":
            self._evict_slot(target)
        else:
            self._evict_job(target)
        return True

    def _requeue(self, rec: _ReqRecord) -> None:
        rec.preemptions += 1
        self._preempt_count += 1
        backoff = min(self.backoff_steps * (2 ** (rec.preemptions - 1)),
                      self.backoff_cap)
        rec.not_before = self._iterations + backoff
        self._pending.append(rec)

    def _evict_slot(self, slot: int) -> None:
        """EVICTED -> QUEUED for a decoding victim: publish its completed
        full pages FIRST (prefix-armed engines make re-admission near-free;
        shared pages merely lose one refcount — the CoW rule keeps every
        other stream untouched), then free the slot + pages and re-queue
        with backoff."""
        st = self._states.pop(slot)
        eng = self.engine
        if hasattr(eng, "publish_prefix"):
            prompt, _ = self._effective(st.rec)
            eng.publish_prefix(slot, prompt)
        self._release_slot(slot)
        self._requeue(st.rec)

    def _evict_job(self, job: _PrefillJob) -> None:
        """Evict a still-prefilling victim: the chunks it computed are
        accounted (they crossed the boundary) and it restarts from
        admission later."""
        try:
            self._prefilling.remove(job)
        except ValueError:
            pass
        computed = job.consumed - job.cached
        self._prefill_tokens += computed
        self._unmetered += computed
        self._release_slot(job.slot)
        self._requeue(job.rec)

    # ------------------------------------------------------------- admission
    def _pick_pending(self, realtime: bool) -> Optional[_ReqRecord]:
        """Highest-priority eligible record (ties: earliest arrival, then
        uid).  Realtime gates on the wall clock; backoff gates evicted
        victims on the iteration clock either way."""
        now = self._now() if realtime else 0.0
        best = None
        for rec in self._pending:
            if realtime and rec.req.arrival_s > now:
                continue
            if rec.not_before > self._iterations:
                continue
            key = (-rec.req.priority, rec.req.arrival_s, rec.req.uid)
            if best is None or key < best[0]:
                best = (key, rec)
        return best[1] if best else None

    def _try_admit(self, rec: _ReqRecord, slot: int):
        """One admission attempt into ``slot``: returns the cached-token
        count, or None on pool pressure.  The fault injector's admission
        point sits BEFORE real admission, so an injected refusal takes no
        resources (``(None, True)`` marks it injected: transient by
        construction, never grounds for rejection)."""
        eng = self.engine
        if (self.faults is not None
                and self.faults.admission_fault(rec.req.uid)):
            return None, True
        prompt, max_new = self._effective(rec)
        if hasattr(eng, "admit_slot"):
            return eng.admit_slot(slot, prompt, max_new,
                                  self.prefill_chunk), False
        if hasattr(eng, "reserve_slot"):
            ok = eng.reserve_slot(slot, len(prompt), max_new)
            return (0 if ok else None), False
        return 0, False

    def _in_flight(self) -> bool:
        return bool(self._states) or bool(self._prefilling)

    def _admit(self, realtime: bool) -> None:
        eng = self.engine
        chunk = self.prefill_chunk
        while True:
            rec = self._pick_pending(realtime)
            if rec is None:
                break
            prompt, max_new = self._effective(rec)
            if (chunk is not None and len(prompt) > 1
                    and len(self._prefilling) >= self.max_prefill_jobs):
                break   # bound the resident B=1 prefill caches
            if (hasattr(eng, "can_ever_admit")
                    and not eng.can_ever_admit(len(prompt), max_new)):
                # statically impossible (exceeds the pool itself): reject
                # NOW instead of head-of-line blocking the queue behind a
                # request no amount of frees can admit
                self._reject_pool(rec)
                continue
            if not self._free and not self._preempt_for(rec):
                break                      # every slot busy, no victim
            slot = self._free[-1]
            cached, injected = self._try_admit(rec, slot)
            while cached is None and not injected:
                # pool pressure: evict strictly-lower-priority victims
                # until the request fits or none remain
                if not self._preempt_for(rec):
                    break
                prompt, max_new = self._effective(rec)
                if hasattr(eng, "admit_slot"):
                    cached = eng.admit_slot(slot, prompt, max_new, chunk)
                elif eng.reserve_slot(slot, len(prompt), max_new):
                    cached = 0
            if cached is None:
                if injected or self._in_flight():
                    break     # wait for running requests to free resources
                # backstop: an idle pool that still refuses can never admit
                self._reject_pool(rec)
                continue
            self._pending.remove(rec)
            self._free.remove(slot)
            self._start(rec, slot, cached)

    def _activate(self, slot: int, rec: _ReqRecord, tok: int,
                  tenure_s: float) -> None:
        self._tokens[slot] = tok
        self._active[slot] = True
        self._states[slot] = _SlotState(rec, tenure_s)

    def _start(self, rec: _ReqRecord, slot: int, cached: int) -> None:
        """Move an admitted record into PREFILL (or straight to DECODE).
        Any ``SchedulerError`` between here and activation — the window
        where the slot holds reserved pages and radix refcounts — releases
        everything and degrades the one request to REJECTED; unknown
        exceptions propagate after the same cleanup."""
        eng = self.engine
        prompt, _ = self._effective(rec)
        body = len(prompt) - 1
        now = self._now()
        if rec.admitted_s is None:
            rec.admitted_s = now
        self._cached_tokens += cached
        rec.cached += cached
        try:
            if cached > 0:
                # prefix hit: seed a B=1 request cache with the matched
                # pages gathered from the pool; only the unmatched tail is
                # prefilled (chunk stream continuing at position ``cached``)
                seeded = eng.seed_request_cache(self.cache, slot, cached)
                if cached < body:
                    self._prefilling.append(_PrefillJob(
                        slot, rec, prompt, seeded, cached, now, cached))
                    return
                # whole-body hit: nothing to prefill, go straight to decode
                self.cache = eng.insert_slot(self.cache, seeded, slot)
                eng.publish_prefix(slot, prompt)
                self._activate(slot, rec, int(prompt[-1]), now)
                return
            if self.prefill_chunk is not None and body > 0:
                self._prefilling.append(_PrefillJob(
                    slot, rec, prompt, eng.new_request_cache(), 0, now))
                return
            slot_cache, tok = eng.prefill_slot(prompt)
            self.cache = eng.insert_slot(self.cache, slot_cache, slot)
            if hasattr(eng, "publish_prefix"):
                eng.publish_prefix(slot, prompt)
            self._prefill_tokens += body
            self._unmetered += body
            self._activate(slot, rec, tok, now)
        except SchedulerError as e:
            self._release_slot(slot)
            self._reject_record(rec, f"prefill failed: {e}")
        except Exception:
            self._release_slot(slot)
            raise

    # -------------------------------------------------------- prefill/decode
    def _prefill_tick(self) -> None:
        """At most ONE chunk per iteration, so a long prompt adds bounded
        latency per decode step.  The fault injector may stall the job
        (chunk withheld) or make it throw; a thrown job releases its slot,
        pages and refcounts and becomes a REJECTED entry."""
        if not self._prefilling:
            return
        eng = self.engine
        chunk = self.prefill_chunk
        job = self._prefilling[0]
        uid = job.rec.req.uid
        if self.faults is not None and self.faults.prefill_stalled(uid):
            return
        body = len(job.prompt) - 1
        try:
            if self.faults is not None:
                self.faults.prefill_fault(uid)
            w = min(chunk, body - job.consumed)
            buf = np.zeros((chunk,), np.int32)
            buf[:w] = job.prompt[job.consumed:job.consumed + w]
            job.cache = eng.prefill_chunk_slot(job.cache, buf, w)
            job.consumed += w
            if job.consumed == body:
                self._prefilling.popleft()
                self.cache = eng.insert_slot(self.cache, job.cache, job.slot)
                if hasattr(eng, "publish_prefix"):
                    eng.publish_prefix(job.slot, job.prompt)
                self._prefill_tokens += body - job.cached
                self._unmetered += body - job.cached
                self._activate(job.slot, job.rec, int(job.prompt[-1]),
                               job.tenure_s)
        except SchedulerError as e:
            self._abort_job(job, RequestState.REJECTED,
                            reason=f"prefill failed: {e}")
        except Exception:
            self._abort_job(job, RequestState.REJECTED,
                            reason="prefill failed: unrecoverable")
            raise

    def _decode_tick(self) -> None:
        if not self._active.any():
            return
        eng = self.engine
        n_active = int(self._active.sum())
        corrupt = None
        if self.faults is not None:
            self.faults.step_stall()
            self.faults.step_fault()       # may raise StepError/DeviceLost
            bad = self.faults.corrupt_uids(self.decoding_uids())
            if bad:
                corrupt = np.zeros_like(self._active)
                for slot, st in self._states.items():
                    if st.rec.req.uid in bad:
                        corrupt[slot] = True
        nxt, ok, self.cache = eng.decode_slots(self.cache, self._tokens,
                                               self._active, corrupt)
        self._decode_steps += 1
        self._decoded_tokens += n_active
        self._unmetered += n_active
        nxt = np.asarray(nxt)
        okh = np.asarray(ok)
        t_step = self._now()
        for slot in np.flatnonzero(self._active):
            st = self._states[slot]
            rec = st.rec
            if not okh[slot]:
                # the sentinel flagged non-finite logits: the token is
                # garbage — quarantine the slot instead of appending it
                self._quarantine_slot(slot)
                continue
            tok = int(nxt[slot])
            if rec.first_token_s is None:
                rec.first_token_s = t_step
            rec.tokens.append(tok)
            if rec.req.stream is not None:
                try:
                    rec.req.stream(tok)
                except Exception:
                    # a throwing consumer is a gone consumer: cancel its
                    # request next iteration, keep every other stream alive
                    self._cancels.add(rec.req.uid)
            done = (len(rec.tokens) >= rec.req.max_new
                    or (self.eos_id is not None and tok == self.eos_id))
            if done:
                self._finish_slot(slot, RequestState.DONE)
            else:
                self._tokens[slot] = tok

    def _quarantine_slot(self, slot: int) -> None:
        """Quarantine a slot whose logits went non-finite: the device-side
        bytes this request touched are suspect, so its pages are freed
        WITHOUT publishing them into the prefix index (a poisoned prefix
        would spread to every future sharer), and the request re-queues
        with strike-keyed bounded backoff.  After ``max_strikes`` strikes
        it degrades to the terminal FAILED state — a deterministically-
        corrupting request must not retry forever — while its batchmates
        keep decoding untouched.  Strikes are counted separately from
        preemptions: an evicted victim did nothing wrong."""
        st = self._states.pop(slot)
        rec = st.rec
        self._release_slot(slot)
        rec.strikes += 1
        self._quarantines += 1
        self.recovery_log.append({
            "event": "quarantine", "uid": rec.req.uid,
            "iteration": self._iterations, "strikes": rec.strikes})
        if rec.strikes >= self.max_strikes:
            self._failed_count += 1
            self.recovery_log.append({
                "event": "failed", "uid": rec.req.uid,
                "iteration": self._iterations,
                "reason": f"StepCorruption: non-finite logits in "
                          f"{rec.strikes} decode attempts"})
            self._finish_record(rec, RequestState.FAILED)
            return
        rec.not_before = self._iterations + min(
            self.backoff_steps * (2 ** (rec.strikes - 1)), self.backoff_cap)
        self._pending.append(rec)

    # ------------------------------------------------------------- recovery
    def recover(self, reason: str = "device fault") -> None:
        """Rebuild the device half of the world from host-authoritative
        state after a device failure (DESIGN.md §12).

        The split-brain contract makes this possible: prompts, generated
        tails, page tables and counters all live on the host, so the
        device's arrays are disposable.  Every in-flight request — decoding
        slots AND chunked-prefill jobs — goes back to QUEUED with its
        generated tail intact (``_effective`` re-prefills prompt+tail, so
        greedy decode resumes bitwise token-identically) and WITHOUT a
        preemption or strike charge: the device failed, not the request.
        The engine then ``rebuild()``s params + pool; the prefix index dies
        with the pool (its device bytes are gone) and re-forms as recovered
        requests republish.  Compiled programs are untouched — recovery
        costs zero recompiles (gated in serve_bench)."""
        self._ensure_began()
        t0 = time.perf_counter()
        n_requeued = 0
        for slot in sorted(self._states):
            st = self._states.pop(slot)
            self._pending.append(st.rec)
            n_requeued += 1
        while self._prefilling:
            job = self._prefilling.popleft()
            computed = job.consumed - job.cached
            self._prefill_tokens += computed
            self._unmetered += computed
            self._pending.append(job.rec)
            n_requeued += 1
        self.cache = None            # the old device arrays are gone
        eng = self.engine
        n = self.max_slots
        if hasattr(eng, "rebuild"):
            self.cache = eng.rebuild(n)
        else:
            self.cache = eng.init_slot_cache(n)
        self._tokens = np.zeros((n,), np.int32)
        self._active = np.zeros((n,), bool)
        self._free = list(range(n - 1, -1, -1))
        self._recoveries += 1
        dt = time.perf_counter() - t0
        self._last_recovery_s = dt
        self.recovery_log.append({
            "event": "recover", "reason": str(reason),
            "iteration": self._iterations, "requeued": n_requeued,
            "recovery_s": dt})

    # ------------------------------------------------------------ open loop
    def step(self, realtime: bool = False) -> List[RequestResult]:
        """ONE scheduler iteration: fault hooks, cancellations, deadlines,
        admission (with preemption), one prefill chunk, one masked decode
        step.  Returns the results that reached a terminal state during
        this iteration (they also stay queued for ``poll()``)."""
        self._ensure_began()
        n0 = len(self._results)
        if self.faults is not None:
            self.faults.on_step(self)
        self._apply_cancellations()
        self._expire_deadlines()
        self._admit(realtime)
        self._prefill_tick()
        try:
            self._decode_tick()
        except DeviceError as e:
            # a typed device failure is survivable by construction: every
            # byte of dynamic state has a host copy — rebuild and resume
            self.recover(reason=f"{type(e).__name__}: {e}")
        self._iterations += 1
        return self._results[n0:]

    def _flush_meter(self) -> None:
        """Replay the accumulated active-token boundary crossings on the
        meter (aggregate form — crossings are linear in count, so one
        replay is byte-identical to per-step logging).  Prefix-cached
        prompt tokens never cross: their K/V was neither recomputed nor
        re-shipped (the saved bytes land on the excluded
        "prefix_prefill_saved" host channel instead, so the eq. 7-10
        exactness contract holds with the cache on or off)."""
        if self._unmetered:
            self.engine.meter_tokens(self._unmetered)
            self._unmetered = 0

    # ------------------------------------------------------------ serve loop
    def run(self, requests: List[Request],
            realtime: bool = False) -> Dict[str, Any]:
        """Closed loop: serve every request to a terminal state; returns
        results + loop stats.

        ``wall_s`` includes realtime arrival sleeps; ``busy_s`` counts only
        time spent doing work, and both tokens/s figures are reported so an
        idle-heavy Poisson run can't masquerade as an efficient one.
        """
        self.begin()
        for r in sorted(requests, key=lambda r: (r.arrival_s, r.uid)):
            self.submit(r)
        while self.has_work():
            self.step(realtime=realtime)
            if (realtime and not self._active.any()
                    and not self._prefilling and self._pending):
                nxt = min(r.req.arrival_s for r in self._pending)
                dt = nxt - self._now()
                if dt > 0:
                    t0 = time.perf_counter()
                    time.sleep(dt)
                    self._slept_s += time.perf_counter() - t0
        wall_s = self._now()
        busy_s = wall_s - self._slept_s
        self._flush_meter()
        results = self._results
        self._results = []
        results.sort(key=lambda r: r.uid)
        by_state: Dict[str, int] = {}
        for r in results:
            by_state[r.state] = by_state.get(r.state, 0) + 1
        return {
            "results": results,
            "rejected": self._rejected,
            "steps": self._decode_steps,
            "iterations": self._iterations,
            "decoded_tokens": self._decoded_tokens,
            "prefill_tokens": self._prefill_tokens,
            "cached_prompt_tokens": self._cached_tokens,
            "preemptions": self._preempt_count,
            "quarantines": self._quarantines,
            "failed": self._failed_count,
            "recoveries": self._recoveries,
            "last_recovery_s": self._last_recovery_s,
            "by_state": by_state,
            "wall_s": wall_s,
            "busy_s": busy_s,
            "slept_s": self._slept_s,
            "tokens_per_s": self._decoded_tokens / wall_s if wall_s else 0.0,
            "requests_per_s": len(results) / wall_s if wall_s else 0.0,
            "tokens_per_s_busy":
                self._decoded_tokens / busy_s if busy_s else 0.0,
            "requests_per_s_busy":
                len(results) / busy_s if busy_s else 0.0,
        }

    def warmup(self, prompt_len: int = 4, max_new: int = 2) -> None:
        """Compile the steady-state programs (prefill bucket / chunk,
        insert, slot step) before timing starts; leaves the TrafficMeter
        untouched.

        With an engine whose prefix cache is armed, the warm trace also
        exercises the sharing programs: a page-aligned prompt is published,
        then a whole-prefix repeat of it forces the seed gather AND the CoW
        page copy (its decode append lands inside the shared last page).
        ``max_prefill_jobs`` is pinched to 1 for the warm run so the
        publisher's insert lands before the repeat is admitted — otherwise
        both would miss the index and nothing prefix-specific compiles.
        """
        eng = self.engine
        ps = getattr(eng, "page_size", None)
        reqs = [Request(uid=-1, prompt=np.ones((prompt_len,), np.int32),
                        max_new=max_new)]
        prefix_armed = (hasattr(eng, "prefix_cache_armed")
                        and eng.prefix_cache_armed())
        if prefix_armed and 2 * ps + max_new <= eng.max_len:
            # publisher: body = 2*ps (two publishable full pages);
            # repeat: its full prompt is a strict prefix of the published
            # body -> whole-body match overshooting into the last page
            long = np.arange(1, 2 * ps + 2, dtype=np.int32)   # T0 = 2ps+1
            reqs = [Request(uid=-3, prompt=long, max_new=max_new),
                    Request(uid=-2, prompt=long[:2 * ps].copy(),
                            max_new=max_new)]
        jobs = self.max_prefill_jobs
        try:
            if prefix_armed:
                self.max_prefill_jobs = 1
            self.run(reqs)
        finally:
            self.max_prefill_jobs = jobs
        self.engine.meter.reset()
