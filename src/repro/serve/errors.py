"""Typed, recoverable serving errors: the ``SchedulerError`` hierarchy.

The serve hot path must never die for one bad request: every failure a
single request can cause — malformed input, a reservation bug surfacing on
its pages, a prefill job throwing mid-chunk, an injected fault — raises a
``SchedulerError`` subclass, and the scheduler degrades that ONE request to
a ``REJECTED`` terminal result (releasing its slot, reserved pages and
radix refcounts) while every other stream keeps decoding.  Anything that is
NOT a ``SchedulerError`` still propagates after the same resource cleanup:
an unknown exception means the loop's own state may be suspect, and hiding
it would trade a crash for silent corruption.

``PageLifecycleError`` doubles as a ``ValueError`` so pre-existing callers
(and tests) that treat pool misuse as ``ValueError`` keep working.
"""
from __future__ import annotations

__all__ = [
    "SchedulerError",
    "InvalidRequestError",
    "AdmissionError",
    "PrefillError",
    "InjectedFault",
    "ReservationError",
    "PageLifecycleError",
    "DeviceError",
    "StepError",
    "StepCorruption",
    "DeviceLost",
]


class SchedulerError(Exception):
    """Base of every recoverable per-request serving failure."""


class InvalidRequestError(SchedulerError):
    """The request itself is malformed (empty prompt, bad shape/dtype,
    non-positive max_new): rejectable before any resource is taken."""


class AdmissionError(SchedulerError):
    """The request can never be admitted (exceeds the pool or the slot
    table even when idle) — rejected instead of head-of-line blocking."""


class PrefillError(SchedulerError):
    """A prefill job failed mid-flight; the slot, reserved pages and any
    radix-admission refcounts have been released by the scheduler."""


class InjectedFault(PrefillError):
    """A deterministic fault-injection event (serve/faults.py): behaves
    exactly like a real prefill failure so graceful degradation is a
    tested property, not a hope."""


class ReservationError(SchedulerError):
    """A page-pool reservation invariant broke on this slot's lifecycle
    (drew past its worst-case reservation, no CoW headroom).  Raised — not
    asserted — so ``python -O`` cannot strip the check and the scheduler
    can quarantine the one request instead of dying."""


class PageLifecycleError(SchedulerError, ValueError):
    """Pool lifecycle misuse (double free, reserve-after-reserve).  Also a
    ``ValueError`` for callers that predate the hierarchy."""


class DeviceError(SchedulerError):
    """Base of device-side failures the host can recover from.  The
    split-brain contract makes the device stateless: every byte of dynamic
    state has a host-authoritative copy, so a device failure is survivable
    by rebuilding device arrays from host state (``scheduler.recover()``)
    rather than fatal."""


class StepError(DeviceError):
    """The persistent decode step raised (driver fault, launch failure).
    The slot cache that was donated into the failed dispatch is suspect;
    recovery rebuilds it from host state."""


class StepCorruption(DeviceError):
    """A slot produced non-finite logits (flipped bits, bad accumulate).
    Detected by the in-step finite-logits sentinel; the affected request is
    quarantined and retried, degrading to FAILED after N strikes."""


class DeviceLost(DeviceError):
    """The engine's device arrays were invalidated wholesale (device
    reset, OOM-kill, preempted accelerator).  Everything device-side —
    params, page pool, slot cache — must be re-materialised from host
    copies before serving can continue."""
