"""Deterministic fault injection for the online serving runtime.

Graceful degradation is a tested property, not a hope: a seeded
:class:`FaultInjector` plugs into ``ContinuousBatchingScheduler`` (the
``faults=`` knob) and perturbs the loop at four injection points, all
driven by one ``numpy`` PRNG so a (plan, seed) pair replays the exact same
fault sequence every run — the chaos-smoke CI job sweeps a small seed
matrix over the same suite:

  admission     — the next N admissions (or a Bernoulli rate) spuriously
                  report pool pressure: the scheduler must wait/preempt/
                  retry, never crash or wrongly reject.
  pool_squeeze  — a window of scheduler iterations during which EVERY
                  admission reports exhaustion (the pool "filled up"),
                  exercising queue growth and deadline timeouts under
                  sustained pressure.
  prefill       — a chunked-prefill job raises ``InjectedFault`` mid-chunk
                  (probabilistic or targeted by uid): the scheduler must
                  release the slot, reserved pages and radix refcounts and
                  degrade the one request to REJECTED; or a job STALLS for
                  k iterations (its chunks stop arriving), exercising the
                  deadline machinery against a wedged prefill.
  cancel_burst  — at a chosen iteration, a seeded fraction of the
                  requests currently DECODING are cancelled at once
                  (mid-decode cancellation burst); their pages must return
                  within one scheduler iteration.

Every fired event is recorded in ``events`` (name, uid/iteration) so tests
can assert the fault actually happened — a chaos test that silently
injected nothing proves nothing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.errors import InjectedFault

__all__ = ["FaultPlan", "FaultInjector"]


@dataclasses.dataclass
class FaultPlan:
    """What to inject; all points default off so a plan enables only the
    failure modes a test targets."""
    # admission: first-N hard failures plus an ongoing Bernoulli rate
    admission_failures: int = 0
    admission_fail_rate: float = 0.0
    # pool exhaustion: every admission fails in [at, at + iters)
    pool_squeeze_at: Optional[int] = None
    pool_squeeze_iters: int = 0
    # prefill faults: raise InjectedFault for these uids / at this rate
    prefill_error_uids: Tuple[int, ...] = ()
    prefill_error_rate: float = 0.0
    # stalled prefill: with stall_rate, a job freezes for stall_iters
    stall_rate: float = 0.0
    stall_iters: int = 0
    stall_uids: Tuple[int, ...] = ()
    # mid-decode cancellation burst at one iteration
    cancel_burst_at: Optional[int] = None
    cancel_burst_frac: float = 0.5


class FaultInjector:
    """Seeded, replayable fault source consulted by the scheduler.

    The scheduler calls :meth:`on_step` once per loop iteration (bursts,
    window bookkeeping), :meth:`admission_fault` immediately before real
    admission (True = pretend the pool refused), :meth:`prefill_fault`
    before executing a chunk (may raise :class:`InjectedFault`), and
    :meth:`prefill_stalled` to decide whether a job's chunk is withheld
    this iteration.  All randomness comes from one ``default_rng(seed)``.
    """

    def __init__(self, plan: FaultPlan, seed: int = 0):
        self.plan = plan
        self.seed = int(seed)
        self.rng = np.random.default_rng(seed)
        self.iteration = 0
        self.events: List[Tuple] = []
        self._admission_budget = int(plan.admission_failures)
        self._stalls: Dict[int, int] = {}      # uid -> iterations remaining
        self._stall_decided: Dict[int, bool] = {}
        self._burst_fired = False

    # ------------------------------------------------------------ loop hooks
    def on_step(self, sched) -> None:
        """Called at the top of every scheduler iteration."""
        p = self.plan
        if (p.cancel_burst_at is not None and not self._burst_fired
                and self.iteration >= p.cancel_burst_at):
            self._burst_fired = True
            uids = sched.decoding_uids()
            if uids:
                n = max(1, int(round(len(uids) * p.cancel_burst_frac)))
                picked = self.rng.choice(len(uids), size=min(n, len(uids)),
                                         replace=False)
                for i in sorted(int(j) for j in picked):
                    self.events.append(("cancel_burst", uids[i],
                                        self.iteration))
                    sched.cancel(uids[i])
        for uid in list(self._stalls):
            self._stalls[uid] -= 1
            if self._stalls[uid] <= 0:
                del self._stalls[uid]
        self.iteration += 1

    def _squeezed(self) -> bool:
        p = self.plan
        return (p.pool_squeeze_at is not None
                and p.pool_squeeze_at <= self.iteration
                < p.pool_squeeze_at + p.pool_squeeze_iters)

    def admission_fault(self, uid: int) -> bool:
        """True: report pool pressure for this admission attempt (no real
        resources are taken; the scheduler waits or preempts)."""
        if self._squeezed():
            self.events.append(("pool_squeeze", uid, self.iteration))
            return True
        if self._admission_budget > 0:
            self._admission_budget -= 1
            self.events.append(("admission_fault", uid, self.iteration))
            return True
        if (self.plan.admission_fail_rate > 0.0
                and self.rng.random() < self.plan.admission_fail_rate):
            self.events.append(("admission_fault", uid, self.iteration))
            return True
        return False

    # -------------------------------------------------------- prefill hooks
    def prefill_fault(self, uid: int) -> None:
        """Raise ``InjectedFault`` when this job is scheduled to fail."""
        p = self.plan
        hit = uid in p.prefill_error_uids or (
            p.prefill_error_rate > 0.0
            and self.rng.random() < p.prefill_error_rate)
        if hit:
            self.events.append(("prefill_fault", uid, self.iteration))
            raise InjectedFault(
                f"injected prefill failure for request uid={uid} "
                f"(seed={self.seed}, iteration={self.iteration})")

    def prefill_stalled(self, uid: int) -> bool:
        """True while this job's chunks are withheld (a wedged prefill)."""
        p = self.plan
        if uid not in self._stall_decided:
            stall = uid in p.stall_uids or (
                p.stall_rate > 0.0 and self.rng.random() < p.stall_rate)
            self._stall_decided[uid] = stall
            if stall and p.stall_iters > 0:
                self._stalls[uid] = int(p.stall_iters)
                self.events.append(("stall", uid, self.iteration))
        return uid in self._stalls

    def fired(self, kind: str) -> int:
        """How many events of ``kind`` actually fired (tests assert > 0)."""
        return sum(1 for e in self.events if e[0] == kind)
