"""Deterministic fault injection for the online serving runtime.

Graceful degradation is a tested property, not a hope: a seeded
:class:`FaultInjector` plugs into ``ContinuousBatchingScheduler`` (the
``faults=`` knob) and perturbs the loop at four injection points, all
driven by one ``numpy`` PRNG so a (plan, seed) pair replays the exact same
fault sequence every run — the chaos-smoke CI job sweeps a small seed
matrix over the same suite:

  admission     — the next N admissions (or a Bernoulli rate) spuriously
                  report pool pressure: the scheduler must wait/preempt/
                  retry, never crash or wrongly reject.
  pool_squeeze  — a window of scheduler iterations during which EVERY
                  admission reports exhaustion (the pool "filled up"),
                  exercising queue growth and deadline timeouts under
                  sustained pressure.
  prefill       — a chunked-prefill job raises ``InjectedFault`` mid-chunk
                  (probabilistic or targeted by uid): the scheduler must
                  release the slot, reserved pages and radix refcounts and
                  degrade the one request to REJECTED; or a job STALLS for
                  k iterations (its chunks stop arriving), exercising the
                  deadline machinery against a wedged prefill.
  cancel_burst  — at a chosen iteration, a seeded fraction of the
                  requests currently DECODING are cancelled at once
                  (mid-decode cancellation burst); their pages must return
                  within one scheduler iteration.

and three *device-level* points that exercise the split-brain recovery
seam (the host must survive anything the stateless device does):

  step_error    — the persistent decode step raises ``StepError`` for a
                  window of iterations (driver fault / launch failure):
                  the scheduler must recover() and resume token-identical.
  step_corrupt  — a seeded subset of DECODING requests gets NaN logits
                  inside the jitted step (via the ``corrupt`` mask input)
                  for a window of iterations: the finite-logits sentinel
                  must quarantine exactly those slots, batchmates unharmed.
  device_loss   — at one iteration the engine's device arrays are
                  invalidated wholesale (``DeviceLost``); everything is
                  rebuilt from host-authoritative state.
  step_stall    — one decode step blocks for ``step_stall_s`` seconds (a
                  wedged dispatch) so the OnlineServer watchdog has a real
                  hang to detect.

Every fired event is recorded in ``events`` (name, uid/iteration) so tests
can assert the fault actually happened — a chaos test that silently
injected nothing proves nothing.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serve.errors import DeviceLost, InjectedFault, StepError

__all__ = ["FaultPlan", "FaultInjector"]


@dataclasses.dataclass
class FaultPlan:
    """What to inject; all points default off so a plan enables only the
    failure modes a test targets."""
    # admission: first-N hard failures plus an ongoing Bernoulli rate
    admission_failures: int = 0
    admission_fail_rate: float = 0.0
    # pool exhaustion: every admission fails in [at, at + iters)
    pool_squeeze_at: Optional[int] = None
    pool_squeeze_iters: int = 0
    # prefill faults: raise InjectedFault for these uids / at this rate
    prefill_error_uids: Tuple[int, ...] = ()
    prefill_error_rate: float = 0.0
    # stalled prefill: with stall_rate, a job freezes for stall_iters
    stall_rate: float = 0.0
    stall_iters: int = 0
    stall_uids: Tuple[int, ...] = ()
    # mid-decode cancellation burst at one iteration
    cancel_burst_at: Optional[int] = None
    cancel_burst_frac: float = 0.5
    # device faults: starting at step_error_at, the next step_error_count
    # decode dispatches raise (counted on fires, not iterations — a
    # recovering scheduler spends iterations with nothing decoding)
    step_error_at: Optional[int] = None
    step_error_count: int = 1
    # per-slot logits corruption: a seeded fraction (or explicit uids) of
    # DECODING requests is NaN-corrupted while iteration is in
    # [at, at + iters) — a long window drives the strike/FAILED path, a
    # short one proves transient corruption retries token-identically
    step_corrupt_at: Optional[int] = None
    step_corrupt_iters: int = 1
    step_corrupt_frac: float = 0.5
    step_corrupt_uids: Tuple[int, ...] = ()
    # wholesale device-array invalidation at one iteration
    device_loss_at: Optional[int] = None
    # a wedged dispatch: one decode step blocks for step_stall_s seconds
    step_stall_at: Optional[int] = None
    step_stall_s: float = 0.0


class FaultInjector:
    """Seeded, replayable fault source consulted by the scheduler.

    The scheduler calls :meth:`on_step` once per loop iteration (bursts,
    window bookkeeping), :meth:`admission_fault` immediately before real
    admission (True = pretend the pool refused), :meth:`prefill_fault`
    before executing a chunk (may raise :class:`InjectedFault`), and
    :meth:`prefill_stalled` to decide whether a job's chunk is withheld
    this iteration.  All randomness comes from one ``default_rng(seed)``.
    """

    def __init__(self, plan: FaultPlan, seed: int = 0):
        self.plan = plan
        self.seed = int(seed)
        self.rng = np.random.default_rng(seed)
        self.iteration = 0
        self.events: List[Tuple] = []
        self._admission_budget = int(plan.admission_failures)
        self._stalls: Dict[int, int] = {}      # uid -> iterations remaining
        self._stall_decided: Dict[int, bool] = {}
        self._burst_fired = False
        self._device_lost = False
        self._step_errors_left = int(plan.step_error_count)
        self._step_stalled = False
        self._corrupt_picked: Optional[Tuple[int, ...]] = None

    # ------------------------------------------------------------ loop hooks
    def on_step(self, sched) -> None:
        """Called at the top of every scheduler iteration."""
        p = self.plan
        if (p.cancel_burst_at is not None and not self._burst_fired
                and self.iteration >= p.cancel_burst_at):
            # defer until requests are actually DECODING: firing the burst
            # into an empty batch would consume the one-shot and inject
            # nothing (a chaos test that injects nothing proves nothing)
            uids = sched.decoding_uids()
            if uids:
                self._burst_fired = True
                n = max(1, int(round(len(uids) * p.cancel_burst_frac)))
                picked = self.rng.choice(len(uids), size=min(n, len(uids)),
                                         replace=False)
                for i in sorted(int(j) for j in picked):
                    self.events.append(("cancel_burst", uids[i],
                                        self.iteration))
                    sched.cancel(uids[i])
        for uid in list(self._stalls):
            self._stalls[uid] -= 1
            if self._stalls[uid] <= 0:
                del self._stalls[uid]
        self.iteration += 1

    def _squeezed(self) -> bool:
        p = self.plan
        return (p.pool_squeeze_at is not None
                and p.pool_squeeze_at <= self.iteration
                < p.pool_squeeze_at + p.pool_squeeze_iters)

    def admission_fault(self, uid: int) -> bool:
        """True: report pool pressure for this admission attempt (no real
        resources are taken; the scheduler waits or preempts)."""
        if self._squeezed():
            self.events.append(("pool_squeeze", uid, self.iteration))
            return True
        if self._admission_budget > 0:
            self._admission_budget -= 1
            self.events.append(("admission_fault", uid, self.iteration))
            return True
        if (self.plan.admission_fail_rate > 0.0
                and self.rng.random() < self.plan.admission_fail_rate):
            self.events.append(("admission_fault", uid, self.iteration))
            return True
        return False

    # -------------------------------------------------------- prefill hooks
    def prefill_fault(self, uid: int) -> None:
        """Raise ``InjectedFault`` when this job is scheduled to fail."""
        p = self.plan
        hit = uid in p.prefill_error_uids or (
            p.prefill_error_rate > 0.0
            and self.rng.random() < p.prefill_error_rate)
        if hit:
            self.events.append(("prefill_fault", uid, self.iteration))
            raise InjectedFault(
                f"injected prefill failure for request uid={uid} "
                f"(seed={self.seed}, iteration={self.iteration})")

    def prefill_stalled(self, uid: int) -> bool:
        """True while this job's chunks are withheld (a wedged prefill)."""
        p = self.plan
        if uid not in self._stall_decided:
            stall = uid in p.stall_uids or (
                p.stall_rate > 0.0 and self.rng.random() < p.stall_rate)
            self._stall_decided[uid] = stall
            if stall and p.stall_iters > 0:
                self._stalls[uid] = int(p.stall_iters)
                self.events.append(("stall", uid, self.iteration))
        return uid in self._stalls

    # --------------------------------------------------------- device hooks
    def step_fault(self) -> None:
        """Consulted immediately before each decode dispatch; raises the
        planned device fault (``DeviceLost`` once, ``StepError`` for every
        iteration in its window).  The scheduler catches ``DeviceError``
        and recovers from host state."""
        p = self.plan
        it = self.iteration
        if (p.device_loss_at is not None and not self._device_lost
                and it >= p.device_loss_at):
            self._device_lost = True
            self.events.append(("device_loss", None, it))
            raise DeviceLost(
                f"injected device loss (seed={self.seed}, iteration={it})")
        if (p.step_error_at is not None and it >= p.step_error_at
                and self._step_errors_left > 0):
            self._step_errors_left -= 1
            self.events.append(("step_error", None, it))
            raise StepError(
                f"injected step error (seed={self.seed}, iteration={it})")

    def step_stall(self) -> None:
        """Wedge ONE decode step for ``step_stall_s`` wall seconds (the
        watchdog's quarry).  Blocks the loop thread, as a hung dispatch
        would."""
        p = self.plan
        if (p.step_stall_at is not None and not self._step_stalled
                and self.iteration >= p.step_stall_at
                and p.step_stall_s > 0.0):
            self._step_stalled = True
            self.events.append(("step_stall", None, self.iteration))
            time.sleep(p.step_stall_s)

    def corrupt_uids(self, decoding_uids: List[int]) -> Tuple[int, ...]:
        """Which of the currently-DECODING uids get NaN logits this
        iteration.  Explicit ``step_corrupt_uids`` are targeted directly;
        otherwise a seeded fraction is picked ONCE at the first iteration
        of the window that has a non-empty decode batch (deferred, like
        cancel_burst, so an empty batch can't consume the pick) and that
        same set is corrupted for the rest of the window — surviving
        quarantine/re-admission, which is what drives the strike counter.
        """
        p = self.plan
        if p.step_corrupt_at is None or not decoding_uids:
            return ()
        it = self.iteration
        if not (p.step_corrupt_at <= it
                < p.step_corrupt_at + p.step_corrupt_iters):
            return ()
        if p.step_corrupt_uids:
            hit = tuple(u for u in decoding_uids if u in p.step_corrupt_uids)
        else:
            if self._corrupt_picked is None:
                n = max(1, int(round(len(decoding_uids)
                                     * p.step_corrupt_frac)))
                idx = self.rng.choice(len(decoding_uids),
                                      size=min(n, len(decoding_uids)),
                                      replace=False)
                self._corrupt_picked = tuple(
                    decoding_uids[int(i)]
                    for i in sorted(int(j) for j in idx))
            hit = tuple(u for u in self._corrupt_picked
                        if u in decoding_uids)
        for uid in hit:
            self.events.append(("step_corrupt", uid, it))
        return hit

    def fired(self, kind: str) -> int:
        """How many events of ``kind`` actually fired (tests assert > 0)."""
        return sum(1 for e in self.events if e[0] == kind)
