"""The serve-discipline registry: ONE list every consumer derives from.

``serve_bench.py`` replays a request trace through each discipline and
gates it; ``benchmarks/tables.py`` enumerates them in the CSV report; the
README's discipline table is generated from here (``python -m
repro.serve.disciplines`` prints the markdown; a tier-1 test pins the
README copy to it).  Adding a discipline means adding ONE entry — a bench
or doc that forgets it fails the registry cross-checks instead of silently
drifting.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class Discipline:
    name: str          # registry key; also the serve_bench report section
    title: str         # one-line README description
    gate: str          # the headline gate serve_bench enforces


DISCIPLINES: Tuple[Discipline, ...] = (
    Discipline(
        "sequential",
        "one request at a time, fused prefill + one-dispatch decode loop",
        "baseline (the other disciplines gate against it)"),
    Discipline(
        "continuous",
        "slot-based continuous batching over a dense `(max_slots, …)` cache",
        "requests/s >= 2x sequential; zero steady-state recompiles"),
    Discipline(
        "paged_gather",
        "shared page pool; decode gathers the dense view through the page "
        "table (reference/oracle)",
        "token identity; nonzero dense-view transient (the copy it models)"),
    Discipline(
        "paged",
        "gather-free: attention walks `pool[table]` page-block-wise "
        "(flash-decode Pallas kernel + jnp oracle), zero dense-view "
        "transient",
        ">= 2x dense memory saving; >= gather tokens/s; zero transient "
        "bytes"),
    Discipline(
        "prefix",
        "`paged` + shared-prefix KV reuse: ref-counted CoW pages behind a "
        "radix block-hash index; shared prompt prefixes are mapped, not "
        "re-prefilled",
        "token identity; prefill tokens/s uplift >= 1.3x at >= 50% "
        "overlap; fewer pages stored"),
    Discipline(
        "overload",
        "open-loop arrivals at 2x the service rate with priorities, "
        "deadlines and SLA preemption",
        "high-priority p95 TTFT <= 1.5x unloaded; cancel frees pages in "
        "one iteration"),
    Discipline(
        "tp",
        "tensor-parallel serving (DESIGN.md §11): the same persistent "
        "decode step over a `(\"data\",\"model\")` mesh — float params "
        "column-cut with all-gathers before down-projections (bitwise "
        "token identity; quantized split-brain keeps the full Megatron "
        "cut, int32-exact), page pool cut on KV heads, page tables "
        "host-owned and replicated",
        "token identity tp=2 vs tp=1; per-shard traffic sums byte-exactly; "
        "decode tokens/s >= 1.6x on >= 2 cores"),
    Discipline(
        "chaos",
        "crash-tolerant serving (DESIGN.md §12): seeded step errors, "
        "per-slot NaN logit corruption and wholesale device loss injected "
        "into the paged + prefix engine; the scheduler quarantines "
        "poisoned slots and rebuilds device state from the "
        "host-authoritative copy",
        "token identity vs the uninterrupted run; pool occupancy back to "
        "baseline; recovery time bounded; zero recompiles on a repeat "
        "chaos cycle"),
    Discipline(
        "kv_quant",
        "`paged` over an int8 page pool (DESIGN.md §13): 1-byte codes + "
        "per-page, per-kv-head scales beside the page table, quantized on "
        "write, dequantized inside the flash-decode page fetch",
        ">= 1.8x resident tokens at fixed pool bytes; bounded per-step "
        "greedy argmax flip rate vs bf16; non-KV traffic channels "
        "byte-exact; zero steady-state recompiles"),
)

NAMES: Tuple[str, ...] = tuple(d.name for d in DISCIPLINES)


def markdown_table() -> str:
    """The README's discipline table, generated (do not hand-edit the
    README copy — regenerate with ``python -m repro.serve.disciplines``)."""
    lines = ["| discipline | what it is |", "|---|---|"]
    lines += [f"| `{d.name}` | {d.title} |" for d in DISCIPLINES]
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
