"""Slot-cache plumbing for the continuous-batching serve loop.

A *slot cache* is an ordinary family cache pytree built for
``batch = max_slots``: every slot is one independent request stream at its
own position (ragged ``len``).  The helpers here are family-agnostic — they
never assume where the batch dimension lives.  Instead ``batch_axes``
*discovers* it per leaf by diffing the shapes of two caches built with
different batch sizes (the batch axis is the only axis that can change), so
lm's ``(n_groups, gs, B, Hkv, S, hd)`` lists, rwkv's ``(L, B, H, hd, hd)``
state and hymba's mixed KV+SSM caches all work through the same two
primitives:

  * ``make_slot_insert`` — write a freshly prefilled single-request cache
    into slot ``i`` of the batched cache (one jitted dispatch, donated
    batched buffers, traced slot index: compiles ONCE).
  * ``select_slots`` — per-leaf ``where`` keyed on the active mask, used by
    the masked decode step to freeze finished/free slots.

Also home to the power-of-two shape bucketing used to bound every serve-path
jit cache, and a process-wide XLA compile counter (the zero-recompile
steady-state assertion in ``benchmarks/serve_bench.py`` is measured, not
assumed).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["bucket", "batch_axes", "select_slots", "make_slot_insert",
           "corrupt_logits", "finite_logits", "CompileCounter"]


def bucket(n: int, floor: int = 1) -> int:
    """Round ``n`` up to the next power of two (>= floor).

    Every serve-path compile key (prompt width, decode steps, batch) is
    bucketed through here, so the number of distinct compiled programs is
    O(log max_len) instead of O(#distinct request shapes).
    """
    n = max(int(n), floor)
    return 1 << (n - 1).bit_length()


def batch_axes(cache_a: Any, cache_b: Any) -> Any:
    """Per-leaf batch-axis pytree, discovered by shape diffing.

    ``cache_a``/``cache_b`` are the same family cache built with two
    different batch sizes (ShapeDtypeStructs from ``jax.eval_shape`` are
    fine — no allocation needed).  Exactly one axis per leaf may differ.
    """
    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        assert len(diffs) == 1, \
            f"cannot locate batch axis: {a.shape} vs {b.shape}"
        return diffs[0]

    return jax.tree.map(axis, cache_a, cache_b)


def _mask_for(active: jnp.ndarray, axis: int, ndim: int) -> jnp.ndarray:
    """Reshape the (n_slots,) mask to broadcast along ``axis`` of a leaf."""
    shape = [1] * ndim
    shape[axis] = active.shape[0]
    return active.reshape(shape)


def select_slots(active: jnp.ndarray, new: Any, old: Any, axes: Any) -> Any:
    """new where the slot is active, old where it is not — per leaf, along
    that leaf's own batch axis.  Traceable."""
    return jax.tree.map(
        lambda n, o, ax: jnp.where(_mask_for(active, ax, n.ndim), n, o),
        new, old, axes)


def corrupt_logits(logits: jnp.ndarray, corrupt: jnp.ndarray) -> jnp.ndarray:
    """NaN-poison the logits of slots where ``corrupt`` is True — the
    fault-injection half of the finite-logits sentinel.  Traced into the
    ONE masked decode step with a fixed ``(n_slots,)`` bool input, so the
    all-False steady state pays one ``where`` and zero recompiles, and an
    injected corruption is REAL non-finite data flowing through the same
    detection path a flipped bit would take."""
    shape = [corrupt.shape[0]] + [1] * (logits.ndim - 1)
    return jnp.where(corrupt.reshape(shape), jnp.nan, logits)


def finite_logits(logits: jnp.ndarray) -> jnp.ndarray:
    """Per-slot ``(n_slots,)`` bool: True iff every logit of that slot is
    finite.  Returned alongside the sampled tokens from the decode step —
    it rides the same device->host transfer, costing no extra sync."""
    axes = tuple(range(1, logits.ndim))
    return jnp.isfinite(logits).all(axis=axes)


def make_slot_insert(axes: Any, batched_sh: Any = None,
                     single_sh: Any = None):
    """Jitted ``insert(batched_cache, single_cache, slot) -> batched_cache``.

    Writes every leaf of a batch-1 cache into position ``slot`` of the
    batched cache along the leaf's batch axis.  ``slot`` is a traced scalar,
    so admission into any slot reuses ONE compiled program; the batched
    buffers are donated (admission is in-place on the accelerator).

    ``batched_sh``/``single_sh`` (optional) pin the slot-cache and request-
    cache placements on a TP serving mesh (NamedSharding pytrees) — explicit
    in/out specs keep the compiled-program cache stable when admission
    interleaves with sharded decode (DESIGN.md §11).
    """
    def insert(batched, single, slot):
        return jax.tree.map(
            lambda b, s, ax: jax.lax.dynamic_update_slice_in_dim(
                b, s.astype(b.dtype), slot, axis=ax),
            batched, single, axes)

    kw = {}
    if batched_sh is not None:
        kw = dict(in_shardings=(batched_sh, single_sh, None),
                  out_shardings=batched_sh)
    return jax.jit(insert, donate_argnums=(0,), **kw)


class CompileCounter:
    """Process-wide XLA backend-compile counter via ``jax.monitoring``.

    Usage: ``c0 = CompileCounter.instance().count`` ... run steady state ...
    ``recompiles = CompileCounter.instance().count - c0``.  Falls back to
    ``available=False`` (count stays 0) if the monitoring API moved.
    """

    _instance = None
    _EVENT = "/jax/core/compile/backend_compile_duration"

    def __init__(self) -> None:
        self.count = 0
        try:
            from jax import monitoring
            monitoring.register_event_duration_secs_listener(self._on_event)
            self.available = True
        except Exception:
            self.available = False

    def _on_event(self, key: str, duration: float, **_) -> None:
        if key == self._EVENT:
            self.count += 1

    @classmethod
    def instance(cls) -> "CompileCounter":
        if cls._instance is None:
            cls._instance = CompileCounter()
        return cls._instance
