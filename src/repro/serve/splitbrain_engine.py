"""Split-Brain serving engine — the paper's §IV-B protocol, executable.

Decoding is explicitly partitioned into:

  device_phase  — the ITA ASIC: stateless, LAQ-quantized linear projections
                  (QKV, FFN, LM head).  Zero dynamic state.
  host_phase    — the host CPU: KV-cache append, attention (the dynamic-
                  state op), residual adds, norm statistics, sampling.

Every tensor that crosses the boundary is registered on a TrafficMeter, so
the *measured* per-token interface bytes can be asserted equal to the
analytical TrafficModel (eq. 7-11) — that equality is a test
(tests/test_splitbrain.py) and a benchmark (table3_interface).

This engine covers the paper's own configs (decoder-only LM family); the
production serving path for all 10 archs is serve/engine.py.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import quant
from repro.core.splitbrain import ACT_BYTES, TrafficMeter, TrafficModel
from repro.kernels import ops
from repro.models import api
from repro.models import layers as L
from repro.models import transformer


def traffic_model_for(cfg: ModelConfig) -> TrafficModel:
    return TrafficModel(
        num_layers=cfg.num_layers,
        d_model=cfg.d_model,
        kv_dim=cfg.kv_dim,
        vocab_size=cfg.vocab_size,
    )


class SplitBrainEngine:
    """Greedy decoding with an explicit host/device boundary."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 256,
                 quantize: bool = True):
        assert cfg.family == "lm" and len(cfg.layer_pattern) == 1, \
            "split-brain reference engine covers the paper's LM configs"
        self.cfg = cfg
        self.meter = TrafficMeter()
        # The "synthesis" step: weights become immutable INT4 codes.
        self.device_params = (api.quantize_model(params, cfg)
                              if quantize else params)
        self.host_params = params  # norms/embedding stay host-side floats
        self.max_len = max_len
        self._hd = cfg.resolved_head_dim

    # ------------------------------------------------------------- device ops
    def _device_qkv(self, layer_p, x):
        """ITA device: hardwired QKV projection (stateless)."""
        cfg = self.cfg
        self.meter.h2d("x_qkv_in", x.shape)
        q, k, v = L.qkv_project(layer_p["attn"], x, cfg.num_heads,
                                cfg.num_kv_heads, self._hd)
        # K, V stream back to the host KV cache (eq. 7); Q accompanies them
        # in the same DMA (the paper counts K/V only — Q stays on-device in
        # the ASIC pipeline; we ship it because our "device" is a function).
        self.meter.d2h("kv_out", (2, *k.shape[:2], k.shape[2], k.shape[3]))
        return q, k, v

    def _device_attn_out(self, layer_p, attn):
        self.meter.h2d("attn_in", attn.shape)   # eq. 8
        return L.linear(attn, layer_p["attn"]["wo"])

    def _device_ffn(self, layer_p, y):
        out = L.swiglu(y, layer_p["mlp"]["w1"], layer_p["mlp"]["w3"],
                       layer_p["mlp"]["w2"])
        return out

    def _device_logits(self, x):
        head = self.device_params.get("lm_head")
        logits = L.linear(x, head)
        self.meter.d2h("logits", logits.shape)   # eq. 9
        return logits

    # --------------------------------------------------------------- decoding
    def decode_token(self, cache: Dict[str, Any], token: jnp.ndarray):
        """One token through the split-brain loop. token: (B,)."""
        cfg = self.cfg
        B = token.shape[0]
        hd = self._hd
        # HOST: embedding lookup (vocabulary table, random access)
        x = self.host_params["embed"][token][:, None, :].astype(jnp.dtype(cfg.dtype))
        pos = cache["len"]
        positions = pos[:, None]

        n_groups, group_size = transformer.group_layout(cfg)
        dev_blocks = self.device_params["blocks"]
        host_blocks = self.host_params["blocks"]
        for g in range(n_groups):
            for j in range(group_size):
                idx = (g, j)
                dev_p = jax.tree.map(lambda a: a[idx[0]][idx[1]], dev_blocks)
                host_p = jax.tree.map(lambda a: a[idx[0]][idx[1]], host_blocks)
                layer = g * group_size + j
                # HOST: pre-norm (dynamic statistics)
                xn = L.rmsnorm(x, host_p["ln_attn"], cfg.norm_eps)
                # DEVICE: QKV projection
                q, k, v = self._device_qkv(dev_p, xn)
                q = L.rope(q, positions, cfg.rope_theta)
                k = L.rope(k, positions, cfg.rope_theta)
                # HOST: KV-cache append + attention
                kc, vc = cache["k"][layer], cache["v"][layer]
                kc = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice(
                    c, kk, (0, i, 0)))(kc, k[:, :, 0:1], pos)
                vc = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice(
                    c, vv, (0, i, 0)))(vc, v[:, :, 0:1], pos)
                cache["k"][layer], cache["v"][layer] = kc, vc
                attn = ops.decode_attention(q, kc, vc, pos + 1,
                                            softcap=cfg.softcap)
                attn = attn.transpose(0, 2, 1, 3).reshape(B, 1, cfg.num_heads * hd)
                # DEVICE: output projection;  HOST: residual add
                x = x + self._device_attn_out(dev_p, attn)
                # HOST norm -> DEVICE FFN -> HOST residual
                y = L.rmsnorm(x, host_p["ln_mlp"], cfg.norm_eps)
                x = x + self._device_ffn(dev_p, y)

        x = L.rmsnorm(x, self.host_params["ln_final"], cfg.norm_eps)
        logits = self._device_logits(x)[:, 0]
        # HOST: sampling
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cache["len"] = cache["len"] + 1
        return next_tok, logits, cache

    def init_cache(self, batch: int) -> Dict[str, Any]:
        cfg = self.cfg
        hd = self._hd
        return {
            "k": [jnp.zeros((batch, cfg.num_kv_heads, self.max_len, hd),
                            jnp.dtype(cfg.dtype)) for _ in range(cfg.num_layers)],
            "v": [jnp.zeros((batch, cfg.num_kv_heads, self.max_len, hd),
                            jnp.dtype(cfg.dtype)) for _ in range(cfg.num_layers)],
            "len": jnp.zeros((batch,), jnp.int32),
        }

    def measured_bytes_per_token(self, batch: int = 1,
                                 count_q: bool = False) -> Dict[str, int]:
        """Per-token boundary bytes from the meter (per sequence).

        The paper's eq. 10 counts K/V out, attention in, logits out; our
        meter additionally logs the QKV input activation (h2d "x_qkv_in").
        ``count_q=False`` reproduces the paper's accounting exactly.
        """
        d2h = h2d = 0
        for direction, name, nbytes in self.meter.log:
            if not count_q and name == "x_qkv_in":
                continue
            if direction == "d2h":
                d2h += nbytes
            else:
                h2d += nbytes
        return {"d2h": d2h // batch, "h2d": h2d // batch,
                "total": (d2h + h2d) // batch}
