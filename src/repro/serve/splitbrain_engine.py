"""Split-Brain serving engine — the paper's §IV-B protocol, executable.

Decoding is explicitly partitioned into:

  device_phase  — the ITA ASIC: stateless, LAQ-quantized linear projections
                  (QKV, FFN, LM head).  Zero dynamic state.
  host_phase    — the host CPU: KV-cache append, attention (the dynamic-
                  state op), residual adds, norm statistics, sampling.

Every tensor that crosses the boundary is registered on a TrafficMeter, so
the *measured* per-token interface bytes can be asserted equal to the
analytical TrafficModel (eq. 7-11) — that equality is a test
(tests/test_splitbrain.py) and a benchmark (table3_interface).

Two execution paths (DESIGN.md §1):

  jit=True (default) — parameters and the KV cache are stacked pytrees with
      a leading layer axis ``(L, ...)``; one ``jax.lax.scan`` sweeps the
      depth and the whole per-token step is a single jitted dispatch with
      donated cache buffers.  Boundary accounting happens at trace time:
      every crossing shape is static, so the meter is replayed host-side per
      token and stays byte-identical to the eager log.
  jit=False — the original per-layer Python loop, kept as the bit-level
      reference for parity tests and as the readable spec of the protocol.

``generate()`` fuses the *multi-token* loop too: prompt forcing plus greedy
decode run inside one jitted ``lax.scan`` — one dispatch per generation.

This engine covers the paper's own configs (decoder-only LM family); the
production serving path for all 10 archs is serve/engine.py.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.splitbrain import TrafficMeter, TrafficModel
from repro.distributed import sharding as shd
from repro.kernels import ops
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.models import layers as L
from repro.serve import pages as pages_mod
from repro.serve import slots as slots_mod


def traffic_model_for(cfg: ModelConfig) -> TrafficModel:
    return TrafficModel.for_config(cfg)


def _stack_layers(tree, num_layers: int):
    """Collapse the (n_groups, group_size, ...) leading dims to (L, ...)."""
    return jax.tree.map(lambda a: a.reshape((num_layers,) + a.shape[2:]), tree)


class SplitBrainEngine(pages_mod.PagedEngineMixin):
    """Greedy decoding with an explicit host/device boundary."""

    def __init__(self, cfg: ModelConfig, params, max_len: int = 256,
                 quantize: bool = True, jit: bool = True,
                 use_pallas: bool = False, page_size: Optional[int] = None,
                 num_pages: Optional[int] = None,
                 paged_attn: str = "inplace", prefix_cache: str = "off",
                 kv_dtype: str = "bf16", mesh=None):
        if cfg.family != "lm" or len(cfg.layer_pattern) != 1:
            raise ValueError(
                "split-brain reference engine covers the paper's LM configs")
        if cfg.moe:
            raise ValueError(
                "split-brain reference engine covers dense FFNs")
        self.cfg = cfg
        self.mesh = mesh if mesh is not None else make_test_mesh()
        # tensor-parallel degree of the serving mesh (DESIGN.md §11); tp == 1
        # (the 1-device test mesh) reproduces the single-device layout.
        self._tp = (int(self.mesh.shape[cfg.parallel.model_axis])
                    if cfg.parallel.model_axis in self.mesh.axis_names else 1)
        self.meter = TrafficMeter()
        # The "synthesis" step: weights become immutable INT4 codes.
        self.device_params = (api.quantize_model(params, cfg)
                              if quantize else params)
        self.host_params = params  # norms/embedding stay host-side floats
        self.max_len = max_len
        self.jit = jit
        self.use_pallas = use_pallas
        # -- static hoisting: everything derivable from cfg/params is computed
        #    once here, not per decode_token call.
        self._hd = cfg.resolved_head_dim
        self._dtype = jnp.dtype(cfg.dtype)
        self._n_layers = cfg.num_layers
        # Stacked (L, ...) layer pytrees: device-phase projections (possibly
        # QuantizedLinear codes+scales) and host-phase norm scales.  No
        # per-layer Python lists anywhere on the hot path.
        dev_blocks = self.device_params["blocks"]
        host_blocks = self.host_params["blocks"]
        self._weights = {
            "layers": {
                "attn": _stack_layers(dev_blocks["attn"], cfg.num_layers),
                "mlp": _stack_layers(dev_blocks["mlp"], cfg.num_layers),
                "ln_attn": _stack_layers(host_blocks["ln_attn"], cfg.num_layers),
                "ln_mlp": _stack_layers(host_blocks["ln_mlp"], cfg.num_layers),
            },
            "embed": self.host_params["embed"],
            "ln_final": self.host_params["ln_final"],
            "head": self.device_params.get("lm_head"),
        }
        # TP placement of the stacked weights: the Megatron column/row rules
        # match the stacked (L, ...) projections through their leading-dim
        # padding, "head" takes the lm_head column cut (DESIGN.md §11).
        # Quantized weights keep the FULL row+column cut — int32 matmul
        # accumulation is associative, so split contractions stay bitwise
        # exact.  Float weights (quantize=False) must fall back to the
        # column-only serve rules to preserve greedy token identity.
        spec_fn = shd.param_pspecs if quantize else shd.serve_param_pspecs
        self._param_sh = shd.with_sharding(
            self.mesh, spec_fn(self._weights, cfg, self.mesh))
        with self.mesh:
            self._weights = jax.device_put(self._weights, self._param_sh)
        self._cache_sh: Dict[int, Any] = {}      # keyed by batch size
        # Pre-computed per-token boundary-crossing byte counts (shapes are
        # static) for the trace-time meter replay; per batch element.
        self._decode_jit = jax.jit(self._token_step, donate_argnums=(1, 2))
        self._generate_jit: Dict[Tuple[int, int, Any], Any] = {}
        self._prefill_jit: Dict[int, Any] = {}   # keyed by bucket width
        self._slot_step = None
        self._slot_insert = None
        # paged slot cache (page_size=None keeps the dense slot layout)
        self.page_size = page_size
        self.num_pages = num_pages
        self._pager = (pages_mod.HostPager(page_size, num_pages, max_len)
                       if page_size is not None else None)
        self._paged_attn = self.check_paged_attn(paged_attn)
        self._prefix_cache_on = self.check_prefix_cache(prefix_cache)
        # pool storage format (DESIGN.md §13): int8/fp8 pages quantize on
        # write and dequantize at the attention page fetch
        self._kv_dtype = pages_mod.check_kv_dtype(kv_dtype, page_size)
        self._paging_active = self._pager is not None   # k/v always page
        self._paged_step = None
        self._b1_shape = None                  # B=1 request-cache eval_shape

    # ------------------------------------------------------------- device ops
    # The eager reference path: each helper registers its boundary crossing
    # on the meter at call time.
    def _device_qkv(self, layer_p, x):
        """ITA device: hardwired QKV projection (stateless)."""
        cfg = self.cfg
        self.meter.h2d("x_qkv_in", x.shape)
        q, k, v = L.qkv_project(layer_p["attn"], x, cfg.num_heads,
                                cfg.num_kv_heads, self._hd,
                                use_pallas=self.use_pallas)
        # K, V stream back to the host KV cache (eq. 7); Q accompanies them
        # in the same DMA (the paper counts K/V only — Q stays on-device in
        # the ASIC pipeline; we ship it because our "device" is a function).
        self.meter.d2h("kv_out", (2, *k.shape[:2], k.shape[2], k.shape[3]))
        return q, k, v

    def _device_attn_out(self, layer_p, attn):
        self.meter.h2d("attn_in", attn.shape)   # eq. 8
        return L.linear(attn, layer_p["attn"]["wo"], self.use_pallas)

    def _device_ffn(self, layer_p, y):
        out = L.swiglu(y, layer_p["mlp"]["w1"], layer_p["mlp"]["w3"],
                       layer_p["mlp"]["w2"], use_pallas=self.use_pallas)
        return out

    def _device_logits(self, x):
        head = self._weights["head"]
        logits = L.linear(x, head, self.use_pallas)
        self.meter.d2h("logits", logits.shape)   # eq. 9
        return logits

    @property
    def traffic_shards(self) -> int:
        """How many ways the boundary-traffic accounting splits per token.

        Equals the mesh's TP degree when every counted channel width
        (d_model, Hkv, Hq, vocab) divides exactly — each shard then crosses
        ``full/tp`` bytes and the per-shard entries sum to the single-device
        analytical model TO THE BYTE (DESIGN.md §11).  Any indivisible width
        falls back to 1 (single aggregate entry)."""
        cfg, tp = self.cfg, self._tp
        if (tp > 1 and cfg.d_model % tp == 0 and cfg.num_kv_heads % tp == 0
                and cfg.num_heads % tp == 0 and cfg.vocab_size % tp == 0):
            return tp
        return 1

    def _meter_token(self, batch: int) -> None:
        """Replay one token's boundary crossings on the meter.

        The jitted path cannot log from inside the trace, but every crossing
        shape is static, so this host-side replay is byte-identical (names,
        order, and sizes) to the eager path's runtime log.  On a TP mesh each
        crossing is logged once per model shard at ``width/tp``
        (``traffic_shards``): the host scatters each shard its activation
        slice and collects its KV-head/logit slice, so boundary bytes never
        duplicate across shards and every total — hence the eq. 7-10
        exactness contract — is unchanged.
        """
        cfg = self.cfg
        s = self.traffic_shards
        for _ in range(self._n_layers):
            for _ in range(s):
                self.meter.h2d("x_qkv_in", (batch, 1, cfg.d_model // s))
                self.meter.d2h("kv_out", (2, batch, cfg.num_kv_heads // s,
                                          1, self._hd))
                self.meter.h2d("attn_in", (batch, 1,
                                           cfg.num_heads * self._hd // s))
        for _ in range(s):
            self.meter.d2h("logits", (batch, 1, cfg.vocab_size // s))

    # --------------------------------------------------------- fused hot path
    def _layer_sweep(self, weights, k_cache, v_cache, pos, token, kv_attend):
        """The shared split-brain per-token body: embed, lax.scan the
        stacked layers (pre-norm -> DEVICE QKV -> rope -> the injected
        ``kv_attend`` -> DEVICE wo -> HOST residual -> DEVICE FFN), final
        norm, DEVICE head, HOST argmax.  ``kv_attend(kc, vc, q, k, v)`` is
        the ONLY point the dense and paged disciplines differ (cache
        append + attention), so their token-identity contract cannot drift
        anywhere else.  Returns (next_tok, logits, new_k, new_v)."""
        cfg = self.cfg
        B = token.shape[0]
        hd = self._hd
        pl = self.use_pallas
        # HOST: embedding lookup (vocabulary table, random access)
        x = weights["embed"][token][:, None, :].astype(self._dtype)
        positions = pos[:, None]

        def layer_fn(x, per_layer):
            p, kc, vc = per_layer
            # HOST: pre-norm (dynamic statistics)
            xn = L.rmsnorm(x, p["ln_attn"], cfg.norm_eps)
            # DEVICE: QKV projection
            q, k, v = L.qkv_project(p["attn"], xn, cfg.num_heads,
                                    cfg.num_kv_heads, hd, use_pallas=pl)
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
            # HOST: KV-cache append + attention (discipline-specific)
            attn, kc, vc = kv_attend(kc, vc, q, k, v)
            attn = attn.transpose(0, 2, 1, 3).reshape(B, 1, cfg.num_heads * hd)
            # DEVICE: output projection;  HOST: residual add
            x = x + L.linear(attn, p["attn"]["wo"], pl)
            # HOST norm -> DEVICE FFN -> HOST residual
            y = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
            x = x + L.swiglu(y, p["mlp"]["w1"], p["mlp"]["w3"],
                             p["mlp"]["w2"], use_pallas=pl)
            return x, (kc, vc)

        x, (new_k, new_v) = jax.lax.scan(
            layer_fn, x, (weights["layers"], k_cache, v_cache))
        x = L.rmsnorm(x, weights["ln_final"], cfg.norm_eps)
        logits = L.linear(x, weights["head"], pl)[:, 0]
        # HOST: sampling
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, new_k, new_v

    def _token_step(self, weights, k_cache, v_cache, length, token):
        """One split-brain token, traceable: lax.scan over the stacked layers.

        k_cache/v_cache: (L, B, Hkv, S, hd).  Returns
        (next_tok, logits, new_k, new_v, new_length).
        """
        pos = length

        def kv_attend(kc, vc, q, k, v):
            kc = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice(
                c, kk, (0, i, 0)))(kc, k[:, :, 0:1], pos)
            vc = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice(
                c, vv, (0, i, 0)))(vc, v[:, :, 0:1], pos)
            attn = ops.decode_attention(q, kc, vc, pos + 1,
                                        softcap=self.cfg.softcap)
            return attn, kc, vc

        next_tok, logits, new_k, new_v = self._layer_sweep(
            weights, k_cache, v_cache, pos, token, kv_attend)
        return next_tok, logits, new_k, new_v, length + 1

    def _paged_token_step(self, weights, k_pool, v_pool, table, length,
                          token, write):
        """One split-brain token computed THROUGH the page pool — no dense
        view.  k_pool/v_pool: (L, num_pages, page_size, Hkv, hd) in the
        kernel-friendly layout, swept per layer by the same
        ``_layer_sweep`` as ``_token_step``; the HOST phase appends each
        active slot's K/V to its page (inactive slots land on scratch) and
        attention walks ``pool[table]`` page-block-wise
        (``ops.paged_decode_attention``), so steady-state KV reads are
        O(live tokens) per slot.  Returns
        (next_tok, logits, new_k_pool, new_v_pool, new_length).
        """
        pos = length

        def kv_attend(kc, vc, q, k, v):
            kc = L.paged_cache_write(kc, k, table, pos, write)
            vc = L.paged_cache_write(vc, v, table, pos, write)
            attn = ops.paged_decode_attention(
                q, kc, vc, table, pos + 1, softcap=self.cfg.softcap,
                use_pallas=self.use_pallas,
                model_axis=self.cfg.parallel.model_axis,
                batch_axes=self.cfg.parallel.batch_axes)
            return attn, kc, vc

        next_tok, logits, new_k, new_v = self._layer_sweep(
            weights, k_pool, v_pool, pos, token, kv_attend)
        return (next_tok, logits, new_k, new_v,
                length + write.astype(jnp.int32))

    def _generate_fn(self, steps: int, max_out: int, eos_id: Optional[int]):
        """Build the fused multi-token loop: prompt forcing + greedy decode
        inside one lax.scan — a single dispatch per generation.

        ``steps``/``max_out`` are power-of-two buckets; the actual prompt
        length ``T0`` is a TRACED argument, so one compiled program serves
        every prompt length in the bucket (the jit cache is O(log max_len)).
        With ``eos_id``, a stream that emits the stop token stops counting
        (``gen_len`` freezes, later outputs pad with ``eos_id``) while the
        scan keeps lockstep — identical semantics to the serve engine loop.
        """

        def gen(weights, k_cache, v_cache, length, prompts, T0, total):
            B = prompts.shape[0]
            W = prompts.shape[1]

            def body(carry, t):
                k, v, ln, tok, alive, n = carry
                nxt, _, k2, v2, ln2 = self._token_step(weights, k, v, ln, tok)
                # ``total`` = T0-1+max_new (traced): the bucket may run more
                # scan steps than the request asked for, but the cache must
                # come back in EXACTLY the prompt+max_new state (and never
                # clamp-write past max_len), so the extras are frozen out.
                run = t < total
                k = jnp.where(run, k2, k)
                v = jnp.where(run, v2, v)
                ln = jnp.where(run, ln2, ln)
                is_gen = (t >= T0 - 1) & run   # ys[T0-1:] = generated region
                if eos_id is None:
                    emitted = nxt
                else:
                    emitted = jnp.where(alive | ~is_gen, nxt,
                                        jnp.int32(eos_id))
                n = n + (is_gen & alive).astype(jnp.int32)
                if eos_id is not None:
                    alive = alive & ~(is_gen & (emitted == eos_id))
                # teacher-force the remaining prompt tokens, then free-run
                forced = jax.lax.dynamic_slice_in_dim(
                    prompts, jnp.minimum(t + 1, W - 1), 1, axis=1)[:, 0]
                tok = jnp.where(t + 1 < T0, forced, emitted)
                return (k, v, ln, tok, alive, n), emitted

            carry = (k_cache, v_cache, length, prompts[:, 0],
                     jnp.ones((B,), bool), jnp.zeros((B,), jnp.int32))
            (k, v, ln, _, _, n), ys = jax.lax.scan(body, carry,
                                                   jnp.arange(steps))
            # ys[t] is the token produced after consuming input t; outputs
            # from step T0-1 onward are the generated continuation.
            toks = jax.lax.dynamic_slice_in_dim(ys.T, T0 - 1, max_out, axis=1)
            return toks, k, v, ln, n

        return jax.jit(gen, donate_argnums=(1, 2))

    # --------------------------------------------------------------- decoding
    def decode_token(self, cache: Dict[str, Any], token: jnp.ndarray):
        """One token through the split-brain loop. token: (B,).

        The jitted path donates the cache buffers: use the *returned* cache,
        the one passed in is consumed.
        """
        if not self.jit:
            return self.decode_token_eager(cache, token)
        self._meter_token(token.shape[0])
        with self.mesh:
            next_tok, logits, k, v, length = self._decode_jit(
                self._weights, cache["k"], cache["v"], cache["len"], token)
        return next_tok, logits, {"k": k, "v": v, "len": length}

    def decode_token_eager(self, cache: Dict[str, Any], token: jnp.ndarray):
        """The reference per-layer Python loop (meter logs at runtime)."""
        cfg = self.cfg
        B = token.shape[0]
        hd = self._hd
        x = self._weights["embed"][token][:, None, :].astype(self._dtype)
        pos = cache["len"]
        positions = pos[:, None]

        new_k, new_v = [], []
        for layer in range(self._n_layers):
            p = jax.tree.map(lambda a: a[layer], self._weights["layers"])
            # HOST: pre-norm (dynamic statistics)
            xn = L.rmsnorm(x, p["ln_attn"], cfg.norm_eps)
            # DEVICE: QKV projection
            q, k, v = self._device_qkv(p, xn)
            q = L.rope(q, positions, cfg.rope_theta)
            k = L.rope(k, positions, cfg.rope_theta)
            # HOST: KV-cache append + attention
            kc, vc = cache["k"][layer], cache["v"][layer]
            kc = jax.vmap(lambda c, kk, i: jax.lax.dynamic_update_slice(
                c, kk, (0, i, 0)))(kc, k[:, :, 0:1], pos)
            vc = jax.vmap(lambda c, vv, i: jax.lax.dynamic_update_slice(
                c, vv, (0, i, 0)))(vc, v[:, :, 0:1], pos)
            attn = ops.decode_attention(q, kc, vc, pos + 1,
                                        softcap=cfg.softcap)
            attn = attn.transpose(0, 2, 1, 3).reshape(B, 1, cfg.num_heads * hd)
            # DEVICE: output projection;  HOST: residual add
            x = x + self._device_attn_out(p, attn)
            # HOST norm -> DEVICE FFN -> HOST residual
            y = L.rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
            x = x + self._device_ffn(p, y)
            new_k.append(kc)
            new_v.append(vc)

        x = L.rmsnorm(x, self._weights["ln_final"], cfg.norm_eps)
        logits = self._device_logits(x)[:, 0]
        # HOST: sampling
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits, {"k": jnp.stack(new_k), "v": jnp.stack(new_v),
                                  "len": cache["len"] + 1}

    def generate(self, prompts, max_new: int = 16,
                 eos_id: Optional[int] = None) -> Dict[str, Any]:
        """Greedy-decode a batch in ONE dispatch. prompts: (B, T0) int32.

        Prompt tokens are teacher-forced through the same per-token step
        (filling the KV cache), then ``max_new`` tokens free-run — all
        inside a single jitted lax.scan.  ``decode_s``/``tokens_per_s``
        cover the whole dispatch (prompt + decode), the same scope the
        stepwise reference times.

        Compiled shapes are bucketed (prompt width / step count to powers of
        two, T0 traced), so the jit cache is O(log max_len).  ``eos_id``
        enables per-request stop tokens: rows pad with ``eos_id`` past each
        stop and ``gen_len`` reports exact generated lengths; the meter then
        replays boundary bytes per *active* token only.
        """
        prompts = jnp.asarray(prompts, jnp.int32)
        B, T0 = prompts.shape
        if T0 - 1 + max_new > self.max_len:
            raise ValueError(
                f"request does not fit the cache: prompt_len={T0} + "
                f"max_new={max_new} needs {T0 - 1 + max_new} positions but "
                f"max_len={self.max_len}")
        if not self.jit:
            return self._generate_stepwise(prompts, max_new, eos_id)
        Pb = slots_mod.bucket(T0)
        Mb = slots_mod.bucket(max_new)
        Sb = slots_mod.bucket(Pb - 1 + Mb)
        key = (Pb, Mb, eos_id)
        if key not in self._generate_jit:
            self._generate_jit[key] = self._generate_fn(Sb, Mb, eos_id)
        if Pb > T0:
            prompts = jnp.pad(prompts, ((0, 0), (0, Pb - T0)))
        cache = self.init_cache(B)
        t0 = time.perf_counter()
        with self.mesh:
            toks, k, v, length, n = self._generate_jit[key](
                self._weights, cache["k"], cache["v"], cache["len"], prompts,
                jnp.int32(T0), jnp.int32(T0 - 1 + max_new))
        toks = jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        toks = np.asarray(toks)[:, :max_new]
        gen_len = np.minimum(np.asarray(n), max_new)
        # Boundary accounting, per ACTIVE token: every prompt-forcing step
        # crosses for the whole batch; decode step t crosses only for the
        # streams still running (eos_id=None -> all of them, the pre-EOS
        # behaviour byte-for-byte).
        for _ in range(T0 - 1):
            self._meter_token(B)
        for t in range(max_new):
            a = int((gen_len > t).sum())
            if a:
                self._meter_token(a)
        return {"tokens": toks,
                "gen_len": gen_len,
                "cache": {"k": k, "v": v, "len": length},
                "tokens_per_s": int(gen_len.sum()) / dt,
                "decode_s": dt}

    def _generate_stepwise(self, prompts: jnp.ndarray, max_new: int,
                           eos_id: Optional[int] = None):
        """Token-at-a-time reference generation (eager decode loop).

        Timed over the WHOLE generation (prompt forcing + decode), same
        scope as the fused path's single dispatch, so the two tokens/s
        figures are directly comparable.  EOS semantics mirror the fused
        loop (finished rows emit/feed ``eos_id``, may break early once all
        rows stop); NOTE the eager meter logs at runtime, so it counts every
        executed lockstep step for the full batch — the per-active-token
        accounting is a property of the replayed (jit) paths.
        """
        B, T0 = prompts.shape
        cache = self.init_cache(B)
        tok = prompts[:, 0]
        outs = []
        alive = np.ones((B,), bool)
        gen_len = np.zeros((B,), np.int32)
        t0 = time.perf_counter()
        for t in range(1, T0):
            _, _, cache = self.decode_token_eager(cache, tok)
            tok = prompts[:, t]
        for _ in range(max_new):
            tok, _, cache = self.decode_token_eager(cache, tok)
            emitted = np.asarray(tok)
            gen_len += alive
            if eos_id is not None:
                emitted = np.where(alive, emitted, eos_id)
                alive &= emitted != eos_id
                tok = jnp.asarray(emitted, jnp.int32)
            outs.append(emitted)
            if eos_id is not None and not alive.any():
                break
        dt = time.perf_counter() - t0
        while len(outs) < max_new:
            outs.append(np.full((B,), eos_id, np.int32))
        return {"tokens": np.stack(outs, 1), "cache": cache,
                "gen_len": gen_len,
                "tokens_per_s": int(gen_len.sum()) / dt, "decode_s": dt}

    def _cache_like(self, batch: int) -> Dict[str, Any]:
        """ShapeDtypeStruct pytree of the stacked (L, B, Hkv, S, hd) cache."""
        cfg = self.cfg
        shape = (cfg.num_layers, batch, cfg.num_kv_heads, self.max_len,
                 self._hd)
        return {
            "k": jax.ShapeDtypeStruct(shape, self._dtype),
            "v": jax.ShapeDtypeStruct(shape, self._dtype),
            "len": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }

    def _cache_shardings(self, batch: int):
        """NamedSharding pytree for the stacked cache under the serve rules
        (head-cut KV; identical to replicated on a 1-device mesh)."""
        if batch not in self._cache_sh:
            self._cache_sh[batch] = shd.with_sharding(
                self.mesh, shd.serve_cache_pspecs(
                    self._cache_like(batch), self.cfg, self.mesh))
        return self._cache_sh[batch]

    def _vec_shardings(self, n: int) -> NamedSharding:
        """Placement of a per-slot (n,) vector (tokens / active mask)."""
        ax = shd.MeshAxes(self.mesh, self.cfg)
        b = ax.resolve("batch")
        if b is None or n % ax.size(b) != 0:
            return NamedSharding(self.mesh, P())
        return NamedSharding(self.mesh, P(b))

    def init_cache(self, batch: int) -> Dict[str, Any]:
        """Stacked KV cache: (L, B, Hkv, S, hd) — scan-sweepable, no lists.
        Allocated directly into its TP placement (no full replica ever
        materialises on a multi-device mesh)."""
        like = self._cache_like(batch)
        sh = self._cache_shardings(batch)
        with self.mesh:
            return jax.tree.map(
                lambda a, s: jnp.zeros(a.shape, a.dtype, device=s), like, sh)

    # ---------------------------------------------------------- slot protocol
    # Consumed by serve/scheduler.py: the stacked cache doubles as a slot
    # cache — slot i is batch row i, at its own ragged position.  With
    # ``page_size`` set, the (L, B, Hkv, S, hd) K/V leaves instead live in a
    # shared page pool behind a host-owned page table (serve/pages.py).
    _SLOT_AXES = {"k": 1, "v": 1, "len": 0}
    _SEQ_AXES = {"k": 3, "v": 3, "len": -1}

    def init_slot_cache(self, n_slots: int) -> Dict[str, Any]:
        shape = self._cache_like(n_slots)
        ba, sa = self._SLOT_AXES, self._SEQ_AXES
        self._note_slot_cache(n_slots, shape, ba, sa)
        if not self._paging_active:
            return self.init_cache(n_slots)
        pool = self._pager.reset(n_slots)
        self._pager.prefix_on = self.prefix_sharing_active()
        # head-cut pool placement (DESIGN.md §11): each model shard owns a
        # (L, num_pages, ps, Hkv/tp, hd) slice; an Hkv the TP degree does
        # not divide auto-replicates (the Hkv < tp fallback) and the
        # per-shard byte accounting stays 1-way.
        pshape = pages_mod.pool_shape(shape, ba, sa, pool.num_pages,
                                      self.page_size, self._kv_dtype)
        pool_specs = shd.pool_pspecs(pshape, self.cfg, self.mesh, sa)
        self._pool_sh = shd.with_sharding(self.mesh, pool_specs)
        self._b1_sh = self._cache_shardings(1)
        self._note_slot_cache(n_slots, shape, ba, sa,
                              shd.pool_kv_cut(pool_specs, sa, self._tp,
                                              self.cfg.parallel.model_axis))
        self._kv_quant_tok_bytes = (
            pages_mod.kv_token_bytes_quant(shape, ba, sa, self.page_size,
                                           self._kv_dtype)
            if self._kv_dtype != "bf16" else None)
        with self.mesh:
            return pages_mod.make_pool(shape, ba, sa, pool.num_pages,
                                       self.page_size,
                                       shardings=self._pool_sh,
                                       kv_dtype=self._kv_dtype)

    # reserve_slot / can_ever_admit / free_slot / cache_stats come from
    # pages_mod.PagedEngineMixin.
    def _stats_seq_axes(self):
        return self._SEQ_AXES

    def rebuild(self, n_slots: int) -> Dict[str, Any]:
        """Re-materialise every device-side byte from host state after a
        device loss: weights re-placed from the host copy, a fresh page
        pool (or dense slot cache) allocated, host pager reset.  The jit
        caches are deliberately kept — compiled programs are immutable
        host artifacts (a device failure invalidates buffers, never code),
        so the rebuilt pool re-enters the SAME compiled step and recovery
        costs zero recompiles."""
        with self.mesh:
            self._weights = jax.device_put(self._weights, self._param_sh)
        return self.init_slot_cache(n_slots)

    def new_request_cache(self) -> Dict[str, Any]:
        """Fresh B=1 cache for chunked prefill (slot-shaped, empty)."""
        return self.init_cache(1)

    def seed_request_cache(self, cache, slot: int, cached_len: int):
        """Prefix-aware prefill entry: B=1 request cache seeded with the
        slot's matched prefix pages gathered from the pool, ``len`` set to
        ``cached_len`` — the tail chunk stream continues from there."""
        if self._b1_shape is None:
            self._b1_shape = self._cache_like(1)
        with self.mesh:
            return self.paged_seed(cache, slot, cached_len, self._SLOT_AXES,
                                   self._SEQ_AXES, self._b1_shape)

    def prefill_chunk_slot(self, cache: Dict[str, Any], chunk: np.ndarray,
                           true_w: int) -> Dict[str, Any]:
        """Advance a B=1 request cache by one right-padded prompt chunk.

        Reuses the bucketed prefill program (it scans the split-brain token
        step from WHATEVER state the cache is in, freezing past
        ``true_w``), so chunked prefill adds zero new compiled programs
        beyond the one chunk width.
        """
        chunk = np.asarray(chunk, np.int32)
        W = chunk.shape[0]
        pages_mod.check_chunk_width(W, self.max_len)
        if W not in self._prefill_jit:
            self._prefill_jit[W] = self._prefill_fn(W)
        with self.mesh:
            k, v, ln = self._prefill_jit[W](
                self._weights, cache["k"], cache["v"], cache["len"],
                jnp.asarray(chunk[None, :]), jnp.int32(true_w))
        return {"k": k, "v": v, "len": ln}

    def _prefill_fn(self, width: int):
        """Bucketed B=1 prompt prefill: scan the split-brain token step over
        the padded width, freezing state past ``true_len`` (traced)."""

        def prefill(weights, k, v, ln, tokens, true_len):
            def body(carry, t):
                k, v, ln = carry
                tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1,
                                                   axis=1)[:, 0]
                _, _, k2, v2, ln2 = self._token_step(weights, k, v, ln, tok)
                keep = t < true_len
                return (jnp.where(keep, k2, k), jnp.where(keep, v2, v),
                        jnp.where(keep, ln2, ln)), None

            (k, v, ln), _ = jax.lax.scan(body, (k, v, ln),
                                         jnp.arange(width))
            if self._kv_dtype != "bf16":
                # fused fake-quant (DESIGN.md §13): completed pages
                # round-trip through the page quantizer, so the chunk
                # stream attends to exactly what pool insertion will store
                c = pages_mod.fake_quant_tree(
                    {"k": k, "v": v}, ln[0], {"k": 3, "v": 3},
                    self.page_size, self._kv_dtype)
                k, v = c["k"], c["v"]
            return k, v, ln

        b1 = self._cache_shardings(1)
        return jax.jit(
            prefill, donate_argnums=(1, 2),
            in_shardings=(self._param_sh, b1["k"], b1["v"], b1["len"],
                          None, None),
            out_shardings=(b1["k"], b1["v"], b1["len"]))

    def prefill_slot(self, prompt: np.ndarray):
        """Prefill ONE request into a fresh B=1 cache (bucketed width).

        prompt (T0,) -> (slot-shaped cache with len = T0-1, input token for
        the next decode step).  Compiles once per power-of-two width.
        """
        prompt = np.asarray(prompt, np.int32)
        T0 = prompt.shape[0]
        cache = self.init_cache(1)
        if T0 > 1:
            width = slots_mod.bucket(T0 - 1)
            if width not in self._prefill_jit:
                self._prefill_jit[width] = self._prefill_fn(width)
            body = np.zeros((1, width), np.int32)
            body[0, :T0 - 1] = prompt[:-1]
            with self.mesh:
                k, v, ln = self._prefill_jit[width](
                    self._weights, cache["k"], cache["v"], cache["len"],
                    jnp.asarray(body), jnp.int32(T0 - 1))
            cache = {"k": k, "v": v, "len": ln}
        return cache, int(prompt[-1])

    def insert_slot(self, batched_cache, slot_cache, slot: int):
        """Write a prefilled request into slot ``slot`` (donated batched
        buffers, traced index: ONE compiled program covers every slot).  On
        the paged layout the host allocates the slot's pages first and the
        B=1 K/V is scattered block-wise onto them."""
        if self._paging_active:
            n_tok = int(np.asarray(slot_cache["len"])[0])
            with self.mesh:
                return self.paged_insert(batched_cache, slot_cache, slot,
                                         self._SLOT_AXES, self._SEQ_AXES,
                                         n_tok)
        if self._slot_insert is None:
            self._slot_insert = slots_mod.make_slot_insert(
                self._SLOT_AXES,
                batched_sh=self._cache_shardings(self._slot_count),
                single_sh=self._cache_shardings(1))
        with self.mesh:
            return self._slot_insert(batched_cache, slot_cache,
                                     jnp.int32(slot))

    def decode_slots(self, cache: Dict[str, Any], tokens, active,
                     corrupt=None):
        """One masked batched split-brain token step: every slot computes,
        only ``active`` slots advance (K/V and ``len`` frozen elsewhere).
        Fixed (max_slots, ...) shapes — zero recompiles in steady state.
        Returns ``(next_tokens, ok, cache)``: ``ok`` is the per-slot
        finite-logits sentinel and ``corrupt`` (optional ``(n,)`` bool)
        NaN-poisons the flagged slots' logits inside the jitted step (the
        fault-injection hook; all-False default, zero extra recompiles).
        Paged layout: host allocates the page position ``len`` falls in;
        ``paged_attn="inplace"`` (default) appends K/V to the pages and
        attends directly through the traced table (``_paged_token_step`` —
        no dense-view transient), ``paged_attn="gather"`` keeps the
        reference discipline (gather K/V through the table, same token
        step, scatter one token back per active slot)."""
        n = int(np.asarray(tokens).shape[0])
        if corrupt is None:
            corrupt = np.zeros((n,), bool)
        if self._paging_active:
            act = np.asarray(active, bool)
            with self.mesh:
                cache = self.paged_pre_step(cache, act, self._SLOT_AXES,
                                            self._SEQ_AXES)
            if self._paged_step is None:
                ba, sa = self._SLOT_AXES, self._SEQ_AXES

                if self._paged_attn == "inplace":
                    def paged_step(weights, pcache, table, tok, act_m, bad):
                        _, logits, k2, v2, ln2 = self._paged_token_step(
                            weights, pcache["k"], pcache["v"], table,
                            pcache["len"], tok, act_m)
                        logits = slots_mod.corrupt_logits(logits, bad)
                        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                        ok = slots_mod.finite_logits(logits)
                        return nxt, ok, {"k": k2, "v": v2, "len": ln2}
                else:
                    def paged_step(weights, pcache, table, tok, act_m, bad):
                        view = pages_mod.gather_tree(pcache, table, ba, sa)
                        pos = view["len"]
                        _, logits, k2, v2, ln2 = self._token_step(
                            weights, view["k"], view["v"], pos, tok)
                        logits = slots_mod.corrupt_logits(logits, bad)
                        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                        ok = slots_mod.finite_logits(logits)
                        new = {"k": k2, "v": v2,
                               "len": jnp.where(act_m, ln2, pos)}
                        pc = pages_mod.scatter_token_tree(
                            pcache, new, table, pos, act_m, ba, sa)
                        return nxt, ok, pc

                # explicit placements: pool head-cut, page table replicated
                # (host-owned), per-slot vectors on the batch axis — the
                # sharded jit cache stays keyed on ONE layout, so the
                # steady state never recompiles on a TP mesh either
                vec = self._vec_shardings(n)
                repl = NamedSharding(self.mesh, P())
                self._paged_step = jax.jit(
                    paged_step, donate_argnums=(1,),
                    in_shardings=(self._param_sh, self._pool_sh, repl,
                                  vec, vec, vec),
                    out_shardings=(vec, vec, self._pool_sh))
            with self.mesh:
                nxt, ok, pc = self._paged_step(
                    self._weights, cache, self._pager.table(),
                    jnp.asarray(tokens, jnp.int32),
                    jnp.asarray(active, bool),
                    jnp.asarray(corrupt, bool))
            self._pager.post_decode(act)
            return nxt, ok, pc
        self._meter_kv_read(np.asarray(active, bool))
        if self._slot_step is None:
            def slot_step(weights, k, v, ln, tok, active, bad):
                _, logits, k2, v2, ln2 = self._token_step(weights, k, v, ln,
                                                          tok)
                logits = slots_mod.corrupt_logits(logits, bad)
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
                ok = slots_mod.finite_logits(logits)
                m = active[None, :, None, None, None]   # (L, B, Hkv, S, hd)
                return (nxt, ok, jnp.where(m, k2, k), jnp.where(m, v2, v),
                        jnp.where(active, ln2, ln))

            sh = self._cache_shardings(self._slot_count)
            vec = self._vec_shardings(n)
            self._slot_step = jax.jit(
                slot_step, donate_argnums=(1, 2),
                in_shardings=(self._param_sh, sh["k"], sh["v"], sh["len"],
                              vec, vec, vec),
                out_shardings=(vec, vec, sh["k"], sh["v"], sh["len"]))
        with self.mesh:
            nxt, ok, k, v, ln = self._slot_step(
                self._weights, cache["k"], cache["v"], cache["len"],
                jnp.asarray(tokens, jnp.int32), jnp.asarray(active, bool),
                jnp.asarray(corrupt, bool))
        return nxt, ok, {"k": k, "v": v, "len": ln}

    def meter_tokens(self, n: int) -> None:
        """Replay ``n`` active tokens' boundary crossings (scheduler hook)."""
        if int(n) > 0:
            self._meter_token(int(n))

    def measured_bytes_per_token(self, batch: int = 1,
                                 count_q: bool = False) -> Dict[str, int]:
        """Per-token boundary bytes from the meter (per sequence).

        The paper's eq. 10 counts K/V out, attention in, logits out; our
        meter additionally logs the QKV input activation (h2d "x_qkv_in").
        ``count_q=False`` reproduces the paper's accounting exactly.
        """
        tot = self.meter.measured_bytes(count_q)
        return {k: v // batch for k, v in tot.items()}
