"""repro.serve"""
