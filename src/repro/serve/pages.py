"""Paged KV-cache plumbing: a shared page pool behind the slot protocol.

The paper's Split-Brain protocol (§IV-B) makes the host CPU the sole owner
of dynamic KV state; this module is the host's memory manager.  Instead of
pinning a full ``(max_slots, ..., max_len, ...)`` cache per slot, every
sequence-growing cache leaf is re-laid-out as a *page pool*

    dense leaf  (..., B, ..., S, ...)          S = max_len
    pool  leaf  (num_pages, page_size, *rest)  rest = shape minus B and S

plus one per-slot *page table* ``(max_slots, max_len // page_size)`` of
physical page ids, owned host-side by :class:`PagePool` (plain numpy — no
device sync on the allocation path).  Pages are allocated on demand as a
sequence grows and returned to the free list when its request finishes, so
resident KV bytes track actual token occupancy.

The pool layout is KERNEL-FRIENDLY: the ``(num_pages, page_size)`` axes sit
exactly where the batch axis sat in the dense leaf (``page_axis``), so
leading non-sequence axes — the stacked layer axis of
``(L, B, Hkv, S, hd)`` caches, the ``(n_groups, gs)`` group axes of the lm
family — stay leading.  A ``lax.scan`` over depth therefore sweeps
per-layer pool slices ``(num_pages, page_size, Hkv, hd)`` directly, which
is the exact operand layout ``kernels/paged_attention.py`` (and its jnp
oracle) consumes: attention walks ``pool[table]`` page-block-wise with no
dense-view transient (DESIGN.md §6).

Physical page 0 is reserved as a *scratch* page: table entries beyond a
slot's allocated pages point at it, so every jitted program can write a
fixed number of pages (traced indices, fixed shapes — zero steady-state
recompiles) and the excess lands in garbage that no gather ever reads
(attention masks positions >= ``len``).

Leaves that do NOT scale with ``max_len`` — rwkv WKV state, hymba SSM
state, sliding-window ring buffers, ``len`` itself — keep their dense
``(max_slots, ...)`` layout and pass through untouched: the recurrent
families effectively run a no-op page table.  Discovery is by shape
diffing (:func:`seq_axes`), the same trick ``serve/slots.py::batch_axes``
uses for the batch dimension.

The traced helpers (:func:`gather_tree` / :func:`scatter_token_tree` /
:func:`insert_tree`) are the paged variant of the dense cache plumbing:
``gather_tree`` reconstructs the exact dense-view pytree the family
``decode_step`` already understands (so paged decode reuses the verified
attention math bit-for-bit), and ``scatter_token_tree`` writes back only
the one new token per active slot — O(B × token bytes) pool traffic per
step.

Shared-prefix KV reuse (DESIGN.md §7): the pool is REF-COUNTED with
copy-on-write semantics and carries a radix-style token-block-hash prefix
index ``H(parent_key, page_tokens) -> page``.  With ``prefix_cache="on"``
admission matches a prompt against the index, maps the shared full pages
into the slot's table (refcount++, zero prefill work) and prefills only the
unmatched tail — seeded from a gathered B=1 prefix view so the
absolute-position chunk path continues from the cached position; completed
full pages are published back.  Decode always appends to a private
(refcount==1) tail page, with a CoW copy (or an unpublish, for a sole
owner) when a whole-prompt match put the append position inside a shared
page.  Freed published pages stay resident and matchable until evicted
under pressure.  Reuse engages only when every dynamic cache leaf pages —
ring/recurrent families run a no-op index, token-identical either way.

Scope of the memory claim: paging shrinks the PERSISTENT cache state — the
pool allocation and the peak pages-in-use that admission and the
serve_bench gate reason about.  The default decode discipline
(``paged_attn="inplace"``) additionally computes attention directly
through the page table (``ops.paged_decode_attention``), so the per-step
gathered dense-view TRANSIENT of the fallback/oracle discipline
(``paged_attn="gather"``, which reconstructs the dense view and reuses the
verified family ``decode_step``) is gone too — zero transient bytes, HBM
reads O(live tokens) per slot.  In-flight chunked prefills each hold a
dense B=1 request cache until insertion, bounded by the scheduler's
``max_prefill_jobs`` cap.  DESIGN.md §5–6 spell out all three pieces.
"""
from __future__ import annotations

import hashlib
import math
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import (KV_DTYPES, QuantizedLeaf, SCRATCH_PAGE,
                                 fake_quant_pages, kv_pow2_scale,
                                 kv_quantize, page_offsets,
                                 quant_page_append)
from repro.serve.errors import PageLifecycleError, ReservationError

__all__ = [
    "PagePool",
    "HostPager",
    "PagedEngineMixin",
    "QuantizedLeaf",
    "check_chunk_width",
    "check_kv_dtype",
    "round_len",
    "seq_axes",
    "page_axis",
    "pool_shape",
    "make_pool",
    "gather_view",
    "gather_tree",
    "scatter_token_tree",
    "insert_tree",
    "fake_quant_tree",
    "pool_bytes",
    "page_token_bytes",
    "kv_token_bytes",
    "kv_token_bytes_quant",
    "SCRATCH_PAGE",
]


def check_kv_dtype(kv_dtype: str, page_size) -> str:
    """Validate the engines' ``kv_dtype`` knob: quantized pools exist only
    in the paged layout (per-PAGE scales need pages), so anything but the
    identity "bf16" requires ``page_size``."""
    if kv_dtype not in ("bf16",) + tuple(KV_DTYPES):
        raise ValueError(
            f"kv_dtype must be one of 'bf16', "
            f"{', '.join(repr(k) for k in KV_DTYPES)}, got {kv_dtype!r}")
    if kv_dtype != "bf16" and page_size is None:
        raise ValueError(
            f"kv_dtype={kv_dtype!r} quantizes the PAGE pool (per-page "
            f"scales) — pass page_size to enable the paged layout")
    return kv_dtype


def check_chunk_width(width: int, max_len: int) -> None:
    """Chunk writes must never spill past the cache end: W | max_len plus
    the full-width feeding order (transformer.prefill_chunk precondition)
    guarantee every chunk lands inside the buffer.  Shared by both engines'
    ``prefill_chunk_slot``."""
    if max_len % width != 0:
        raise ValueError(
            f"chunk width {width} must divide max_len ({max_len}) so "
            f"chunk writes never spill past the cache end")


def round_len(n: int, *quanta: Optional[int]) -> int:
    """Round a cache length up so every given quantum (page size, prefill
    chunk width) tiles it exactly — a COMMON multiple, not each quantum in
    turn (sequential rounding can un-align the earlier one)."""
    q = math.lcm(*(int(x) for x in quanta if x))
    return -(-int(n) // q) * q


# ----------------------------------------------------------------------------
# Host-side allocator (numpy only — the host owns the dynamic state)
# ----------------------------------------------------------------------------
class PagePool:
    """Ref-counted free-list page allocator with copy-on-write semantics and
    a radix-style token-block-hash prefix index.

    Lifecycle (DESIGN.md §7): ``try_admit(slot, n_tokens, matched)`` claims
    the worst-case count of NEW pages for a request at admission time and
    maps any ``matched`` prefix pages into the slot's table (refcount++,
    zero prefill work for them); ``ensure(slot, n_tokens)`` then draws
    private pages lazily as the sequence actually grows, which therefore
    never fails — under pressure a draw evicts the least-recently-released
    refcount-0 index page instead of failing.  ``free_slot`` decrements
    every mapped page's refcount; pages that hit zero return to the free
    list, unless they are published in the prefix index, in which case they
    stay resident (and matchable) until evicted.

    The prefix index is a chained block hash
    ``key = H(parent_key, page_token_ids)`` -> physical page, which is a
    flat encoding of a radix tree over token blocks: matching walks the
    chain page by page from the root and stops at the first miss, so a
    lookup is O(matched pages) regardless of how many prefixes are stored.

    Sharing invariant: a page with ``refcount > 1``, or one still published
    in the index, is IMMUTABLE.  Writers (the decode append landing inside
    a fully-matched last page) must call :meth:`cow_page` first, which
    either hands back a private copy target (refcount>1 → the caller copies
    the device bytes src→dst) or retires the index entry when the writer is
    the sole owner (write-in-place, no copy).

    Admission safety: with ``pinned`` = distinct pages referenced by >= 1
    slot, ``R`` = outstanding worst-case new-page reservations and ``D`` =
    pages already drawn under them, admission maintains
    ``pinned + (R - D) <= capacity`` — so free + evictable pages always
    cover every future draw and ``ensure`` cannot fail mid-decode.

    ``double_free`` selects the free-after-free policy: ``"raise"``
    (default) raises ValueError, ``"ignore"`` makes it a no-op.
    Reserve-after-free of the same slot is the normal lifecycle and always
    works; reserve-after-reserve (without a free between) raises.
    """

    _ROOT_KEY = b"radix-root"

    def __init__(self, num_pages: int, page_size: int, n_slots: int,
                 slot_pages: int, double_free: str = "raise"):
        if num_pages < 2:
            raise ValueError(f"num_pages must be >= 2 (page {SCRATCH_PAGE} "
                             f"is the reserved scratch page), got {num_pages}")
        if double_free not in ("raise", "ignore"):
            raise ValueError(f"double_free must be 'raise' or 'ignore', "
                             f"got {double_free!r}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.slot_pages = int(slot_pages)
        self.double_free = double_free
        # logical->physical map; unallocated entries hit the scratch page
        self.table = np.full((n_slots, slot_pages), SCRATCH_PAGE, np.int32)
        self._free = list(range(num_pages - 1, SCRATCH_PAGE, -1))
        self._n_alloc = np.zeros(n_slots, np.int64)
        self._matched = np.zeros(n_slots, np.int64)  # leading SHARED pages
        self._reserved = np.zeros(n_slots, np.int64)  # worst-case NEW pages
        self._drawn = np.zeros(n_slots, np.int64)     # new pages drawn so far
        self._live = np.zeros(n_slots, bool)
        self.refcount = np.zeros(num_pages, np.int32)
        self._index: Dict[bytes, int] = {}            # block-hash -> page
        self._published: Dict[int, bytes] = {}        # page -> its index key
        # refcount-0 published pages, oldest-released first (eviction order)
        self._evictable: "OrderedDict[int, None]" = OrderedDict()
        self.total_reserved = 0
        self.total_drawn = 0
        self.pages_in_use = 0         # pinned pages (refcount >= 1), distinct
        self.peak_pages_in_use = 0
        self.pages_allocated = 0      # cumulative private draws (KV stored)
        self.evictions = 0
        self.cow_copies = 0

    @property
    def capacity(self) -> int:
        """Allocatable pages (scratch excluded)."""
        return self.num_pages - 1

    @property
    def cached_pages(self) -> int:
        """Refcount-0 pages kept resident by the prefix index (evictable)."""
        return len(self._evictable)

    @property
    def index_pages(self) -> int:
        """Pages currently published in the prefix index (any refcount)."""
        return len(self._index)

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 0) // self.page_size)

    # ------------------------------------------------------ radix prefix index
    def page_key(self, parent: bytes, tokens: np.ndarray) -> bytes:
        """Chained block hash: one radix-tree edge per full token page."""
        h = hashlib.blake2b(parent, digest_size=16)
        h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
        return h.digest()

    def match_prefix(self, tokens: np.ndarray) -> List[int]:
        """Longest-prefix match of ``tokens`` against the index, in FULL
        pages: walk the hash chain from the root, stop at the first miss.
        Returns the matched physical pages (possibly empty)."""
        ps = self.page_size
        tokens = np.asarray(tokens, np.int32)
        pages: List[int] = []
        key = self._ROOT_KEY
        for p in range(len(tokens) // ps):
            nxt = self.page_key(key, tokens[p * ps:(p + 1) * ps])
            page = self._index.get(nxt)
            if page is None:
                break
            pages.append(page)
            key = nxt
        return pages

    def publish(self, slot: int, tokens: np.ndarray, n_tokens: int) -> int:
        """Publish the slot's completed full pages into the prefix index.

        ``tokens`` are the slot's prompt tokens, ``n_tokens`` how many the
        slot actually holds (its prefilled body).  Only pages FULLY covered
        by ``n_tokens`` are publishable — decode never writes below that
        boundary, so published content is final.  Existing entries win (a
        concurrent identical prefill keeps its pages private).  Returns the
        number of new index entries."""
        ps = self.page_size
        tokens = np.asarray(tokens, np.int32)
        nfull = min(int(n_tokens) // ps, int(self._n_alloc[slot]),
                    len(tokens) // ps)
        key = self._ROOT_KEY
        added = 0
        for p in range(nfull):
            key = self.page_key(key, tokens[p * ps:(p + 1) * ps])
            page = int(self.table[slot, p])
            if key in self._index or page in self._published:
                continue
            self._index[key] = page
            self._published[page] = key
            added += 1
        return added

    def _unpublish(self, page: int) -> None:
        key = self._published.pop(page)
        del self._index[key]
        self._evictable.pop(page, None)

    # --------------------------------------------------------------- admission
    def try_admit(self, slot: int, n_tokens: int,
                  matched: Sequence[int] = (), extra_new: int = 0) -> bool:
        """Admission: map ``matched`` prefix pages into the slot's table
        (refcount++) and claim worst-case NEW pages for the rest.  False if
        the pool cannot take the request right now.  ``extra_new`` reserves
        additional headroom (the CoW copy target when the match covers the
        decode append position)."""
        if self._live[slot]:
            raise PageLifecycleError(
                f"slot {slot} already reserved — reserve/admit must be "
                f"paired with free_slot")
        need_total = self.pages_for(n_tokens)
        matched = list(matched)[:need_total]
        need_new = need_total - len(matched) + int(extra_new)
        if need_total > self.slot_pages:
            return False              # longer than one slot's page table
        newly = sum(1 for p in matched if self.refcount[p] == 0)
        if (self.pages_in_use + newly + self.total_reserved + need_new
                - self.total_drawn > self.capacity):
            return False
        for i, p in enumerate(matched):
            if self.refcount[p] == 0:
                self.pages_in_use += 1
                self._evictable.pop(p, None)
            self.refcount[p] += 1
            self.table[slot, i] = p
        self._n_alloc[slot] = len(matched)
        self._matched[slot] = len(matched)
        self._reserved[slot] = need_new
        self._drawn[slot] = 0
        self._live[slot] = True
        self.total_reserved += need_new
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.pages_in_use)
        return True

    def try_reserve(self, slot: int, n_tokens: int) -> bool:
        """Claim worst-case pages for a request; False if the pool is full.
        (The no-sharing admission path: ``try_admit`` with no matches.)"""
        return self.try_admit(slot, n_tokens)

    def _take_page(self) -> int:
        """Draw a free page; under pressure, evict the oldest-released
        refcount-0 index page (its content is recomputable by definition —
        it was published from a prompt prefix)."""
        if self._free:
            return self._free.pop()
        page, _ = self._evictable.popitem(last=False)
        self._unpublish(page)
        self.evictions += 1
        return page

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Allocate private pages so the slot can hold ``n_tokens``."""
        need = self.pages_for(n_tokens)
        while self._n_alloc[slot] < need:
            if self._drawn[slot] >= self._reserved[slot]:
                raise ReservationError(
                    f"slot {slot} drew {self._drawn[slot]} of "
                    f"{self._reserved[slot]} reserved pages but needs more "
                    f"— reservation bug")
            page = self._take_page()  # cannot fail: admission invariant
            self.refcount[page] = 1
            self.table[slot, self._n_alloc[slot]] = page
            self._n_alloc[slot] += 1
            self._drawn[slot] += 1
            self.total_drawn += 1
            self.pages_in_use += 1
            self.pages_allocated += 1
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.pages_in_use)

    def cow_page(self, slot: int, logical: int) -> Optional[Tuple[int, int]]:
        """Make the slot's ``logical`` page writable (the CoW rule).

        refcount > 1 → draw a private target under the slot's reservation
        and return ``(src, dst)``: the caller must copy the device page
        bytes before writing.  Sole owner but still published → retire the
        index entry and write in place (no copy).  Private and unpublished
        → None, nothing to do.
        """
        src = int(self.table[slot, logical])
        if self.refcount[src] > 1:
            if self._drawn[slot] >= self._reserved[slot]:
                raise ReservationError(
                    f"slot {slot} has no reserved page left for the CoW "
                    f"copy of logical page {logical} — admission bug")
            dst = self._take_page()
            self.refcount[dst] = 1
            self.refcount[src] -= 1
            self.table[slot, logical] = dst
            self._drawn[slot] += 1
            self.total_drawn += 1
            self.pages_in_use += 1
            self.pages_allocated += 1
            self.peak_pages_in_use = max(self.peak_pages_in_use,
                                         self.pages_in_use)
            self.cow_copies += 1
            return (src, dst)
        if src in self._published:
            self._unpublish(src)
        return None

    def free_slot(self, slot: int) -> None:
        """Release the slot: decrement every mapped page's refcount and
        return the reservation.  Pages hitting refcount 0 go back to the
        free list unless published — those stay resident in the prefix
        index (evictable under pressure) so later requests can share them.
        """
        if not self._live[slot]:
            if self.double_free == "ignore":
                return
            raise PageLifecycleError(
                f"double free: slot {slot} is not reserved (free_slot "
                f"without a matching try_reserve/try_admit)")
        for i in range(int(self._n_alloc[slot])):
            p = int(self.table[slot, i])
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self.pages_in_use -= 1
                if p in self._published:
                    self._evictable[p] = None   # resident, matchable, LRU
                else:
                    self._free.append(p)
        self.table[slot, :] = SCRATCH_PAGE
        self._n_alloc[slot] = 0
        self._matched[slot] = 0
        self.total_reserved -= int(self._reserved[slot])
        self.total_drawn -= int(self._drawn[slot])
        self._reserved[slot] = 0
        self._drawn[slot] = 0
        self._live[slot] = False


class HostPager:
    """The host-side paging companion both engines own when ``page_size``
    is set: PagePool lifecycle, the per-slot length mirror (so the decode
    loop never syncs ``len`` off the device), admission queries (now
    prefix-matching against the pool's radix index), CoW scheduling, and
    byte accounting.  The jitted gather/scatter/seed programs stay with
    each engine (they bind its own decode step); every host-side decision
    lives here exactly once.
    """

    def __init__(self, page_size: int, num_pages: Optional[int],
                 max_len: int):
        if max_len % page_size != 0:
            raise ValueError(
                f"max_len ({max_len}) must be a multiple of page_size "
                f"({page_size}) so the page table tiles the cache exactly")
        self.page_size = int(page_size)
        self.max_len = int(max_len)
        self.slot_pages = max_len // page_size
        self._num_pages_opt = num_pages
        self.pool: Optional[PagePool] = None
        self.host_len = None
        self._table_dev = None     # device copy, invalidated on table writes
        # prefix sharing: armed by the engine's init_slot_cache when the
        # knob is on AND every dynamic cache leaf actually pages
        self.prefix_on = False
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0

    def reset(self, n_slots: int) -> PagePool:
        """Fresh pool (and prefix index) + length mirror for a new slot
        cache."""
        num_pages = (self._num_pages_opt if self._num_pages_opt is not None
                     else n_slots * self.slot_pages + 1)   # +1: scratch
        self.pool = PagePool(num_pages, self.page_size, n_slots,
                             self.slot_pages)
        self.host_len = np.zeros((n_slots,), np.int64)
        self._table_dev = None
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        return self.pool

    def _tokens_for(self, prompt_len: int, max_new: int) -> int:
        return prompt_len - 1 + max_new

    def try_reserve(self, slot: int, prompt_len: int, max_new: int) -> bool:
        return self.pool.try_reserve(slot,
                                     self._tokens_for(prompt_len, max_new))

    def admit(self, slot: int, prompt: np.ndarray, max_new: int,
              chunk: Optional[int] = None) -> Optional[int]:
        """Admission with radix prefix matching.

        Matches the prompt against the index in full pages, maps the
        matched pages into the slot's table (refcount++) and reserves
        worst-case NEW pages for the rest.  Returns the number of CACHED
        tokens (0 = no reuse), or None when the pool cannot take the
        request right now (the scheduler waits for frees).

        Match capping rules (DESIGN.md §7):
          * a match covering the whole prompt body skips prefill entirely
            (``cached = body``); when it overshoots the body — the full
            prompt including the decode-input token is indexed — the last
            matched page contains the decode append position, so one extra
            page is reserved for its CoW copy;
          * a partial match is rounded DOWN to a multiple of
            ``lcm(page_size, chunk)`` so the tail chunk stream starts
            chunk-aligned (the lm block chunk path writes full fixed-width
            chunks); without chunked prefill (``chunk=None``) only
            whole-body matches are usable, partial ones are dropped.
        """
        prompt = np.asarray(prompt, np.int32)
        body = len(prompt) - 1
        total = self._tokens_for(len(prompt), max_new)
        if not self.prefix_on or body < 1:
            return 0 if self.pool.try_admit(slot, total) else None
        pages = self.pool.match_prefix(prompt)
        m_tok = len(pages) * self.page_size
        cow = 0
        if pages and m_tok >= body:
            cached = body
            cow = 1 if m_tok > body else 0
        elif pages and chunk:
            quantum = math.lcm(self.page_size, int(chunk))
            m_tok = (m_tok // quantum) * quantum
            pages = pages[:m_tok // self.page_size]
            cached = m_tok
        else:
            pages, cached = [], 0
        if not self.pool.try_admit(slot, total, matched=pages,
                                   extra_new=cow):
            return None
        if cached:
            self.prefix_hits += 1
            self.prefix_hit_tokens += cached
            self._table_dev = None
        return cached

    def can_ever_admit(self, prompt_len: int, max_new: int) -> bool:
        """Static capacity check: could this request be admitted into an
        IDLE pool?  False means waiting for frees can never help — the
        scheduler rejects immediately instead of head-of-line blocking.
        (Deliberately prefix-blind: a hit could shrink the new-page need,
        but index contents are transient, so admission stays worst-case.)"""
        need = self.pool.pages_for(self._tokens_for(prompt_len, max_new))
        return need <= min(self.pool.slot_pages, self.pool.capacity)

    def free(self, slot: int) -> None:
        self.pool.free_slot(slot)
        self.host_len[slot] = 0
        self._table_dev = None

    def _ensure(self, slot: int, n_tokens: int) -> None:
        before = self.pool.pages_in_use
        self.pool.ensure(slot, n_tokens)
        if self.pool.pages_in_use != before:
            self._table_dev = None

    def note_insert(self, slot: int, n_tokens: int) -> None:
        """Allocate the admitted prompt's pages, mirror its length."""
        self._ensure(slot, n_tokens)
        self.host_len[slot] = n_tokens

    def publish(self, slot: int, prompt: np.ndarray) -> int:
        """Publish the slot's completed full prefill pages (positions below
        its prefilled body) into the prefix index.  No-op when prefix
        sharing is off."""
        if not self.prefix_on:
            return 0
        prompt = np.asarray(prompt, np.int32)
        return self.pool.publish(slot, prompt, int(self.host_len[slot]))

    def pre_decode(self, active: np.ndarray) -> List[Tuple[int, int]]:
        """Make every active slot's append position writable and allocated.

        Each active slot writes at position ``len``: if that position falls
        inside a SHARED or published page (a whole-prompt prefix hit), the
        CoW rule fires first — returns the ``(src, dst)`` physical page
        pairs whose device bytes the engine must copy before dispatching
        the step.  Then allocates any fresh page the step grows into."""
        copies: List[Tuple[int, int]] = []
        for s in np.flatnonzero(active):
            pos = int(self.host_len[s])
            pi = pos // self.page_size
            if pi < int(self.pool._n_alloc[s]):
                op = self.pool.cow_page(int(s), pi)
                if op is not None:
                    copies.append(op)
                    self._table_dev = None
            self._ensure(s, pos + 1)
        return copies

    def post_decode(self, active: np.ndarray) -> None:
        self.host_len[active] += 1

    def table(self) -> jnp.ndarray:
        """Device copy of the page table, re-uploaded only when a table
        entry actually changed (steady-state decode reuses it)."""
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self.pool.table)
        return self._table_dev

    def row(self, slot: int) -> jnp.ndarray:
        return jnp.asarray(self.pool.table[slot])

    def insert_row(self, slot: int) -> jnp.ndarray:
        """Table row for the slot's INSERT program: matched prefix entries
        are redirected to the scratch page, so the B=1 request cache's
        blocks land only on the slot's private tail pages — the shared
        prefix pages are never written (they already hold the content the
        seed gathered from them)."""
        row = self.pool.table[slot].copy()
        row[:int(self.pool._matched[slot])] = SCRATCH_PAGE
        return jnp.asarray(row)

    def stats(self, cache: Any, sa: Any) -> Dict[str, int]:
        """Resident-cache accounting for the paged-vs-dense benchmark."""
        total = sum(int(a.nbytes) for a in jax.tree.leaves(cache))
        page_bytes = page_token_bytes(cache, sa, self.pool.num_pages,
                                      self.page_size) * self.page_size
        dense_leaves = total - pool_bytes(cache, sa)
        return {
            "cache_bytes": total,
            "page_size": self.page_size,
            "num_pages": self.pool.num_pages,
            # dtype-aware: pool_bytes/page_bytes come from the leaves'
            # actual nbytes (quantized codes + scales included), not page
            # counts x a dense assumption
            "pool_bytes": pool_bytes(cache, sa),
            "page_bytes": page_bytes,
            "pages_in_use": self.pool.pages_in_use,
            "peak_pages_in_use": self.pool.peak_pages_in_use,
            "pages_allocated": self.pool.pages_allocated,
            "peak_kv_bytes_in_use":
                dense_leaves + self.pool.peak_pages_in_use * page_bytes,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "index_pages": self.pool.index_pages,
            "cached_index_pages": self.pool.cached_pages,
            "evictions": self.pool.evictions,
            "cow_copies": self.pool.cow_copies,
        }


def _path_entry_key(entry) -> Any:
    """The dict key / attr name / sequence index of one KeyPath entry."""
    for attr in ("key", "name", "idx"):
        if hasattr(entry, attr):
            return getattr(entry, attr)
    return None


def _is_len_path(path) -> bool:
    """True for the cache's ``len`` leaf (the per-slot length vector)."""
    return bool(path) and _path_entry_key(path[-1]) == "len"


class PagedEngineMixin:
    """The slot-protocol paging hooks both serving engines share verbatim.

    An engine mixes this in and maintains two attributes: ``_pager`` (a
    :class:`HostPager`, or None when constructed dense) and
    ``_paging_active`` (set by its ``init_slot_cache`` — False when the
    family has no paging leaves and fell back to the dense layout), plus a
    ``_stats_seq_axes()`` hook returning its per-leaf sequence-axis tree.

    ``paged_attn`` selects the paged decode discipline: ``"inplace"`` (the
    default) computes attention directly through the page table
    (``ops.paged_decode_attention`` — no dense-view transient, O(live
    tokens) KV reads per slot); ``"gather"`` keeps the PR-3 reference path
    (gather dense view -> family ``decode_step`` -> scatter one token) as
    the fallback/oracle the parity suite checks the kernel against.

    ``prefix_cache`` arms shared-prefix KV reuse (DESIGN.md §7): admission
    radix-matches the prompt against the pool's block-hash index, maps the
    matched full pages into the slot's table (refcount++, zero prefill
    work) and only the unmatched tail is prefilled — seeded from a
    gathered B=1 prefix view so the absolute-position chunk attention
    continues from the cached position.  It engages only when EVERY
    dynamic cache leaf pages (``len`` aside): recurrent state and
    sliding-window ring buffers are slot-private dense leaves that a
    shared page cannot restore, so those families run a no-op index and
    fall back to full prefill — token-identical either way.
    """

    _pager: Optional[HostPager] = None
    _paging_active: bool = False
    _paged_insert_jit = None
    _paged_attn: str = "inplace"
    _prefix_cache_on: bool = False
    _prefix_shareable: bool = False
    _seed_jit = None
    _cow_jit = None
    _kv_tok_bytes: int = 0       # per-token-per-slot seq-scaling cache bytes
    _kv_quant_tok_bytes: Optional[float] = None  # quantized-pool figure
    _kv_dtype: str = "bf16"      # pool storage format (engines override)
    _kv_shards: int = 1          # TP head cut of the pool (1 = replicated)
    _slot_count: int = 0
    # TP serving mesh placements (None = single-device / unspecified): the
    # engine's ``init_slot_cache`` fills these with NamedSharding pytrees so
    # every mixin jit pins its pool/request-cache layout explicitly — the
    # sharded jit caches stay stable (zero steady-state recompiles).
    _pool_sh = None              # paged slot-cache placement pytree
    _b1_sh = None                # B=1 request-cache placement pytree

    def _stats_seq_axes(self):
        raise NotImplementedError

    def will_page(self) -> bool:
        """Whether ``init_slot_cache`` will engage the page pool — THE
        paging-leaf discovery rule (a ``page_size`` plus at least one
        sequence-scaling leaf), shared by the engines' fallback decision,
        the in-place/shard_map refusal, and serve_bench's discipline
        selection."""
        if getattr(self, "page_size", None) is None:
            return False
        return any(ax >= 0 for ax in jax.tree.leaves(self._stats_seq_axes()))

    @staticmethod
    def check_paged_attn(paged_attn: str) -> str:
        if paged_attn not in ("inplace", "gather"):
            raise ValueError(
                f"paged_attn must be 'inplace' or 'gather', got {paged_attn!r}")
        return paged_attn

    @staticmethod
    def check_prefix_cache(prefix_cache: str) -> bool:
        if prefix_cache not in ("on", "off"):
            raise ValueError(
                f"prefix_cache must be 'on' or 'off', got {prefix_cache!r}")
        return prefix_cache == "on"

    def _note_slot_cache(self, n_slots: int, cache_shape: Any, ba: Any,
                         sa: Any, kv_shards: int = 1) -> None:
        """Record the slot-cache geometry the KV-read accounting needs
        (called by both engines' ``init_slot_cache``, every layout), and
        decide prefix shareability: reuse is sound only when every dynamic
        cache leaf pages — a leaf that batch-indexes but does NOT page
        (ring K/V, recurrent state) is slot-private state a shared page
        cannot restore, so its presence demotes the prefix index to a
        no-op (``len`` is exempt: the seed program sets it directly).

        ``kv_shards`` is the TP head cut of the KV state (DESIGN.md §11):
        the aggregate read model is unchanged (``_kv_tok_bytes`` stays the
        full-model figure so every gate and exactness assertion holds
        verbatim), but per-shard accounting —
        ``kv_token_bytes(..., kv_shards)`` × shards == full — is exposed
        through :meth:`cache_stats`."""
        self._slot_count = int(n_slots)
        self._kv_tok_bytes = kv_token_bytes(cache_shape, ba, sa)
        self._kv_shards = int(kv_shards)
        if self._kv_shards > 1:     # validates exact divisibility
            kv_token_bytes(cache_shape, ba, sa, self._kv_shards)
        leaves = jax.tree_util.tree_flatten_with_path(sa)[0]
        self._prefix_shareable = all(
            ax >= 0 or _is_len_path(path) for path, ax in leaves)

    # ------------------------------------------------ host KV-read accounting
    def _kv_bytes(self, tokens) -> int:
        """KV bytes ``tokens`` token-positions occupy in the slot cache's
        STORAGE format: the quantized per-token figure (1-byte codes plus
        page-amortized scales — ``kv_token_bytes_quant``) when the pool is
        quantized, the dense figure otherwise.  Every host_read channel
        that reads or copies POOL bytes routes through here, so quantizing
        the pool shrinks the measured KV traffic accordingly."""
        if self._kv_quant_tok_bytes is not None:
            return int(round(tokens * self._kv_quant_tok_bytes))
        return int(tokens * self._kv_tok_bytes)

    def _dense_view_read_bytes(self) -> int:
        """Bytes one masked decode step reads through a dense (or gathered)
        ``(max_slots, ..., max_len, ...)`` KV view: every slot's full
        allocation, live or not.  Deliberately the DENSE figure even under
        a quantized pool — the gather discipline materializes and reads the
        dequantized dense-view transient."""
        return self._slot_count * self.max_len * self._kv_tok_bytes

    def kv_read_bytes_step(self, active: np.ndarray) -> int:
        """KV-cache bytes ONE decode step reads under the engine's current
        discipline's read MODEL (replayed host-side like every meter entry,
        not a hardware counter).  The in-place paged discipline touches only
        the LIVE pages — ``ceil((len + is_active)/page_size)`` per occupied
        slot, since the kernel's grid walks EVERY slot's table but fetches
        real pages only up to its length (free slots hold length 0 and
        all-scratch tables; the dead tail lands on the one hot scratch
        page).  Eq. 7-10's intent: traffic proportional to live tokens.
        The gather and dense disciplines materialize/read the full
        ``max_slots x max_len`` view regardless of occupancy."""
        if self._paging_active and self._paged_attn == "inplace":
            ps = self._pager.page_size
            lens = self._pager.host_len + np.asarray(active, bool)
            pages_touched = int(-((lens[lens > 0]) // -ps).sum())
            return self._kv_bytes(pages_touched * ps)
        return self._dense_view_read_bytes()

    def _meter_kv_read(self, active: np.ndarray) -> None:
        n = self.kv_read_bytes_step(active)
        if n:
            self.meter.host_read("kv_cache_read", n)

    def gather_transient_bytes_per_step(self) -> int:
        """Dense-view TRANSIENT bytes one paged decode step materializes:
        the gather discipline copies every live slot's full dense view per
        dispatch; the in-place discipline (and the dense layout, whose
        cache IS the view) materializes none.  The serve_bench regression
        gate for the eliminated copy."""
        if self._paging_active and self._paged_attn == "gather":
            return self._dense_view_read_bytes()
        return 0

    def paged_insert(self, batched_cache, single_cache, slot: int,
                     ba: Any, sa: Any, n_tokens: int):
        """Admit one prefilled B=1 dense cache into the pool: allocate the
        slot's pages, then scatter its page blocks through the (traced)
        table row — one compiled program for every slot and assignment.
        Matched prefix entries of the row are redirected to scratch
        (``HostPager.insert_row``): the shared pages already hold the
        prefix content and must never be written.  Callers wrap this in
        their mesh context where needed."""
        self._pager.note_insert(slot, n_tokens)
        if self._paged_insert_jit is None:
            def insert(pcache, single, row, s, n):
                return insert_tree(pcache, single, row, s, ba, sa,
                                   n_tokens=n)

            kw = {}
            if self._pool_sh is not None:
                kw = dict(in_shardings=(self._pool_sh, self._b1_sh,
                                        None, None, None),
                          out_shardings=self._pool_sh)
            self._paged_insert_jit = jax.jit(insert, donate_argnums=(0,),
                                             **kw)
        return self._paged_insert_jit(batched_cache, single_cache,
                                      self._pager.insert_row(slot),
                                      jnp.int32(slot), jnp.int32(n_tokens))

    # ------------------------------------------------- shared-prefix KV reuse
    def prefix_cache_armed(self) -> bool:
        """Whether the engine was CONSTRUCTED with the prefix cache on (a
        pre-``init_slot_cache`` predicate — shareability is not known yet).
        The scheduler's warmup keys its prefix warm trace on this."""
        return (self._prefix_cache_on
                and getattr(self, "page_size", None) is not None)

    def prefix_sharing_active(self) -> bool:
        """Whether admission actually radix-matches: the knob is on, the
        slot cache pages, and every dynamic leaf is poolable."""
        return (self._paging_active and self._prefix_cache_on
                and self._prefix_shareable)

    def admit_slot(self, slot: int, prompt: np.ndarray, max_new: int,
                   chunk: Optional[int] = None) -> Optional[int]:
        """Admission control with prefix reuse: returns the CACHED token
        count (0 = admitted with no reuse; dense engines always 0), or
        None when the paged pool cannot take the request right now and the
        scheduler should wait for running requests to free pages.
        ``chunk`` is the scheduler's prefill chunk width (alignment quantum
        for partial matches)."""
        if not self._paging_active:
            return 0
        cached = self._pager.admit(
            slot, prompt, max_new,
            chunk if self.prefix_sharing_active() else None)
        if cached:
            # host-local accounting channel (excluded from eq. 7-10): the
            # prefill KV bytes the prefix hit did NOT recompute/store —
            # measured in the pool's STORAGE format (quantized pools save
            # quantized bytes)
            self.meter.host_read("prefix_prefill_saved",
                                 self._kv_bytes(cached))
        return cached

    def publish_prefix(self, slot: int, prompt: np.ndarray) -> None:
        """Publish the slot's completed full prefill pages into the prefix
        index (post-insert hook; no-op when sharing is inactive)."""
        if self._paging_active:
            self._pager.publish(slot, prompt)

    def paged_seed(self, batched_cache, slot: int, cached_len: int,
                   ba: Any, sa: Any, b1_shape: Any):
        """The prefix-aware prefill entry: gather the slot's matched prefix
        pages into a fresh B=1 request cache with ``len = cached_len``.
        The tail chunk stream (``prefill_chunk_slot``) continues from that
        position — the absolute-position chunk attention path needs no
        change.  ``b1_shape`` is the engine's B=1 request-cache eval_shape
        (same pytree as the slot cache).  One compiled program covers
        every slot, match length and page assignment (row/len traced)."""
        if self._seed_jit is None:
            def seed(pcache, row, m):
                def leaf(path, sh, b_ax, s_ax, pl):
                    if s_ax >= 0:
                        return gather_view(pl, row[None, :], b_ax, s_ax)
                    if _is_len_path(path):
                        return jnp.full(sh.shape, m, sh.dtype)
                    # unreachable when prefix sharing is active (the
                    # shareability rule excludes other dense leaves), but
                    # keep the seed total
                    return jnp.zeros(sh.shape, sh.dtype)

                return jax.tree_util.tree_map_with_path(
                    leaf, b1_shape, ba, sa, pcache)

            kw = {}
            if self._pool_sh is not None:
                kw = dict(in_shardings=(self._pool_sh, None, None),
                          out_shardings=self._b1_sh)
            self._seed_jit = jax.jit(seed, **kw)
        return self._seed_jit(batched_cache, self._pager.row(slot),
                              jnp.int32(cached_len))

    def apply_cow_copies(self, cache, copies, ba: Any, sa: Any):
        """Copy the device bytes of each CoW'd page (src -> dst) in every
        pool leaf.  Compiles once (traced page ids); runs only on CoW
        events — a whole-prompt prefix hit's first decode step — never in
        the steady state."""
        if not copies:
            return cache
        if self._cow_jit is None:
            def copy(pcache, src, dst):
                def leaf(b_ax, s_ax, p):
                    if s_ax < 0:
                        return p
                    if isinstance(p, QuantizedLeaf):
                        # scales travel with their page: a CoW'd page keeps
                        # encoding the same values in its private copy
                        cl = _pages_leading(p.codes, b_ax, s_ax)
                        sl = _scales_leading(p.scales, b_ax, s_ax)
                        return QuantizedLeaf(
                            _pages_restore(cl.at[dst].set(cl[src]),
                                           b_ax, s_ax),
                            _scales_restore(sl.at[dst].set(sl[src]),
                                            b_ax, s_ax),
                            p.kv_dtype, p.out_dtype)
                    pl = _pages_leading(p, b_ax, s_ax)
                    pl = pl.at[dst].set(pl[src])
                    return _pages_restore(pl, b_ax, s_ax)

                return jax.tree.map(leaf, ba, sa, pcache)

            kw = {}
            if self._pool_sh is not None:
                kw = dict(in_shardings=(self._pool_sh, None, None),
                          out_shardings=self._pool_sh)
            self._cow_jit = jax.jit(copy, donate_argnums=(0,), **kw)
        page_bytes = self._kv_bytes(self._pager.page_size)
        for src, dst in copies:
            cache = self._cow_jit(cache, jnp.int32(src), jnp.int32(dst))
            self.meter.host_read("page_cow_copy", page_bytes)
        return cache

    def paged_pre_step(self, cache, active: np.ndarray, ba: Any, sa: Any):
        """Host work before one paged decode step: CoW-protect and allocate
        every active slot's append position, apply any required page
        copies, and meter the step's KV reads.  Returns the (possibly
        copied-into) cache."""
        copies = self._pager.pre_decode(active)
        cache = self.apply_cow_copies(cache, copies, ba, sa)
        self._meter_kv_read(active)
        return cache

    def reserve_slot(self, slot: int, prompt_len: int, max_new: int) -> bool:
        """Admission control: claim worst-case pages for a request.  Dense
        slot caches always admit; a paged pool may ask the scheduler to
        wait until running requests free pages.  (The prefix-aware entry
        point is :meth:`admit_slot`; this stays as the plain-reservation
        protocol hook.)"""
        if not self._paging_active:
            return True
        return self._pager.try_reserve(slot, prompt_len, max_new)

    def can_ever_admit(self, prompt_len: int, max_new: int) -> bool:
        """False when the request exceeds the pool's STATIC capacity: no
        amount of waiting for frees can help, so the scheduler rejects it
        immediately instead of head-of-line blocking the queue."""
        if not self._paging_active:
            return True
        return self._pager.can_ever_admit(prompt_len, max_new)

    def free_slot(self, slot: int) -> None:
        """Release a finished request's pages (no-op for the dense layout)."""
        if self._paging_active:
            self._pager.free(slot)

    def cache_stats(self, cache: Any) -> Dict[str, int]:
        """Resident-cache accounting for the paged-vs-dense benchmark.

        ``cache_bytes`` is the allocation backing the slot cache;
        ``peak_kv_bytes_in_use`` is what the pages actually held at peak
        (== cache_bytes for the dense layout, where every slot pins
        ``max_len`` positions whether it uses them or not).  NOTE these
        measure the PERSISTENT cache state; the per-dispatch dense-view
        transient on top of it is ``gather_transient_bytes_per_step()`` —
        nonzero only under the ``paged_attn="gather"`` fallback, zero for
        the default in-place discipline (module docstring, DESIGN.md §6).
        """
        if not self._paging_active:
            total = sum(int(a.nbytes) for a in jax.tree.leaves(cache))
            return {"cache_bytes": total, "peak_kv_bytes_in_use": total}
        stats = self._pager.stats(cache, self._stats_seq_axes())
        stats["kv_shards"] = self._kv_shards
        stats["kv_token_bytes_per_shard"] = (
            self._kv_tok_bytes // self._kv_shards)
        # dtype-aware capacity accounting (DESIGN.md §13): pool bytes and
        # the per-token STORAGE cost in the pool's actual format, so the
        # serve_bench resident-token gate is checkable from the artifact
        stats["kv_dtype"] = self._kv_dtype
        stats["kv_token_bytes_stored"] = (
            self._kv_quant_tok_bytes if self._kv_quant_tok_bytes is not None
            else self._kv_tok_bytes)
        return stats


# ----------------------------------------------------------------------------
# Layout discovery (shape diffing, like slots.batch_axes)
# ----------------------------------------------------------------------------
def seq_axes(cache_a: Any, cache_b: Any, delta: int) -> Any:
    """Per-leaf sequence-axis pytree; -1 where the leaf does not page.

    ``cache_a``/``cache_b`` are the same family cache built with two
    ``max_len`` values ``delta`` apart (ShapeDtypeStructs are fine).  A leaf
    pages only if exactly one axis grew by exactly ``delta`` — ring buffers
    capped at a window, recurrent state and ``len`` all stay dense (-1),
    which is the recurrent families' no-op page table.
    """
    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        if len(diffs) == 1 and b.shape[diffs[0]] - a.shape[diffs[0]] == delta:
            return diffs[0]
        return -1

    return jax.tree.map(axis, cache_a, cache_b)


def page_axis(b_ax: int, s_ax: int) -> int:
    """Leading axis of the ``(num_pages, page_size)`` pair in a pool leaf.

    The kernel-friendly layout keeps every non-(B, S) axis in its dense
    order and drops the page axes exactly where the batch axis sat, so
    layer-leading caches stay ``lax.scan``-sweepable and per-layer pool
    slices land in the ``(num_pages, page_size, *tail)`` operand layout
    ``kernels/paged_attention.py`` expects.
    """
    return b_ax - (1 if 0 <= s_ax < b_ax else 0)


def _pages_leading(pool: jnp.ndarray, b_ax: int, s_ax: int) -> jnp.ndarray:
    """View a pool leaf with the (num_pages, page_size) axes leading."""
    pax = page_axis(b_ax, s_ax)
    return jnp.moveaxis(pool, (pax, pax + 1), (0, 1))


def _pages_restore(pool: jnp.ndarray, b_ax: int, s_ax: int) -> jnp.ndarray:
    pax = page_axis(b_ax, s_ax)
    return jnp.moveaxis(pool, (0, 1), (pax, pax + 1))


def pool_shape(cache_shape: Any, ba: Any, sa: Any, num_pages: int,
               page_size: int, kv_dtype: str = "bf16") -> Any:
    """ShapeDtypeStruct pytree of the paged slot cache (``make_pool``
    without the allocation) — what the sharding rules and eval_shape-based
    plumbing consume.  With ``kv_dtype`` other than "bf16" every pool leaf
    becomes a :class:`QuantizedLeaf`: codes in the pool layout at the
    quantized dtype plus per-page × per-kv-head float32 scales (the pool
    shape minus the ``page_size`` axis and the trailing head_dim axis)."""
    def leaf(a, b_ax, s_ax):
        if s_ax < 0:
            return jax.ShapeDtypeStruct(a.shape, a.dtype)
        rest = tuple(d for i, d in enumerate(a.shape) if i not in (b_ax, s_ax))
        pax = page_axis(b_ax, s_ax)
        shape = rest[:pax] + (num_pages, page_size) + rest[pax:]
        if kv_dtype == "bf16":
            return jax.ShapeDtypeStruct(shape, a.dtype)
        sc_shape = shape[:pax + 1] + shape[pax + 2:-1]
        return QuantizedLeaf(
            jax.ShapeDtypeStruct(shape, KV_DTYPES[kv_dtype]),
            jax.ShapeDtypeStruct(sc_shape, jnp.float32),
            kv_dtype, jnp.dtype(a.dtype).name)

    return jax.tree.map(leaf, cache_shape, ba, sa)


def make_pool(cache_shape: Any, ba: Any, sa: Any, num_pages: int,
              page_size: int, shardings: Any = None,
              kv_dtype: str = "bf16") -> Any:
    """Allocate the paged slot cache: pool layout for paging leaves, dense
    ``(max_slots, ...)`` zeros for everything else.  Same pytree structure
    as the dense cache, so engines keep one cache object either way
    (quantized pools substitute a :class:`QuantizedLeaf` per pool leaf —
    a registered pytree node, so the structure contract still holds).

    ``shardings`` (optional) is a matching pytree of ``jax.sharding``
    placements — the TP serving mesh allocates each pool leaf directly in
    its head-cut layout, so no full replica ever materializes."""
    shapes = pool_shape(cache_shape, ba, sa, num_pages, page_size, kv_dtype)
    if shardings is None:
        return jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), shapes)
    return jax.tree.map(lambda a, sh: jnp.zeros(a.shape, a.dtype, device=sh),
                        shapes, shardings)


def pool_bytes(pcache: Any, sa: Any) -> int:
    """Resident bytes of the pool leaves (the paged share of the cache) —
    dtype-aware: a quantized leaf counts its codes AND scale arrays."""
    sizes = jax.tree.map(lambda s_ax, a: int(a.nbytes) if s_ax >= 0 else 0,
                         sa, pcache)
    return sum(jax.tree.leaves(sizes))


def page_token_bytes(pcache: Any, sa: Any, num_pages: int,
                     page_size: int) -> int:
    """KV bytes per token summed over the paged leaves (pool bytes spread
    over the pool's ``num_pages * page_size`` token positions)."""
    return pool_bytes(pcache, sa) // (int(num_pages) * int(page_size))


def kv_token_bytes(cache_shape: Any, ba: Any, sa: Any,
                   kv_shards: int = 1) -> int:
    """Per-token-per-slot bytes of the sequence-scaling cache leaves, from
    the DENSE cache shapes (paged or not: the same KV bytes per token).
    The denominator of the live-page read accounting (TrafficMeter
    ``host_read``) and of the gather-transient metric in serve_bench.

    ``kv_shards`` > 1 returns the PER-SHARD bytes of the head-cut TP pool
    (DESIGN.md §11): each model shard owns ``Hkv/kv_shards`` of every KV
    leaf, so per-shard bytes are exactly ``full/kv_shards`` — summed over
    the shards they reproduce the single-device accounting to the byte.
    The shard count must divide the total (callers pass 1 when any leaf's
    head dim is indivisible — the replication fallback)."""
    def per_tok(a, b_ax, s_ax):
        if s_ax < 0:
            return 0
        n = int(math.prod(a.shape)) // (a.shape[b_ax] * a.shape[s_ax])
        return n * jnp.dtype(a.dtype).itemsize

    sizes = jax.tree.map(per_tok, cache_shape, ba, sa)
    total = sum(jax.tree.leaves(sizes))
    kv_shards = int(kv_shards)
    if kv_shards > 1:
        if total % kv_shards != 0:
            raise ValueError(
                f"kv_token_bytes ({total}) not divisible by kv_shards "
                f"({kv_shards}) — per-shard accounting would not sum "
                f"exactly; use kv_shards=1 (replicated fallback)")
        return total // kv_shards
    return total


def kv_token_bytes_quant(cache_shape: Any, ba: Any, sa: Any,
                         page_size: int, kv_dtype: str) -> float:
    """Per-token bytes of the QUANTIZED pool leaves (DESIGN.md §13): the
    1-byte codes plus the per-page × per-kv-head float32 scales amortized
    over ``page_size`` token positions.  From the DENSE cache shapes, like
    :func:`kv_token_bytes` — may be fractional (the scale amortization),
    so callers round at the meter boundary (``PagedEngineMixin._kv_bytes``).
    """
    itemsize = jnp.dtype(KV_DTYPES[kv_dtype]).itemsize

    def per_tok(a, b_ax, s_ax):
        if s_ax < 0:
            return 0.0
        n = int(math.prod(a.shape)) // (a.shape[b_ax] * a.shape[s_ax])
        return n * itemsize + (n // a.shape[-1]) * 4.0 / int(page_size)

    sizes = jax.tree.map(per_tok, cache_shape, ba, sa)
    return float(sum(jax.tree.leaves(sizes)))


# ----------------------------------------------------------------------------
# Traced page-table ops (fixed shapes, traced indices — compile once)
# ----------------------------------------------------------------------------
def _scales_leading(scales: jnp.ndarray, b_ax: int, s_ax: int) -> jnp.ndarray:
    """View a scale array with its page axis leading (scales have no
    page_size axis, so only one move)."""
    return jnp.moveaxis(scales, page_axis(b_ax, s_ax), 0)


def _scales_restore(scales: jnp.ndarray, b_ax: int, s_ax: int) -> jnp.ndarray:
    return jnp.moveaxis(scales, 0, page_axis(b_ax, s_ax))


def gather_view(pool, table: jnp.ndarray, b_ax: int,
                s_ax: int) -> jnp.ndarray:
    """Reassemble one paged leaf into its dense ``(..., B, ..., S, ...)``
    view through the page table ``(B, P)``.  This materializes the
    O(B x max_len) transient the in-place paged attention path exists to
    avoid — fallback/oracle and prefix-seed only (DESIGN.md §6).

    A :class:`QuantizedLeaf` gathers codes and scales together and
    DEQUANTIZES: power-of-two scales make the product exact even in a
    bfloat16 ``out_dtype`` (layers.kv_dequantize), so the dense view is
    bit-stable — the prefix seed path depends on that."""
    B, P = table.shape
    if isinstance(pool, QuantizedLeaf):
        cl = _pages_leading(pool.codes, b_ax, s_ax)    # (N, ps, *rest)
        sl = _scales_leading(pool.scales, b_ax, s_ax)  # (N, *rest[:-1])
        g = cl[table]                                  # (B, P, ps, *rest)
        gs = jnp.expand_dims(sl[table], (2, sl.ndim + 2))
        d = (g.astype(jnp.float32) * gs).astype(jnp.dtype(pool.out_dtype))
        d = d.reshape((B, P * cl.shape[1]) + cl.shape[2:])
        return jnp.moveaxis(d, (0, 1), (b_ax, s_ax))
    p = _pages_leading(pool, b_ax, s_ax)               # (N, ps, *rest)
    ps = p.shape[1]
    g = p[table]                                       # (B, P, ps, *rest)
    g = g.reshape((B, P * ps) + p.shape[2:])           # (B, S, *rest)
    return jnp.moveaxis(g, (0, 1), (b_ax, s_ax))


def gather_tree(pcache: Any, table: jnp.ndarray, ba: Any, sa: Any) -> Any:
    """Dense-view pytree: paged leaves gathered, dense leaves passed through.
    The result is exactly the cache pytree the family decode_step expects.
    (``ba`` leads the tree.map so quantized pool subtrees arrive whole.)"""
    return jax.tree.map(
        lambda b_ax, s_ax, p: p if s_ax < 0
        else gather_view(p, table, b_ax, s_ax),
        ba, sa, pcache)


def _take_token(leaf: jnp.ndarray, pos: jnp.ndarray, b_ax: int,
                s_ax: int) -> jnp.ndarray:
    """Slice per-slot position ``pos[b]`` along the seq axis -> (B, *rest)."""
    B = pos.shape[0]
    idx_shape = [1] * leaf.ndim
    idx_shape[b_ax] = B
    idx = pos.reshape(idx_shape).astype(jnp.int32)
    tok = jnp.take_along_axis(leaf, idx, axis=s_ax)
    tok = jnp.squeeze(tok, axis=s_ax)
    return jnp.moveaxis(tok, b_ax - (1 if s_ax < b_ax else 0), 0)


def scatter_token(pool, table: jnp.ndarray,
                  new_leaf: jnp.ndarray, pos: jnp.ndarray,
                  write: jnp.ndarray, b_ax: int, s_ax: int):
    """Write each active slot's token at ``pos[b]`` from the updated dense
    view back into its page; inactive slots land on the scratch page.
    Quantized pools route through the shared quantize-on-write append core
    (``layers.quant_page_append``) so the gather discipline's writeback and
    the in-place append encode pages identically."""
    if isinstance(pool, QuantizedLeaf):
        cl = _pages_leading(pool.codes, b_ax, s_ax)
        sl = _scales_leading(pool.scales, b_ax, s_ax)
        tok = _take_token(new_leaf, pos, b_ax, s_ax)   # (B, *rest)
        page, off = page_offsets(table, pos, write, cl.shape[1])
        cl, sl = quant_page_append(cl, sl, tok, page, off, pool.kv_dtype)
        return QuantizedLeaf(_pages_restore(cl, b_ax, s_ax),
                             _scales_restore(sl, b_ax, s_ax),
                             pool.kv_dtype, pool.out_dtype)
    p = _pages_leading(pool, b_ax, s_ax)
    tok = _take_token(new_leaf, pos, b_ax, s_ax)       # (B, *rest)
    page, off = page_offsets(table, pos, write, p.shape[1])
    p = p.at[page, off].set(tok.astype(pool.dtype))
    return _pages_restore(p, b_ax, s_ax)


def scatter_token_tree(pcache: Any, new_view: Any, table: jnp.ndarray,
                       pos: jnp.ndarray, write: jnp.ndarray, ba: Any,
                       sa: Any) -> Any:
    """Per-leaf post-step writeback: paged leaves get the one new token at
    ``pos`` scattered into their page, dense leaves take the (already
    slot-masked) updated view wholesale."""
    return jax.tree.map(
        lambda b_ax, s_ax, n, p: n if s_ax < 0
        else scatter_token(p, table, n, pos, write, b_ax, s_ax),
        ba, sa, new_view, pcache)


def _dense_to_pages(leaf: jnp.ndarray, b_ax: int, s_ax: int,
                    ps: int) -> jnp.ndarray:
    """B=1 dense leaf -> (P, ps, *rest) page blocks."""
    x = jnp.moveaxis(leaf, (b_ax, s_ax), (0, 1))       # (1, S, *rest)
    S = x.shape[1]
    return x[0].reshape((S // ps, ps) + x.shape[2:])


def insert_tree(pcache: Any, single: Any, table_row: jnp.ndarray,
                slot: jnp.ndarray, ba: Any, sa: Any,
                n_tokens: Optional[jnp.ndarray] = None) -> Any:
    """Admit one prefilled B=1 dense cache: paged leaves scatter their page
    blocks to the slot's physical pages (excess logical pages hit scratch),
    dense leaves do the ordinary slot insert.  ``table_row``/``slot`` are
    traced — ONE compiled program covers every slot and page assignment.

    ``n_tokens`` (traced; required for quantized pools) is the prefilled
    length: positions at or past it are GARBAGE the prefill bucketing wrote
    past the prompt, and the quantizer zeroes them before computing the
    per-page scale — otherwise a junk amax in the tail page would coarsen
    the scale of real content (and break the scale agreement with
    ``layers.fake_quant_pages``, which sees only valid positions)."""
    def leaf(b_ax, s_ax, s, p):
        if s_ax < 0:
            return jax.lax.dynamic_update_slice_in_dim(
                p, s.astype(p.dtype), slot, axis=b_ax)
        if isinstance(p, QuantizedLeaf):
            cl = _pages_leading(p.codes, b_ax, s_ax)
            sl = _scales_leading(p.scales, b_ax, s_ax)
            ps = cl.shape[1]
            blocks = _dense_to_pages(s, b_ax, s_ax, ps)    # (P, ps, *rest)
            P = blocks.shape[0]
            pos = (jnp.arange(P) * ps)[:, None] + jnp.arange(ps)[None, :]
            valid = pos < jnp.asarray(n_tokens, jnp.int32)
            blocks = jnp.where(
                valid.reshape((P, ps) + (1,) * (blocks.ndim - 2)),
                blocks.astype(jnp.float32), 0.0)
            amax = jnp.max(jnp.abs(blocks), axis=(1, blocks.ndim - 1))
            sc = kv_pow2_scale(amax, p.kv_dtype)           # (P, *rest[:-1])
            q = kv_quantize(
                blocks, jnp.expand_dims(sc, (1, blocks.ndim - 1)),
                p.kv_dtype)
            cl = cl.at[table_row].set(q)
            sl = sl.at[table_row].set(sc)
            return QuantizedLeaf(_pages_restore(cl, b_ax, s_ax),
                                 _scales_restore(sl, b_ax, s_ax),
                                 p.kv_dtype, p.out_dtype)
        pl = _pages_leading(p, b_ax, s_ax)
        blocks = _dense_to_pages(s, b_ax, s_ax, pl.shape[1])
        pl = pl.at[table_row].set(blocks.astype(p.dtype))
        return _pages_restore(pl, b_ax, s_ax)

    return jax.tree.map(leaf, ba, sa, single, pcache)


def fake_quant_tree(cache: Any, n_tokens, sa: Any, page_size: int,
                    kv_dtype: str) -> Any:
    """Round-trip the completed pages of a dense B=1 request cache through
    the page quantizer (``layers.fake_quant_pages`` per paging leaf; dense
    leaves untouched).  Both engines apply this after every prefill /
    prefill chunk when the pool is quantized, so the chunk stream attends
    to exactly the values insertion will store — the prefix on/off token
    identity survives quantization (DESIGN.md §13)."""
    return jax.tree.map(
        lambda s_ax, x: x if s_ax < 0
        else fake_quant_pages(x, s_ax, n_tokens, page_size, kv_dtype),
        sa, cache)
