"""Paged KV-cache plumbing: a shared page pool behind the slot protocol.

The paper's Split-Brain protocol (§IV-B) makes the host CPU the sole owner
of dynamic KV state; this module is the host's memory manager.  Instead of
pinning a full ``(max_slots, ..., max_len, ...)`` cache per slot, every
sequence-growing cache leaf is re-laid-out as a *page pool*

    dense leaf  (..., B, ..., S, ...)          S = max_len
    pool  leaf  (num_pages, page_size, *rest)  rest = shape minus B and S

plus one per-slot *page table* ``(max_slots, max_len // page_size)`` of
physical page ids, owned host-side by :class:`PagePool` (plain numpy — no
device sync on the allocation path).  Pages are allocated on demand as a
sequence grows and returned to the free list when its request finishes, so
resident KV bytes track actual token occupancy.

The pool layout is KERNEL-FRIENDLY: the ``(num_pages, page_size)`` axes sit
exactly where the batch axis sat in the dense leaf (``page_axis``), so
leading non-sequence axes — the stacked layer axis of
``(L, B, Hkv, S, hd)`` caches, the ``(n_groups, gs)`` group axes of the lm
family — stay leading.  A ``lax.scan`` over depth therefore sweeps
per-layer pool slices ``(num_pages, page_size, Hkv, hd)`` directly, which
is the exact operand layout ``kernels/paged_attention.py`` (and its jnp
oracle) consumes: attention walks ``pool[table]`` page-block-wise with no
dense-view transient (DESIGN.md §6).

Physical page 0 is reserved as a *scratch* page: table entries beyond a
slot's allocated pages point at it, so every jitted program can write a
fixed number of pages (traced indices, fixed shapes — zero steady-state
recompiles) and the excess lands in garbage that no gather ever reads
(attention masks positions >= ``len``).

Leaves that do NOT scale with ``max_len`` — rwkv WKV state, hymba SSM
state, sliding-window ring buffers, ``len`` itself — keep their dense
``(max_slots, ...)`` layout and pass through untouched: the recurrent
families effectively run a no-op page table.  Discovery is by shape
diffing (:func:`seq_axes`), the same trick ``serve/slots.py::batch_axes``
uses for the batch dimension.

The traced helpers (:func:`gather_tree` / :func:`scatter_token_tree` /
:func:`insert_tree`) are the paged variant of the dense cache plumbing:
``gather_tree`` reconstructs the exact dense-view pytree the family
``decode_step`` already understands (so paged decode reuses the verified
attention math bit-for-bit), and ``scatter_token_tree`` writes back only
the one new token per active slot — O(B × token bytes) pool traffic per
step.

Scope of the memory claim: paging shrinks the PERSISTENT cache state — the
pool allocation and the peak pages-in-use that admission and the
serve_bench gate reason about.  The default decode discipline
(``paged_attn="inplace"``) additionally computes attention directly
through the page table (``ops.paged_decode_attention``), so the per-step
gathered dense-view TRANSIENT of the fallback/oracle discipline
(``paged_attn="gather"``, which reconstructs the dense view and reuses the
verified family ``decode_step``) is gone too — zero transient bytes, HBM
reads O(live tokens) per slot.  In-flight chunked prefills each hold a
dense B=1 request cache until insertion, bounded by the scheduler's
``max_prefill_jobs`` cap.  DESIGN.md §5–6 spell out all three pieces.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import SCRATCH_PAGE, page_offsets

__all__ = [
    "PagePool",
    "HostPager",
    "PagedEngineMixin",
    "check_chunk_width",
    "round_len",
    "seq_axes",
    "page_axis",
    "make_pool",
    "gather_view",
    "gather_tree",
    "scatter_token_tree",
    "insert_tree",
    "pool_bytes",
    "page_token_bytes",
    "kv_token_bytes",
    "SCRATCH_PAGE",
]


def check_chunk_width(width: int, max_len: int) -> None:
    """Chunk writes must never spill past the cache end: W | max_len plus
    the full-width feeding order (transformer.prefill_chunk precondition)
    guarantee every chunk lands inside the buffer.  Shared by both engines'
    ``prefill_chunk_slot``."""
    if max_len % width != 0:
        raise ValueError(
            f"chunk width {width} must divide max_len ({max_len}) so "
            f"chunk writes never spill past the cache end")


def round_len(n: int, *quanta: Optional[int]) -> int:
    """Round a cache length up so every given quantum (page size, prefill
    chunk width) tiles it exactly — a COMMON multiple, not each quantum in
    turn (sequential rounding can un-align the earlier one)."""
    q = math.lcm(*(int(x) for x in quanta if x))
    return -(-int(n) // q) * q


# ----------------------------------------------------------------------------
# Host-side allocator (numpy only — the host owns the dynamic state)
# ----------------------------------------------------------------------------
class PagePool:
    """Free-list page allocator with worst-case admission reservations.

    ``try_reserve(slot, n_tokens)`` claims the worst-case page count for a
    request at admission time; ``ensure(slot, n_tokens)`` then draws pages
    lazily as the sequence actually grows, which therefore never fails.
    ``free_slot`` returns both the pages and the reservation.  Reservation
    admission is deliberately conservative (no mid-decode preemption needed);
    ``peak_pages_in_use`` records what was ever resident simultaneously.
    """

    def __init__(self, num_pages: int, page_size: int, n_slots: int,
                 slot_pages: int):
        if num_pages < 2:
            raise ValueError(f"num_pages must be >= 2 (page {SCRATCH_PAGE} "
                             f"is the reserved scratch page), got {num_pages}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.slot_pages = int(slot_pages)
        # logical->physical map; unallocated entries hit the scratch page
        self.table = np.full((n_slots, slot_pages), SCRATCH_PAGE, np.int32)
        self._free = list(range(num_pages - 1, SCRATCH_PAGE, -1))
        self._n_alloc = np.zeros(n_slots, np.int64)
        self._reserved = np.zeros(n_slots, np.int64)
        self.total_reserved = 0
        self.pages_in_use = 0
        self.peak_pages_in_use = 0

    @property
    def capacity(self) -> int:
        """Allocatable pages (scratch excluded)."""
        return self.num_pages - 1

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 0) // self.page_size)

    def try_reserve(self, slot: int, n_tokens: int) -> bool:
        """Claim worst-case pages for a request; False if the pool is full."""
        assert self._reserved[slot] == 0, f"slot {slot} already reserved"
        need = self.pages_for(n_tokens)
        if need > self.slot_pages:
            return False              # longer than one slot's page table
        if self.total_reserved + need > self.capacity:
            return False
        self._reserved[slot] = need
        self.total_reserved += need
        return True

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Allocate pages so the slot can hold ``n_tokens`` positions."""
        need = self.pages_for(n_tokens)
        assert need <= self._reserved[slot], \
            (f"slot {slot} needs {need} pages but reserved only "
             f"{self._reserved[slot]} — reservation bug")
        while self._n_alloc[slot] < need:
            page = self._free.pop()   # cannot fail: alloc <= reservation
            self.table[slot, self._n_alloc[slot]] = page
            self._n_alloc[slot] += 1
            self.pages_in_use += 1
        self.peak_pages_in_use = max(self.peak_pages_in_use,
                                     self.pages_in_use)

    def free_slot(self, slot: int) -> None:
        """Return the slot's pages and reservation to the pool."""
        n = int(self._n_alloc[slot])
        for i in range(n):
            self._free.append(int(self.table[slot, i]))
        self.table[slot, :] = SCRATCH_PAGE
        self.pages_in_use -= n
        self._n_alloc[slot] = 0
        self.total_reserved -= int(self._reserved[slot])
        self._reserved[slot] = 0


class HostPager:
    """The host-side paging companion both engines own when ``page_size``
    is set: PagePool lifecycle, the per-slot length mirror (so the decode
    loop never syncs ``len`` off the device), admission queries, and byte
    accounting.  The jitted gather/scatter programs stay with each engine
    (they bind its own decode step); every host-side decision lives here
    exactly once.
    """

    def __init__(self, page_size: int, num_pages: Optional[int],
                 max_len: int):
        if max_len % page_size != 0:
            raise ValueError(
                f"max_len ({max_len}) must be a multiple of page_size "
                f"({page_size}) so the page table tiles the cache exactly")
        self.page_size = int(page_size)
        self.max_len = int(max_len)
        self.slot_pages = max_len // page_size
        self._num_pages_opt = num_pages
        self.pool: Optional[PagePool] = None
        self.host_len = None
        self._table_dev = None     # device copy, invalidated on table writes

    def reset(self, n_slots: int) -> PagePool:
        """Fresh pool + length mirror for a new slot cache."""
        num_pages = (self._num_pages_opt if self._num_pages_opt is not None
                     else n_slots * self.slot_pages + 1)   # +1: scratch
        self.pool = PagePool(num_pages, self.page_size, n_slots,
                             self.slot_pages)
        self.host_len = np.zeros((n_slots,), np.int64)
        self._table_dev = None
        return self.pool

    def _tokens_for(self, prompt_len: int, max_new: int) -> int:
        return prompt_len - 1 + max_new

    def try_reserve(self, slot: int, prompt_len: int, max_new: int) -> bool:
        return self.pool.try_reserve(slot,
                                     self._tokens_for(prompt_len, max_new))

    def can_ever_admit(self, prompt_len: int, max_new: int) -> bool:
        """Static capacity check: could this request be admitted into an
        IDLE pool?  False means waiting for frees can never help — the
        scheduler rejects immediately instead of head-of-line blocking."""
        need = self.pool.pages_for(self._tokens_for(prompt_len, max_new))
        return need <= min(self.pool.slot_pages, self.pool.capacity)

    def free(self, slot: int) -> None:
        self.pool.free_slot(slot)
        self.host_len[slot] = 0
        self._table_dev = None

    def _ensure(self, slot: int, n_tokens: int) -> None:
        before = self.pool.pages_in_use
        self.pool.ensure(slot, n_tokens)
        if self.pool.pages_in_use != before:
            self._table_dev = None

    def note_insert(self, slot: int, n_tokens: int) -> None:
        """Allocate the admitted prompt's pages, mirror its length."""
        self._ensure(slot, n_tokens)
        self.host_len[slot] = n_tokens

    def pre_decode(self, active: np.ndarray) -> None:
        """Allocate any page the coming decode step writes into (each
        active slot writes at position ``len``)."""
        for s in np.flatnonzero(active):
            self._ensure(s, int(self.host_len[s]) + 1)

    def post_decode(self, active: np.ndarray) -> None:
        self.host_len[active] += 1

    def table(self) -> jnp.ndarray:
        """Device copy of the page table, re-uploaded only when a table
        entry actually changed (steady-state decode reuses it)."""
        if self._table_dev is None:
            self._table_dev = jnp.asarray(self.pool.table)
        return self._table_dev

    def row(self, slot: int) -> jnp.ndarray:
        return jnp.asarray(self.pool.table[slot])

    def stats(self, cache: Any, sa: Any) -> Dict[str, int]:
        """Resident-cache accounting for the paged-vs-dense benchmark."""
        total = sum(int(a.nbytes) for a in jax.tree.leaves(cache))
        page_bytes = page_token_bytes(cache, sa, self.pool.num_pages,
                                      self.page_size) * self.page_size
        dense_leaves = total - pool_bytes(cache, sa)
        return {
            "cache_bytes": total,
            "page_size": self.page_size,
            "num_pages": self.pool.num_pages,
            "page_bytes": page_bytes,
            "pages_in_use": self.pool.pages_in_use,
            "peak_pages_in_use": self.pool.peak_pages_in_use,
            "peak_kv_bytes_in_use":
                dense_leaves + self.pool.peak_pages_in_use * page_bytes,
        }


class PagedEngineMixin:
    """The slot-protocol paging hooks both serving engines share verbatim.

    An engine mixes this in and maintains two attributes: ``_pager`` (a
    :class:`HostPager`, or None when constructed dense) and
    ``_paging_active`` (set by its ``init_slot_cache`` — False when the
    family has no paging leaves and fell back to the dense layout), plus a
    ``_stats_seq_axes()`` hook returning its per-leaf sequence-axis tree.

    ``paged_attn`` selects the paged decode discipline: ``"inplace"`` (the
    default) computes attention directly through the page table
    (``ops.paged_decode_attention`` — no dense-view transient, O(live
    tokens) KV reads per slot); ``"gather"`` keeps the PR-3 reference path
    (gather dense view -> family ``decode_step`` -> scatter one token) as
    the fallback/oracle the parity suite checks the kernel against.
    """

    _pager: Optional[HostPager] = None
    _paging_active: bool = False
    _paged_insert_jit = None
    _paged_attn: str = "inplace"
    _kv_tok_bytes: int = 0       # per-token-per-slot seq-scaling cache bytes
    _slot_count: int = 0

    def _stats_seq_axes(self):
        raise NotImplementedError

    def will_page(self) -> bool:
        """Whether ``init_slot_cache`` will engage the page pool — THE
        paging-leaf discovery rule (a ``page_size`` plus at least one
        sequence-scaling leaf), shared by the engines' fallback decision,
        the in-place/shard_map refusal, and serve_bench's discipline
        selection."""
        if getattr(self, "page_size", None) is None:
            return False
        return any(ax >= 0 for ax in jax.tree.leaves(self._stats_seq_axes()))

    @staticmethod
    def check_paged_attn(paged_attn: str) -> str:
        if paged_attn not in ("inplace", "gather"):
            raise ValueError(
                f"paged_attn must be 'inplace' or 'gather', got {paged_attn!r}")
        return paged_attn

    def _note_slot_cache(self, n_slots: int, cache_shape: Any, ba: Any,
                         sa: Any) -> None:
        """Record the slot-cache geometry the KV-read accounting needs
        (called by both engines' ``init_slot_cache``, every layout)."""
        self._slot_count = int(n_slots)
        self._kv_tok_bytes = kv_token_bytes(cache_shape, ba, sa)

    # ------------------------------------------------ host KV-read accounting
    def _dense_view_read_bytes(self) -> int:
        """Bytes one masked decode step reads through a dense (or gathered)
        ``(max_slots, ..., max_len, ...)`` KV view: every slot's full
        allocation, live or not."""
        return self._slot_count * self.max_len * self._kv_tok_bytes

    def kv_read_bytes_step(self, active: np.ndarray) -> int:
        """KV-cache bytes ONE decode step reads under the engine's current
        discipline's read MODEL (replayed host-side like every meter entry,
        not a hardware counter).  The in-place paged discipline touches only
        the LIVE pages — ``ceil((len + is_active)/page_size)`` per occupied
        slot, since the kernel's grid walks EVERY slot's table but fetches
        real pages only up to its length (free slots hold length 0 and
        all-scratch tables; the dead tail lands on the one hot scratch
        page).  Eq. 7-10's intent: traffic proportional to live tokens.
        The gather and dense disciplines materialize/read the full
        ``max_slots x max_len`` view regardless of occupancy."""
        if self._paging_active and self._paged_attn == "inplace":
            ps = self._pager.page_size
            lens = self._pager.host_len + np.asarray(active, bool)
            pages_touched = int(-((lens[lens > 0]) // -ps).sum())
            return pages_touched * ps * self._kv_tok_bytes
        return self._dense_view_read_bytes()

    def _meter_kv_read(self, active: np.ndarray) -> None:
        n = self.kv_read_bytes_step(active)
        if n:
            self.meter.host_read("kv_cache_read", n)

    def gather_transient_bytes_per_step(self) -> int:
        """Dense-view TRANSIENT bytes one paged decode step materializes:
        the gather discipline copies every live slot's full dense view per
        dispatch; the in-place discipline (and the dense layout, whose
        cache IS the view) materializes none.  The serve_bench regression
        gate for the eliminated copy."""
        if self._paging_active and self._paged_attn == "gather":
            return self._dense_view_read_bytes()
        return 0

    def paged_insert(self, batched_cache, single_cache, slot: int,
                     ba: Any, sa: Any, n_tokens: int):
        """Admit one prefilled B=1 dense cache into the pool: allocate the
        slot's pages, then scatter its page blocks through the (traced)
        table row — one compiled program for every slot and assignment.
        Callers wrap this in their mesh context where needed."""
        self._pager.note_insert(slot, n_tokens)
        if self._paged_insert_jit is None:
            def insert(pcache, single, row, s):
                return insert_tree(pcache, single, row, s, ba, sa)

            self._paged_insert_jit = jax.jit(insert, donate_argnums=(0,))
        return self._paged_insert_jit(batched_cache, single_cache,
                                      self._pager.row(slot),
                                      jnp.int32(slot))

    def reserve_slot(self, slot: int, prompt_len: int, max_new: int) -> bool:
        """Admission control: claim worst-case pages for a request.  Dense
        slot caches always admit; a paged pool may ask the scheduler to
        wait until running requests free pages."""
        if not self._paging_active:
            return True
        return self._pager.try_reserve(slot, prompt_len, max_new)

    def can_ever_admit(self, prompt_len: int, max_new: int) -> bool:
        """False when the request exceeds the pool's STATIC capacity: no
        amount of waiting for frees can help, so the scheduler rejects it
        immediately instead of head-of-line blocking the queue."""
        if not self._paging_active:
            return True
        return self._pager.can_ever_admit(prompt_len, max_new)

    def free_slot(self, slot: int) -> None:
        """Release a finished request's pages (no-op for the dense layout)."""
        if self._paging_active:
            self._pager.free(slot)

    def cache_stats(self, cache: Any) -> Dict[str, int]:
        """Resident-cache accounting for the paged-vs-dense benchmark.

        ``cache_bytes`` is the allocation backing the slot cache;
        ``peak_kv_bytes_in_use`` is what the pages actually held at peak
        (== cache_bytes for the dense layout, where every slot pins
        ``max_len`` positions whether it uses them or not).  NOTE these
        measure the PERSISTENT cache state; the per-dispatch dense-view
        transient on top of it is ``gather_transient_bytes_per_step()`` —
        nonzero only under the ``paged_attn="gather"`` fallback, zero for
        the default in-place discipline (module docstring, DESIGN.md §6).
        """
        if not self._paging_active:
            total = sum(int(a.nbytes) for a in jax.tree.leaves(cache))
            return {"cache_bytes": total, "peak_kv_bytes_in_use": total}
        return self._pager.stats(cache, self._stats_seq_axes())


# ----------------------------------------------------------------------------
# Layout discovery (shape diffing, like slots.batch_axes)
# ----------------------------------------------------------------------------
def seq_axes(cache_a: Any, cache_b: Any, delta: int) -> Any:
    """Per-leaf sequence-axis pytree; -1 where the leaf does not page.

    ``cache_a``/``cache_b`` are the same family cache built with two
    ``max_len`` values ``delta`` apart (ShapeDtypeStructs are fine).  A leaf
    pages only if exactly one axis grew by exactly ``delta`` — ring buffers
    capped at a window, recurrent state and ``len`` all stay dense (-1),
    which is the recurrent families' no-op page table.
    """
    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        if len(diffs) == 1 and b.shape[diffs[0]] - a.shape[diffs[0]] == delta:
            return diffs[0]
        return -1

    return jax.tree.map(axis, cache_a, cache_b)


def page_axis(b_ax: int, s_ax: int) -> int:
    """Leading axis of the ``(num_pages, page_size)`` pair in a pool leaf.

    The kernel-friendly layout keeps every non-(B, S) axis in its dense
    order and drops the page axes exactly where the batch axis sat, so
    layer-leading caches stay ``lax.scan``-sweepable and per-layer pool
    slices land in the ``(num_pages, page_size, *tail)`` operand layout
    ``kernels/paged_attention.py`` expects.
    """
    return b_ax - (1 if 0 <= s_ax < b_ax else 0)


def _pages_leading(pool: jnp.ndarray, b_ax: int, s_ax: int) -> jnp.ndarray:
    """View a pool leaf with the (num_pages, page_size) axes leading."""
    pax = page_axis(b_ax, s_ax)
    return jnp.moveaxis(pool, (pax, pax + 1), (0, 1))


def _pages_restore(pool: jnp.ndarray, b_ax: int, s_ax: int) -> jnp.ndarray:
    pax = page_axis(b_ax, s_ax)
    return jnp.moveaxis(pool, (0, 1), (pax, pax + 1))


def make_pool(cache_shape: Any, ba: Any, sa: Any, num_pages: int,
              page_size: int) -> Any:
    """Allocate the paged slot cache: pool layout for paging leaves, dense
    ``(max_slots, ...)`` zeros for everything else.  Same pytree structure
    as the dense cache, so engines keep one cache object either way."""
    def leaf(a, b_ax, s_ax):
        if s_ax < 0:
            return jnp.zeros(a.shape, a.dtype)
        rest = tuple(d for i, d in enumerate(a.shape) if i not in (b_ax, s_ax))
        pax = page_axis(b_ax, s_ax)
        return jnp.zeros(rest[:pax] + (num_pages, page_size) + rest[pax:],
                         a.dtype)

    return jax.tree.map(leaf, cache_shape, ba, sa)


def pool_bytes(pcache: Any, sa: Any) -> int:
    """Resident bytes of the pool leaves (the paged share of the cache)."""
    sizes = jax.tree.map(lambda a, s_ax: int(a.nbytes) if s_ax >= 0 else 0,
                         pcache, sa)
    return sum(jax.tree.leaves(sizes))


def page_token_bytes(pcache: Any, sa: Any, num_pages: int,
                     page_size: int) -> int:
    """KV bytes per token summed over the paged leaves (pool bytes spread
    over the pool's ``num_pages * page_size`` token positions)."""
    return pool_bytes(pcache, sa) // (int(num_pages) * int(page_size))


def kv_token_bytes(cache_shape: Any, ba: Any, sa: Any) -> int:
    """Per-token-per-slot bytes of the sequence-scaling cache leaves, from
    the DENSE cache shapes (paged or not: the same KV bytes per token).
    The denominator of the live-page read accounting (TrafficMeter
    ``host_read``) and of the gather-transient metric in serve_bench."""
    def per_tok(a, b_ax, s_ax):
        if s_ax < 0:
            return 0
        n = int(math.prod(a.shape)) // (a.shape[b_ax] * a.shape[s_ax])
        return n * jnp.dtype(a.dtype).itemsize

    sizes = jax.tree.map(per_tok, cache_shape, ba, sa)
    return sum(jax.tree.leaves(sizes))


# ----------------------------------------------------------------------------
# Traced page-table ops (fixed shapes, traced indices — compile once)
# ----------------------------------------------------------------------------
def gather_view(pool: jnp.ndarray, table: jnp.ndarray, b_ax: int,
                s_ax: int) -> jnp.ndarray:
    """Reassemble one paged leaf into its dense ``(..., B, ..., S, ...)``
    view through the page table ``(B, P)``.  This materializes the
    O(B x max_len) transient the in-place paged attention path exists to
    avoid — fallback/oracle only (DESIGN.md §6)."""
    B, P = table.shape
    p = _pages_leading(pool, b_ax, s_ax)               # (N, ps, *rest)
    ps = p.shape[1]
    g = p[table]                                       # (B, P, ps, *rest)
    g = g.reshape((B, P * ps) + p.shape[2:])           # (B, S, *rest)
    return jnp.moveaxis(g, (0, 1), (b_ax, s_ax))


def gather_tree(pcache: Any, table: jnp.ndarray, ba: Any, sa: Any) -> Any:
    """Dense-view pytree: paged leaves gathered, dense leaves passed through.
    The result is exactly the cache pytree the family decode_step expects."""
    return jax.tree.map(
        lambda p, b_ax, s_ax: p if s_ax < 0
        else gather_view(p, table, b_ax, s_ax),
        pcache, ba, sa)


def _take_token(leaf: jnp.ndarray, pos: jnp.ndarray, b_ax: int,
                s_ax: int) -> jnp.ndarray:
    """Slice per-slot position ``pos[b]`` along the seq axis -> (B, *rest)."""
    B = pos.shape[0]
    idx_shape = [1] * leaf.ndim
    idx_shape[b_ax] = B
    idx = pos.reshape(idx_shape).astype(jnp.int32)
    tok = jnp.take_along_axis(leaf, idx, axis=s_ax)
    tok = jnp.squeeze(tok, axis=s_ax)
    return jnp.moveaxis(tok, b_ax - (1 if s_ax < b_ax else 0), 0)


def scatter_token(pool: jnp.ndarray, table: jnp.ndarray,
                  new_leaf: jnp.ndarray, pos: jnp.ndarray,
                  write: jnp.ndarray, b_ax: int, s_ax: int) -> jnp.ndarray:
    """Write each active slot's token at ``pos[b]`` from the updated dense
    view back into its page; inactive slots land on the scratch page."""
    p = _pages_leading(pool, b_ax, s_ax)
    tok = _take_token(new_leaf, pos, b_ax, s_ax)       # (B, *rest)
    page, off = page_offsets(table, pos, write, p.shape[1])
    p = p.at[page, off].set(tok.astype(pool.dtype))
    return _pages_restore(p, b_ax, s_ax)


def scatter_token_tree(pcache: Any, new_view: Any, table: jnp.ndarray,
                       pos: jnp.ndarray, write: jnp.ndarray, ba: Any,
                       sa: Any) -> Any:
    """Per-leaf post-step writeback: paged leaves get the one new token at
    ``pos`` scattered into their page, dense leaves take the (already
    slot-masked) updated view wholesale."""
    return jax.tree.map(
        lambda p, n, b_ax, s_ax: n if s_ax < 0
        else scatter_token(p, table, n, pos, write, b_ax, s_ax),
        pcache, new_view, ba, sa)


def _dense_to_pages(leaf: jnp.ndarray, b_ax: int, s_ax: int,
                    ps: int) -> jnp.ndarray:
    """B=1 dense leaf -> (P, ps, *rest) page blocks."""
    x = jnp.moveaxis(leaf, (b_ax, s_ax), (0, 1))       # (1, S, *rest)
    S = x.shape[1]
    return x[0].reshape((S // ps, ps) + x.shape[2:])


def insert_tree(pcache: Any, single: Any, table_row: jnp.ndarray,
                slot: jnp.ndarray, ba: Any, sa: Any) -> Any:
    """Admit one prefilled B=1 dense cache: paged leaves scatter their page
    blocks to the slot's physical pages (excess logical pages hit scratch),
    dense leaves do the ordinary slot insert.  ``table_row``/``slot`` are
    traced — ONE compiled program covers every slot and page assignment."""
    def leaf(p, s, b_ax, s_ax):
        if s_ax < 0:
            return jax.lax.dynamic_update_slice_in_dim(
                p, s.astype(p.dtype), slot, axis=b_ax)
        pl = _pages_leading(p, b_ax, s_ax)
        blocks = _dense_to_pages(s, b_ax, s_ax, pl.shape[1])
        pl = pl.at[table_row].set(blocks.astype(p.dtype))
        return _pages_restore(pl, b_ax, s_ax)

    return jax.tree.map(leaf, pcache, single, ba, sa)
