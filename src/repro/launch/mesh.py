"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (smoke tests must see 1 device; only dryrun.py sets
the 512-placeholder-device XLA flag).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds the cross-pod 'pod' axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices=None):
    """Tiny mesh over whatever devices exist (CPU tests)."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    return jax.make_mesh((1, n), ("data", "model"), devices=devices)
