"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (smoke tests must see 1 device; only dryrun.py sets
the 512-placeholder-device XLA flag).
"""
from __future__ import annotations

import math

import jax


def _validate_shape(shape, devices, *, what):
    """Raise a readable error before jax.make_mesh fails opaquely."""
    n = len(devices)
    want = math.prod(shape)
    if any(s <= 0 for s in shape):
        raise ValueError(f"{what}: mesh shape {shape} has a non-positive axis")
    if want != n:
        raise ValueError(
            f"{what}: mesh shape {shape} needs {want} devices but "
            f"{n} are available; pick (dp, tp) with dp*tp == {n}"
        )


def make_production_mesh(shape=(16, 16), *, multi_pod: bool = False):
    """Data x model mesh; default 16x16 = 256 chips/pod.

    ``shape`` is the explicit ``(dp, tp)`` pair (or ``(pods, dp, tp)`` when
    ``multi_pod``); it is validated against the visible device count so a
    mismatch raises a clear error instead of an opaque jax.make_mesh failure.
    """
    if multi_pod:
        shape = (2, *shape) if len(shape) == 2 else tuple(shape)
        axes = ("pod", "data", "model")
    else:
        shape = tuple(shape)
        axes = ("data", "model")
    if len(shape) != len(axes):
        raise ValueError(
            f"make_production_mesh: shape {shape} must have {len(axes)} axes {axes}"
        )
    _validate_shape(shape, jax.devices(), what="make_production_mesh")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices=None, shape=None):
    """Small ("data", "model") mesh over ``devices`` (CPU tests).

    Default shape is ``(1, n)`` — all devices on the model (tensor-parallel)
    axis. Pass an explicit ``(dp, tp)`` to split them; the product must match
    the device count.
    """
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    if shape is None:
        shape = (1, n)
    shape = tuple(shape)
    if len(shape) != 2:
        raise ValueError(f"make_test_mesh: shape {shape} must be (dp, tp)")
    _validate_shape(shape, devices, what="make_test_mesh")
    return jax.make_mesh(shape, ("data", "model"), devices=devices)
