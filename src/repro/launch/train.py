"""Training driver: end-to-end loop with checkpointing, restart, preemption
handling, and deterministic data.

At production scale this is launched once per host with the same arguments
(jax.distributed initializes from the TPU env); on CPU it runs reduced
configs for the e2e examples and integration tests:

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
      --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, DataLoader
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.train import optimizer as opt_mod
from repro.train import step as step_mod


def build(cfg, optcfg, mesh, key):
    with mesh:
        params = api.init_params(cfg, key)
        opt_state = opt_mod.init_state(params, optcfg)
    step = step_mod.make_train_step(cfg, optcfg, mesh, params, opt_state)
    return params, opt_state, step


def main(argv=None) -> Dict[str, Any]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    optcfg = opt_mod.AdamWConfig(lr=args.lr, warmup_steps=10,
                                 total_steps=args.steps)
    mesh = make_test_mesh()
    key = jax.random.PRNGKey(args.seed)
    params, opt_state, train_step = build(cfg, optcfg, mesh, key)

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=args.seed,
                      frontend_tokens=cfg.frontend_tokens, d_model=cfg.d_model)
    loader = DataLoader(dcfg)

    mgr: Optional[CheckpointManager] = None
    start_step = 0
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=2, async_save=True)
        if args.resume and mgr.latest_step() is not None:
            state_like = {"params": params, "opt": opt_state}
            restored, meta = mgr.restore(state_like)
            params, opt_state = restored["params"], restored["opt"]
            start_step = int(meta["step"]) + 1
            loader.load_state_dict({"step": start_step})
            print(f"resumed from step {meta['step']}")
        mgr.save_on_signal(lambda: (int(loader.step),
                                    {"params": params, "opt": opt_state}))

    losses = []
    step_times = []
    with mesh:
        for i in range(start_step, args.steps):
            batch_np = next(loader)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            batch["mask"] = jnp.ones_like(batch["labels"], jnp.float32)
            t0 = time.time()
            params, opt_state, metrics = train_step(params, opt_state, batch)
            loss = float(metrics["loss"])
            step_times.append(time.time() - t0)  # straggler watch (see below)
            losses.append(loss)
            if i % args.log_every == 0 or i == args.steps - 1:
                # straggler mitigation signal: flag steps >2x the median
                med = float(np.median(step_times)) if step_times else 0.0
                slow = sum(1 for t in step_times if t > 2 * med)
                print(f"step {i:5d} loss {loss:.4f} lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"med_step {med*1e3:.0f}ms stragglers {slow}")
            if mgr and (i + 1) % args.ckpt_every == 0:
                mgr.save(i, {"params": params, "opt": opt_state},
                         metadata={"step": i, "loss": loss,
                                   "mesh": list(mesh.devices.shape)})
    if mgr:
        mgr.wait()
    result = {"first_loss": losses[0] if losses else None,
              "last_loss": losses[-1] if losses else None,
              "steps": len(losses)}
    print(json.dumps(result))
    return result


if __name__ == "__main__":
    main()
