"""Roofline-term extraction from the compiled, SPMD-partitioned HLO.

``compiled.cost_analysis()`` counts every while-loop (scan) body ONCE, which
undercounts a scanned-layers transformer by ~num_layers x.  This module does
its own static analysis of ``compiled.as_text()`` instead:

  * the HLO is split into computations; a call graph is built from
    ``calls=`` (fusions), ``body=``/``condition=`` (while; weighted by the
    ``known_trip_count`` XLA records in backend_config), and
    ``branch_computations=`` (conditionals; weighted 1/num_branches —
    expected-value accounting for the causal block-skip ``lax.cond``),
  * FLOPs: every ``dot`` = 2 x output elems x contracted dims (operand
    shapes resolved through the computation's symbol table),
  * HBM traffic follows XLA's fusion-aware convention:
      - dot: operands + result,
      - data movers (convert/copy/slice/transpose/concat/pad): 2 x result,
      - dynamic-slice/gather: 2 x result (NOT the full operand — a scan
        slicing per-layer weights from the stacked array reads one layer),
      - dynamic-update-slice: 2 x update (in-place aliasing),
      - reduce/reduce-window: operands + result,
      - broadcast/iota: free (always fused into consumers on TPU),
      - fusion ops: result + operand bytes, where an operand consumed inside
        the fused computation solely through dynamic-slice counts as the
        slice size, and a fused root dynamic-update-slice counts as the
        update size (this is the scan-body weight-slice / carry-write
        pattern; counting full buffers would overcount by num_layers x),
  * collectives: per-chip wire bytes with ring factors (all-reduce 2x,
    all-gather 1x result, reduce-scatter ~operand, all-to-all /
    collective-permute 1x), group size from replica_groups.

Shapes in the partitioned module are per-device: memory/collective sums are
per-chip; FLOPs are multiplied by ``chips`` by the caller for the global
compute term (every chip executes the same SPMD program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")

_MOVER_OPS = {"convert", "copy", "slice", "transpose", "concatenate", "pad",
              "reverse", "sort"}
_COLL_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute"}


def _shape_elems_bytes(type_str: str) -> Tuple[float, float]:
    elems = 0.0
    nbytes = 0.0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return elems, nbytes


def _operand_names(line: str, op: str) -> List[str]:
    tail = line.split(op + "(", 1)
    if len(tail) != 2:
        return []
    buf = ""
    depth = 1
    for ch in tail[1]:
        if ch == "(":
            depth += 1
        if ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf += ch
    return _OPERANDS_RE.findall(buf)


@dataclass
class CompInfo:
    flops: float = 0.0
    mem_bytes: float = 0.0
    mem_by_kind: Dict[str, float] = field(default_factory=dict)
    coll: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)
    edges: List[Tuple[str, float]] = field(default_factory=list)
    # for fused computations: per-parameter effective read bytes
    # (None = full operand), and effective output bytes (None = full result)
    param_read_bytes: Dict[int, float] = field(default_factory=dict)
    out_write_bytes: Optional[float] = None
    fusion_ops: List[Tuple[str, str, List[str]]] = field(default_factory=list)
    symbols: Dict[str, str] = field(default_factory=dict)
    ops_seen: List[str] = field(default_factory=list)

    @property
    def is_pure_convert(self) -> bool:
        """A fused computation containing only parameter/convert/bitcast/copy
        ops — XLA:CPU inserts these to legalize bf16 (no native bf16 ALUs).
        They do not exist in the TPU lowering, so their boundary traffic is
        accounted separately (``fp_convert_bytes``), not in the memory term.
        """
        body = [o for o in self.ops_seen if o not in ("parameter", "constant")]
        return (len(body) > 0 and
                all(o in ("convert", "bitcast", "copy", "reshape", "tuple",
                          "get-tuple-element") for o in body))


def _split_computations(text: str) -> Dict[str, Tuple[List[str], bool]]:
    comps: Dict[str, Tuple[List[str], bool]] = {}
    cur: Optional[str] = None
    lines: List[str] = []
    entry = False
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = m.group(2)
                entry = bool(m.group(1))
                lines = []
        else:
            if line.startswith("}"):
                comps[cur] = (lines, entry)
                cur = None
            else:
                lines.append(line)
    return comps


def _analyze_computation(lines: List[str]) -> CompInfo:
    ci = CompInfo(coll={k: 0.0 for k in _COLL_OPS},
                  coll_counts={k: 0.0 for k in _COLL_OPS})
    symbols = ci.symbols
    params: Dict[str, int] = {}        # %name -> parameter index
    consumers: Dict[str, List[Tuple[str, str]]] = {}  # name -> [(op, defline)]
    root_line: Optional[Tuple[str, str, str]] = None

    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        symbols[name] = type_str
        ci.ops_seen.append(op)
        if "ROOT" in line.split("=")[0]:
            root_line = (name, op, line)
        pm = _PARAM_IDX_RE.search(line) if op == "parameter" else None
        if pm:
            params[name] = int(pm.group(1))
        for a in _operand_names(line, op):
            consumers.setdefault(a, []).append((op, line))

        if op == "dot":
            out_elems, out_bytes = _shape_elems_bytes(type_str)
            args = _operand_names(line, op)
            lhs_shape = symbols.get(args[0], "") if args else ""
            mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            contract = 1
            if lhs_shape and mc:
                dims_m = _SHAPE_RE.search(lhs_shape)
                if dims_m:
                    dims = [int(d) for d in dims_m.group(2).split(",") if d]
                    for cidx in mc.group(1).split(","):
                        if cidx and int(cidx) < len(dims):
                            contract *= dims[int(cidx)]
            ci.flops += 2.0 * out_elems * contract
            b = out_bytes + sum(
                _shape_elems_bytes(symbols.get(a, ""))[1] for a in args)
            ci.mem_bytes += b
            ci.mem_by_kind["dot"] = ci.mem_by_kind.get("dot", 0.0) + b
        elif op in _COLL_OPS:
            _, out_bytes = _shape_elems_bytes(type_str)
            g = 2
            gm = _GROUPS_RE.search(line)
            if gm:
                g = max(2, int(gm.group(2)))
            factor = {"all-reduce": 2.0, "all-gather": 1.0,
                      "reduce-scatter": float(g - 1), "all-to-all": 1.0,
                      "collective-permute": 1.0}[op]
            ci.coll[op] += out_bytes * factor
            ci.coll_counts[op] += 1
        elif op in _MOVER_OPS:
            _, out_bytes = _shape_elems_bytes(type_str)
            if op == "convert":
                args = _operand_names(line, op)
                src = ci.symbols.get(args[0], "") if args else ""
                fp = {"f32", "bf16", "f16"}
                sm = _SHAPE_RE.search(src)
                rm = _SHAPE_RE.search(type_str)
                if (sm and rm and sm.group(1) in fp and rm.group(1) in fp
                        and sm.group(2) == rm.group(2)):
                    # bf16<->f32 legalization copy (absent on TPU)
                    ci.mem_by_kind["fp_convert(cpu-legalization)"] =                         ci.mem_by_kind.get("fp_convert(cpu-legalization)", 0.0)                         + 2.0 * out_bytes
                    continue
            ci.mem_bytes += 2.0 * out_bytes
            ci.mem_by_kind[op] = ci.mem_by_kind.get(op, 0.0) + 2.0 * out_bytes
        elif op in ("dynamic-slice", "gather"):
            _, out_bytes = _shape_elems_bytes(type_str)
            ci.mem_bytes += 2.0 * out_bytes
            ci.mem_by_kind[op] = ci.mem_by_kind.get(op, 0.0) + 2.0 * out_bytes
        elif op == "dynamic-update-slice":
            args = _operand_names(line, op)
            upd = symbols.get(args[1], "") if len(args) > 1 else type_str
            _, upd_bytes = _shape_elems_bytes(upd)
            ci.mem_bytes += 2.0 * upd_bytes
            ci.mem_by_kind[op] = ci.mem_by_kind.get(op, 0.0) + 2.0 * upd_bytes
        elif op == "scatter":
            args = _operand_names(line, op)
            upd = symbols.get(args[-1], "") if args else type_str
            _, upd_bytes = _shape_elems_bytes(upd)
            ci.mem_bytes += 2.0 * upd_bytes
            ci.mem_by_kind[op] = ci.mem_by_kind.get(op, 0.0) + 2.0 * upd_bytes
        elif op in ("reduce", "reduce-window"):
            _, out_bytes = _shape_elems_bytes(type_str)
            b = out_bytes + sum(
                _shape_elems_bytes(symbols.get(a, ""))[1]
                for a in _operand_names(line, op))
            ci.mem_bytes += b
            ci.mem_by_kind[op] = ci.mem_by_kind.get(op, 0.0) + b

        # call-graph edges
        if op in ("fusion", "call", "custom-call"):
            cm = _CALLS_RE.search(line)
            if cm:
                if op == "fusion":
                    ci.fusion_ops.append((cm.group(1), type_str,
                                          _operand_names(line, op)))
                else:
                    ci.edges.append((cm.group(1), 1.0))
        elif op == "while":
            trip = 1.0
            tm = _TRIP_RE.search(line)
            if tm:
                trip = float(tm.group(1))
            bm = _BODY_RE.search(line)
            cm = _COND_RE.search(line)
            if bm:
                ci.edges.append((bm.group(1), trip))
            if cm:
                ci.edges.append((cm.group(1), trip))
        elif op == "conditional":
            brm = _BRANCH_RE.search(line)
            if brm:
                branches = _OPERANDS_RE.findall(brm.group(1))
                for b in branches:
                    ci.edges.append((b, 1.0 / max(1, len(branches))))

    # ---- fused-computation read/write summaries ----
    for pname, pidx in params.items():
        cons = consumers.get(pname, [])
        if cons and all(c[0] in ("dynamic-slice", "gather", "bitcast", "slice")
                        for c in cons):
            total = 0.0
            for cop, cline in cons:
                if cop == "bitcast":
                    continue
                dm = _DEF_RE.match(cline)
                total += _shape_elems_bytes(dm.group(2))[1] if dm else 0.0
            ci.param_read_bytes[pidx] = total
    if root_line and root_line[1] == "dynamic-update-slice":
        args = _operand_names(root_line[2], "dynamic-update-slice")
        if len(args) > 1:
            ci.out_write_bytes = _shape_elems_bytes(symbols.get(args[1], ""))[1]
    return ci


@dataclass
class HloTotals:
    flops_per_chip: float
    mem_bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_by_kind: Dict[str, float]
    coll_counts: Dict[str, float]
    mem_by_kind: Dict[str, float] = field(default_factory=dict)


def analyze(hlo_text: str) -> HloTotals:
    comps = _split_computations(hlo_text)
    infos = {name: _analyze_computation(lines)
             for name, (lines, _) in comps.items()}
    entry = next((n for n, (_, e) in comps.items() if e), None)

    # resolve fusion-op bytes now that every callee is summarized
    for ci in infos.values():
        for callee, out_type, operands in ci.fusion_ops:
            callee_ci = infos.get(callee)
            _, out_bytes = _shape_elems_bytes(out_type)
            total = (callee_ci.out_write_bytes
                     if callee_ci and callee_ci.out_write_bytes is not None
                     else out_bytes)
            for idx, opname in enumerate(operands):
                full = _shape_elems_bytes(ci.symbols.get(opname, ""))[1]
                if callee_ci and idx in callee_ci.param_read_bytes:
                    total += min(full, callee_ci.param_read_bytes[idx])
                else:
                    total += full
            if callee_ci is not None and callee_ci.is_pure_convert:
                ci.mem_by_kind["fp_convert(cpu-legalization)"] =                     ci.mem_by_kind.get("fp_convert(cpu-legalization)", 0.0) + total
                continue
            ci.mem_bytes += total
            ci.mem_by_kind["fusion"] = ci.mem_by_kind.get("fusion", 0.0) + total

    memo = {}

    def total(name: str):
        if name in memo:
            return memo[name]
        ci = infos.get(name)
        if ci is None:
            return (0.0, 0.0, {}, {}, {})
        f, b = ci.flops, ci.mem_bytes
        c = dict(ci.coll)
        cc = dict(ci.coll_counts)
        mk = dict(ci.mem_by_kind)
        memo[name] = (f, b, c, cc, mk)  # cycle guard
        for callee, mult in ci.edges:
            cf, cb, ccoll, ccnt, cmk = total(callee)
            f += mult * cf
            b += mult * cb
            for k, v in ccoll.items():
                c[k] = c.get(k, 0.0) + mult * v
            for k, v in ccnt.items():
                cc[k] = cc.get(k, 0.0) + mult * v
            for k, v in cmk.items():
                mk[k] = mk.get(k, 0.0) + mult * v
        memo[name] = (f, b, c, cc, mk)
        return memo[name]

    f, b, c, cc, mk = total(entry) if entry else (0.0, 0.0, {}, {}, {})
    return HloTotals(
        flops_per_chip=f, mem_bytes_per_chip=b,
        coll_bytes_per_chip=sum(c.values()), coll_by_kind=c, coll_counts=cc,
        mem_by_kind=mk)


# --- hardware constants (TPU v5e target, per assignment) ---------------------
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (per-chip effective, conservative)


@dataclass
class Roofline:
    hlo_flops: float              # whole-program FLOPs (global = per-chip x chips)
    hlo_bytes: float              # whole-program HBM bytes (global)
    coll_bytes_per_chip: float    # per-chip wire bytes
    chips: int
    model_flops: float            # 6*N*D (train) / 2*N_active*D (inference)
    model_bytes: float = 0.0      # minimum necessary HBM traffic (global)

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_frac(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def t_ideal(self) -> float:
        """Hardware floor for this workload: the slower of (useful FLOPs at
        peak) and (minimum-necessary bytes at full HBM bandwidth).  Decode is
        legitimately memory-bound — its roofline target is the bandwidth
        floor, not peak FLOPs."""
        t_c = self.model_flops / (self.chips * PEAK_FLOPS)
        t_m = self.model_bytes / (self.chips * HBM_BW)
        return max(t_c, t_m)

    @property
    def roofline_frac(self) -> float:
        """t_ideal / t_bound — how close the compiled program's dominant
        roofline term is to the workload's hardware floor."""
        return self.t_ideal / self.t_bound if self.t_bound else 0.0

    def as_dict(self) -> Dict[str, float]:
        return {
            "hlo_flops": self.hlo_flops,
            "hlo_bytes": self.hlo_bytes,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "model_bytes": self.model_bytes,
            "t_ideal_s": self.t_ideal,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_frac": self.roofline_frac,
        }
