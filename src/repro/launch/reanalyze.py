"""Recompute roofline JSONs from saved .hlo.gz artifacts (no recompilation).

Keeps every published number on ONE analyzer version: after an analyzer
refinement, re-run this over experiments/hlo/ to refresh experiments/dryrun/.
"""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os
import re

from repro.configs import SHAPES, get_config
from repro.launch import hlo_analysis as hlo
from repro.launch.dryrun import model_bytes, model_flops


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--hlo-dir", default="experiments/hlo")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    for path in sorted(glob.glob(os.path.join(args.hlo_dir, "*.hlo.gz"))):
        name = os.path.basename(path)[:-7]
        m = re.match(r"(.+)__(\w+)__pod(\d)(?:__(\w+))?$", name)
        arch, shape_name, pods, variant = m.group(1), m.group(2), int(m.group(3)), m.group(4) or "baseline"
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        chips = 256 * pods
        with gzip.open(path, "rt") as f:
            totals = hlo.analyze(f.read())
        roof = hlo.Roofline(
            hlo_flops=totals.flops_per_chip * chips,
            hlo_bytes=totals.mem_bytes_per_chip * chips,
            coll_bytes_per_chip=totals.coll_bytes_per_chip,
            chips=chips, model_flops=model_flops(cfg, shape),
            model_bytes=model_bytes(cfg, shape))
        out_path = os.path.join(args.out, name + ".json")
        base = {}
        if os.path.exists(out_path):
            with open(out_path) as f:
                base = json.load(f)
        base.update({
            "arch": arch, "shape": shape_name, "chips": chips,
            "mesh": "2x16x16" if pods == 2 else "16x16",
            "variant": variant, "status": "ok",
            "collectives": {"by_kind": totals.coll_by_kind,
                            "op_counts_weighted": totals.coll_counts,
                            "total_per_chip": totals.coll_bytes_per_chip},
            "mem_by_kind_per_chip": totals.mem_by_kind,
            "roofline": roof.as_dict(),
        })
        with open(out_path, "w") as f:
            json.dump(base, f, indent=2, default=str)
        r = roof.as_dict()
        print(f"{name}: bn={r['bottleneck']} frac={r['roofline_frac']:.4f}")


if __name__ == "__main__":
    main()
