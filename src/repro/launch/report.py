"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONs."""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(results_dir: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_row(r: Dict) -> str:
    tag = f"{r['arch']} x {r['shape']} [{r['mesh']}]"
    if r["status"] == "skipped":
        return f"| {tag} | — | — | — | — | — | skipped: {r['reason'][:40]}… |"
    if r["status"] != "ok":
        return f"| {tag} | ERROR | | | | | |"
    ro = r["roofline"]
    t = [ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"]]
    return ("| {tag} | {tc:.4g} | {tm:.4g} | {tcoll:.4g} | {bn} | "
            "{useful:.2f} | {frac:.3f} |".format(
                tag=tag, tc=t[0], tm=t[1], tcoll=t[2], bn=ro["bottleneck"],
                useful=ro["useful_flops_frac"], frac=ro["roofline_frac"]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None, help="filter: 16x16 or 2x16x16")
    args = ap.parse_args()
    rows = load(args.dir)
    if args.mesh:
        rows = [r for r in rows if r.get("mesh") == args.mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r.get("mesh", "")))
    print("| arch x shape [mesh] | t_comp (s) | t_mem (s) | t_coll (s) | "
          "bottleneck | MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|")
    for r in rows:
        print(fmt_row(r))


if __name__ == "__main__":
    main()
