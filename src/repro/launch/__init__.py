"""repro.launch"""
