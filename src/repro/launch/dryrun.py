import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding rules are coherent (pjit partitions every op),
  * the program fits (memory_analysis),
  * and it emits the roofline terms (cost_analysis + HLO collective parse).

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod]    # every valid cell
"""
import argparse
import dataclasses
import gzip
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, registry
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import hlo_analysis as hlo
from repro.launch.mesh import make_production_mesh
from repro.models import api
from repro.train import optimizer as opt_mod
from repro.train import step as step_mod

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


def cell_skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> Optional[str]:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention arch: 500k decode needs sub-quadratic "
                "attention (DESIGN.md §7)")
    return None


OPT_NOTES = """--variant opt applies (per shape kind; EXPERIMENTS.md §Perf):
  decode : H2 shard_map LSE flash-decode over the seq-sharded cache +
           aligned cache writes (kills the cache all-gather/scatter);
           H3 paper-technique W4A8 device weights (s8-direct MXU dots).
  train/prefill : H1 chunked matmul-form WKV for rwkv (rwkv_chunk=64);
           H4 ZeRO-3 per-layer weight gather (MoE experts excluded) +
           residual-stream batch pinning; G1 grouped-einsum attention
           (in ref.mha_chunked, always on after the G1 commit — the
           original baselines are preserved in experiments/dryrun_baseline/).
"""


def apply_variant(cfg: ModelConfig, shape: ShapeConfig, variant: str) -> ModelConfig:
    if variant == "baseline":
        return cfg
    assert variant == "opt", variant
    if cfg.family == "rwkv":
        cfg = dataclasses.replace(cfg, rwkv_chunk=64)
    if cfg.family == "hymba":
        cfg = dataclasses.replace(cfg, ssm_scan="associative")
    if shape.kind in ("train", "prefill"):
        par = dataclasses.replace(cfg.parallel, gather_fsdp_weights=True)
        cfg = dataclasses.replace(cfg, parallel=par)
    if shape.kind == "decode":
        par = dataclasses.replace(cfg.parallel, decode_attn="shard_map")
        ita = dataclasses.replace(cfg.ita, quantize_weights=True)
        cfg = dataclasses.replace(cfg, parallel=par, ita=ita)
    return cfg


def adapt_parallel(cfg: ModelConfig, shape: ShapeConfig, mesh) -> ModelConfig:
    """Per-cell parallelism fixes: drop batch axes that don't divide."""
    par = cfg.parallel
    sizes = [mesh.shape[a] for a in par.batch_axes if a in mesh.axis_names]
    total = 1
    for s in sizes:
        total *= s
    if shape.global_batch % max(total, 1) != 0:
        # keep the largest prefix of batch axes that divides
        axes = []
        prod = 1
        for a in par.batch_axes:
            if a in mesh.axis_names and shape.global_batch % (prod * mesh.shape[a]) == 0:
                axes.append(a)
                prod *= mesh.shape[a]
        par = dataclasses.replace(par, batch_axes=tuple(axes))
    return dataclasses.replace(cfg, parallel=par)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token


def model_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Minimum-necessary global HBM traffic for one step (the memory-roofline
    floor).  bf16 weights/activations; fp32 optimizer state.

      train   : weights read fwd+bwd (2x2P) + grads written (2P) + AdamW
                moments read+written (2 x 8P fp32, or 2P int8-quantized)
      prefill : weights read once (2P) + KV cache written once
      decode  : active weights read once per step (2P_act; batch amortizes)
                + the whole KV cache / recurrent state read once
    """
    P_tot = cfg.param_count()
    P_act = cfg.active_param_count()
    B, T = shape.global_batch, shape.seq_len
    kv_bytes_full = 0.0
    n_groups = cfg.num_layers
    window = None
    if cfg.layer_pattern:
        windows = [s.window for s in cfg.layer_pattern]
        per_layer = []
        for i in range(cfg.num_layers):
            w = windows[i % len(windows)]
            s_len = min(T, w) if w else T
            per_layer.append(s_len)
        kv_bytes_full = sum(2 * s_len * cfg.kv_dim * 2 * B for s_len in per_layer)
    if cfg.family == "rwkv":
        hd = 64
        kv_bytes_full = cfg.num_layers * B * (cfg.d_model // hd) * hd * hd * 4
    if cfg.family == "hymba":
        ssm_state = (cfg.ssm.state_dim if cfg.ssm else 16)
        kv_bytes_full += cfg.num_layers * B * cfg.d_model * ssm_state * 4
    if shape.kind == "train":
        moments = 4.0 * P_tot if cfg.param_count() > 5e10 else 32.0 * P_tot
        return 6.0 * P_tot + moments  # 2P fwd + 2P bwd + 2P grads (+opt)
    if shape.kind == "prefill":
        return 2.0 * P_tot + kv_bytes_full
    # decode: every live weight streams once; whole cache read once
    return 2.0 * P_act + kv_bytes_full


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               variant: str = "baseline") -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    reason = cell_skip_reason(cfg, shape)
    meta: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": chips, "variant": variant,
    }
    if reason:
        return dict(meta, status="skipped", reason=reason)

    cfg = apply_variant(cfg, shape, variant)
    cfg = adapt_parallel(cfg, shape, mesh)
    key = jax.random.PRNGKey(0)
    params_like = jax.eval_shape(lambda k: api.init_params(cfg, k), key)
    if cfg.ita.quantize_weights and shape.kind == "decode":
        # H3: the serving weights are the LAQ INT4 codes (the "synthesis"
        # output) — shapes only, no allocation
        params_like = jax.eval_shape(
            lambda p: api.quantize_model(p, cfg), params_like)
    B, T = shape.global_batch, shape.seq_len
    specs = api.input_specs(cfg, shape)

    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            optcfg = opt_mod.AdamWConfig(
                quantize_moments=cfg.param_count() > 5e10)
            opt_like = jax.eval_shape(
                lambda p: opt_mod.init_state(p, optcfg), params_like)
            step = step_mod.make_train_step(cfg, optcfg, mesh, params_like,
                                            opt_like, donate=True)
            batch = {k: v for k, v in specs.items()}
            batch["mask"] = jax.ShapeDtypeStruct((B, T), jnp.float32)
            lowered = step.lower(params_like, opt_like, batch)
        elif shape.kind == "prefill":
            step = step_mod.make_prefill_step(cfg, mesh)(params_like)
            lowered = step.lower(params_like, specs)
        else:  # decode
            frontend = specs.get("frontend")
            cache_like = jax.eval_shape(
                lambda p, f: api.init_cache(cfg, B, T, frontend=f, params=p),
                params_like, frontend)
            step = step_mod.make_serve_step(cfg, mesh, params_like, cache_like,
                                            donate=True)
            lowered = step.lower(params_like, cache_like, specs["tokens"])
        t_lower = time.time() - t0

        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            mem[attr] = getattr(ma, attr, None)
    except Exception as e:  # CPU backend may not implement it
        mem["error"] = str(e)

    hlo_text = compiled.as_text()
    if os.environ.get("REPRO_DRYRUN_SAVE_HLO"):
        with open(os.environ["REPRO_DRYRUN_SAVE_HLO"], "w") as f:
            f.write(hlo_text)
    hlo_dir = os.environ.get("REPRO_HLO_DIR")
    if hlo_dir:
        os.makedirs(hlo_dir, exist_ok=True)
        suffix = "" if variant == "baseline" else f"__{variant}"
        tag = (f"{arch}__{shape_name}__"
               f"{'pod2' if multi_pod else 'pod1'}{suffix}.hlo.gz")
        with gzip.open(os.path.join(hlo_dir, tag), "wt") as f:
            f.write(hlo_text)
    totals = hlo.analyze(hlo_text)
    roof = hlo.Roofline(
        hlo_flops=totals.flops_per_chip * chips,
        hlo_bytes=totals.mem_bytes_per_chip * chips,
        coll_bytes_per_chip=totals.coll_bytes_per_chip,
        chips=chips,
        model_flops=model_flops(cfg, shape),
        model_bytes=model_bytes(cfg, shape),
    )
    return dict(
        meta, status="ok",
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=mem,
        collectives={"by_kind": totals.coll_by_kind,
                     "op_counts_weighted": totals.coll_counts,
                     "total_per_chip": totals.coll_bytes_per_chip},
        mem_by_kind_per_chip=totals.mem_by_kind,
        cost_analysis_raw={"flops": cost.get("flops"),
                           "bytes accessed": cost.get("bytes accessed")},
        roofline=roof.as_dict(),
        hlo_size=len(hlo_text),
    )


def run_cells(cells, multi_pod: bool, out_dir: str,
              variant: str = "baseline") -> int:
    os.makedirs(out_dir, exist_ok=True)
    failures = 0
    for arch, shape_name in cells:
        tag = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}"
        if variant != "baseline":
            tag += f"__{variant}"
        path = os.path.join(out_dir, tag + ".json")
        try:
            res = lower_cell(arch, shape_name, multi_pod, variant)
        except Exception:
            res = {"arch": arch, "shape": shape_name, "status": "error",
                   "traceback": traceback.format_exc()}
            failures += 1
        with open(path, "w") as f:
            json.dump(res, f, indent=2, default=str)
        status = res["status"]
        extra = ""
        if status == "ok":
            r = res["roofline"]
            extra = (f" bottleneck={r['bottleneck']}"
                     f" frac={r['roofline_frac']:.3f}"
                     f" compile={res['compile_s']}s")
        elif status == "error":
            extra = " " + res["traceback"].strip().splitlines()[-1]
        print(f"[{status:7s}] {tag}{extra}", flush=True)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--variant", default="baseline",
                    choices=("baseline", "opt"), help=OPT_NOTES)
    args = ap.parse_args()

    if args.all:
        cells = [(a, s) for a in registry.ASSIGNED for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    failures = run_cells(cells, args.multi_pod, args.out, args.variant)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
