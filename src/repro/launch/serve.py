"""Serving driver: batched greedy decoding for any --arch.

  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --smoke \
      --batch 4 --prompt-len 8 --max-new 16

  # continuous batching: N concurrent requests over a slot-based KV cache
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --continuous --requests 8 --slots 4 --max-new 16

  # paged KV cache + chunked prefill: KV lives in a shared page pool,
  # prompts stream in fixed-width chunks between decode steps
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --continuous --page-size 8 --prefill-chunk 8

  # shared-prefix KV reuse: prompts sharing page-aligned prefixes with
  # earlier requests skip re-prefilling them (ref-counted CoW pages)
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --continuous --page-size 8 --prefill-chunk 8 --prefix-cache on

  # quantized KV pages: int8 (or fp8) codes + per-page scales, dequant
  # fused into the decode kernel's page fetch (~2x resident tokens/byte)
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --continuous --page-size 8 --kv-dtype int8

  # online semantics: SLA classes, deadlines, SLA-aware preemption
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --continuous --page-size 8 --priority 0,0,0,1 --deadline-s 5 \
      --preemption on

  # tensor-parallel serving over a (1, tp) device mesh (DESIGN.md §11);
  # on a CPU-only host, force visible devices first:
  XLA_FLAGS=--xla_force_host_platform_device_count=2 JAX_PLATFORMS=cpu \
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --continuous --page-size 8 --tp 2

  # chaos: seeded device-fault injection against the recovery seam
  # (DESIGN.md §12) — NaN-corrupt half the decoding slots for two
  # iterations, then lose the device wholesale; the run must still finish
  # every request, and --recovery-log captures the quarantine/recover
  # event stream as JSON
  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --continuous --page-size 8 --prefill-chunk 8 --prefix-cache on \
      --chaos-seed 0 --recovery-log recovery_events.json \
      --chaos-plan "step_corrupt_at=4,step_corrupt_iters=2,device_loss_at=10"
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.serve import pages
from repro.serve.engine import ServeEngine
from repro.serve.faults import FaultInjector, FaultPlan
from repro.serve.scheduler import ContinuousBatchingScheduler, Request


def _parse_chaos_plan(spec: str, ap: argparse.ArgumentParser) -> FaultPlan:
    """``key=val,key=val`` over FaultPlan's fields, coerced per field type
    (tuple fields take ``+``-separated uids, e.g. ``step_corrupt_uids=1+3``).
    """
    fields = {f.name: f for f in dataclasses.fields(FaultPlan)}
    kwargs = {}
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        key, sep, val = item.partition("=")
        key, val = key.strip(), val.strip()
        if not sep or key not in fields:
            ap.error(f"--chaos-plan: unknown or malformed entry {item!r} "
                     f"(fields: {', '.join(sorted(fields))})")
        ftype = str(fields[key].type)
        try:
            if "Tuple" in ftype:
                kwargs[key] = tuple(int(v) for v in val.split("+") if v)
            elif ftype == "float":
                kwargs[key] = float(val)
            else:
                kwargs[key] = int(val)
        except ValueError:
            ap.error(f"--chaos-plan: bad value {val!r} for {key} ({ftype})")
    if not kwargs:
        ap.error("--chaos-plan named no fault points")
    return FaultPlan(**kwargs)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="serve --requests ragged prompts via the "
                         "slot-based continuous-batching scheduler")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=None,
                    help="enable the paged KV cache with this page size "
                         "(tokens per page; must divide max_len)")
    ap.add_argument("--num-pages", type=int, default=None,
                    help="page-pool capacity (default: dense-equivalent)")
    ap.add_argument("--paged-attn", choices=("inplace", "gather"),
                    default="inplace",
                    help="paged decode discipline: 'inplace' computes "
                         "attention directly through the page table "
                         "(gather-free, no dense-view transient); 'gather' "
                         "keeps the dense-view fallback/oracle")
    ap.add_argument("--kv-dtype", choices=("bf16", "int8", "fp8"),
                    default="bf16",
                    help="page-pool storage format (DESIGN.md §13): int8/fp8 "
                         "pages quantize on write with per-page per-kv-head "
                         "scales and dequantize inside the decode kernel's "
                         "page fetch (~2x more resident tokens per pool "
                         "byte); requires --page-size")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked prefill width (interleaves prompt chunks "
                         "with decode steps; must divide max_len)")
    ap.add_argument("--prefix-cache", choices=("on", "off"), default="off",
                    help="shared-prefix KV reuse: admission radix-matches "
                         "each prompt against previously served page-"
                         "aligned prefixes and maps the shared pages "
                         "(refcounted, copy-on-write) instead of "
                         "re-prefilling them; requires --page-size, "
                         "no-ops for families with recurrent/ring state")
    ap.add_argument("--priority", default=None,
                    help="comma-separated SLA classes cycled over the "
                         "request stream (higher wins admission and may "
                         "preempt lower), e.g. '0,0,0,1'")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request deadline in seconds from serve-loop "
                         "start; a request not finished by then terminates "
                         "as TIMEOUT (slot and pages freed)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel degree: serve over a "
                         "(data=1, model=tp) mesh — params column-cut, page "
                         "pool cut on KV heads, token-identical to --tp 1 "
                         "(needs >= tp visible devices; see module docstring "
                         "for forcing host devices)")
    ap.add_argument("--chaos-plan", default=None,
                    help="seeded fault injection: comma-separated "
                         "FaultPlan fields (repro/serve/faults.py), e.g. "
                         "'step_corrupt_at=4,step_corrupt_iters=2,"
                         "device_loss_at=10'; device faults exercise "
                         "quarantine + host-authoritative recovery")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="PRNG seed for --chaos-plan: same (plan, seed) -> "
                         "same fault sequence")
    ap.add_argument("--recovery-log", default=None,
                    help="write the scheduler's quarantine/recover event "
                         "stream to this path as JSON")
    ap.add_argument("--preemption", choices=("on", "off"), default="off",
                    help="SLA-aware preemption: when a higher-priority "
                         "request cannot be admitted, evict a lower-"
                         "priority victim (publishing its full pages to "
                         "the prefix cache first) and re-queue it with "
                         "bounded exponential backoff")
    args = ap.parse_args(argv)
    if args.num_pages is not None and args.page_size is None:
        ap.error("--num-pages requires --page-size (the paged KV cache)")
    if args.prefix_cache == "on" and args.page_size is None:
        ap.error("--prefix-cache on requires --page-size (the prefix index "
                 "shares pool pages)")
    if args.kv_dtype != "bf16" and args.page_size is None:
        ap.error("--kv-dtype int8/fp8 requires --page-size (quantization "
                 "scales live per pool page)")
    if not args.continuous and (args.page_size is not None
                                or args.num_pages is not None
                                or args.prefill_chunk is not None):
        ap.error("--page-size/--num-pages/--prefill-chunk only apply to "
                 "the --continuous serve loop")
    if not args.continuous and (args.priority is not None
                                or args.deadline_s is not None
                                or args.preemption == "on"):
        ap.error("--priority/--deadline-s/--preemption only apply to the "
                 "--continuous serve loop")
    if not args.continuous and (args.chaos_plan is not None
                                or args.recovery_log is not None):
        ap.error("--chaos-plan/--recovery-log only apply to the "
                 "--continuous serve loop")
    faults = None
    if args.chaos_plan is not None:
        faults = FaultInjector(_parse_chaos_plan(args.chaos_plan, ap),
                               seed=args.chaos_seed)
    priorities = [0]
    if args.priority is not None:
        try:
            priorities = [int(p) for p in args.priority.split(",") if p != ""]
        except ValueError:
            ap.error(f"--priority must be comma-separated integers, "
                     f"got {args.priority!r}")
        if not priorities:
            ap.error("--priority must name at least one SLA class")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    mesh = None
    if args.tp > 1:
        if jax.device_count() < args.tp:
            ap.error(f"--tp {args.tp} needs >= {args.tp} devices, have "
                     f"{jax.device_count()} (see module docstring for "
                     f"forcing host devices)")
        mesh = make_test_mesh(shape=(1, args.tp))

    if args.continuous:
        # pages AND prefill chunks must both tile the cache
        max_len = pages.round_len(args.prompt_len + args.max_new + 1,
                                  args.page_size, args.prefill_chunk)
        eng = ServeEngine(cfg, params, mesh=mesh, max_len=max_len,
                          page_size=args.page_size, num_pages=args.num_pages,
                          paged_attn=args.paged_attn,
                          prefix_cache=args.prefix_cache,
                          kv_dtype=args.kv_dtype)
        lo = min(2, args.prompt_len)
        reqs = [Request(uid=i,
                        prompt=rng.integers(
                            1, cfg.vocab_size,
                            (int(rng.integers(lo, args.prompt_len + 1)),)
                        ).astype(np.int32),
                        max_new=args.max_new,
                        priority=priorities[i % len(priorities)],
                        deadline_s=args.deadline_s)
                for i in range(args.requests)]
        sched = ContinuousBatchingScheduler(
            eng, max_slots=args.slots, eos_id=args.eos_id,
            prefill_chunk=args.prefill_chunk,
            preemption=args.preemption == "on", faults=faults)
        out = sched.run(reqs)
        report = {
            "arch": cfg.name,
            "tp": args.tp,
            "requests": args.requests,
            "slots": args.slots,
            "steps": out["steps"],
            "decoded_tokens": out["decoded_tokens"],
            "tokens_per_s": round(out["tokens_per_s"], 2),
            "requests_per_s": round(out["requests_per_s"], 2),
            "gen_len": [r.gen_len for r in out["results"]],
            "cached_prompt_tokens": out["cached_prompt_tokens"],
            "rejected": [(r.uid, r.reason) for r in out["rejected"]],
            "by_state": out["by_state"],
            "preemptions": out["preemptions"],
        }
        if args.page_size:
            report["cache"] = eng.cache_stats(sched.cache)
        if faults is not None:
            fired: dict = {}
            for name, *_ in faults.events:
                fired[name] = fired.get(name, 0) + 1
            report["chaos"] = {
                "seed": args.chaos_seed,
                "fired": fired,
                "quarantines": out["quarantines"],
                "failed": out["failed"],
                "recoveries": out["recoveries"],
                "last_recovery_s": round(out["last_recovery_s"], 4),
            }
        if args.recovery_log is not None:
            Path(args.recovery_log).write_text(
                json.dumps(sched.recovery_log, indent=2) + "\n")
        print(json.dumps(report))
        return out

    prompts = rng.integers(1, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    frontend = (jnp.asarray(rng.standard_normal(
        (args.batch, cfg.frontend_tokens, cfg.d_model)), jnp.float32)
        if cfg.frontend_tokens else None)

    eng = ServeEngine(cfg, params, mesh=mesh,
                      max_len=args.prompt_len + args.max_new + 1)
    out = eng.generate(prompts, max_new=args.max_new, frontend=frontend,
                       eos_id=args.eos_id)
    print(json.dumps({
        "arch": cfg.name,
        "tp": args.tp,
        "batch": args.batch,
        "generated": out["tokens"][:2, :8].tolist(),
        "gen_len": out["gen_len"].tolist(),
        "tokens_per_s": round(out["tokens_per_s"], 2),
    }))
    return out


if __name__ == "__main__":
    main()
