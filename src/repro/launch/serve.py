"""Serving driver: batched greedy decoding for any --arch.

  PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b --smoke \
      --batch 4 --prompt-len 8 --max-new 16
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(1, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    frontend = (jnp.asarray(rng.standard_normal(
        (args.batch, cfg.frontend_tokens, cfg.d_model)), jnp.float32)
        if cfg.frontend_tokens else None)

    eng = ServeEngine(cfg, params,
                      max_len=args.prompt_len + args.max_new + 1)
    out = eng.generate(prompts, max_new=args.max_new, frontend=frontend)
    print(json.dumps({
        "arch": cfg.name,
        "batch": args.batch,
        "generated": out["tokens"][:2, :8].tolist(),
        "tokens_per_s": round(out["tokens_per_s"], 2),
    }))
    return out


if __name__ == "__main__":
    main()
