"""Logic-Aware Quantization (LAQ) — the paper's §IV-C in software.

Pipeline (per weight matrix):
  1. symmetric per-output-channel INT4 quantization (scale = amax/7),
  2. zero-weight pruning: |w| below ``prune_threshold`` * scale is forced to
     zero, deleting the MAC entirely (§IV-C.3; paper threshold 2^-6 of the
     full-scale range, claimed to catch 15-25% of weights),
  3. logic-aware rounding: between the two nearest INT4 codes, prefer the
     one whose CSD encoding needs fewer adders when the extra quantization
     error is below ``laq_slack`` of the scale (this is the "exploiting
     knowledge of weight values during synthesis" step).

Activations are INT8 symmetric (§V-C).  The paper's device model calibrates
ONE static range per tensor; this implementation defaults to per-row
(per-token) dynamic scales — the serving path's dynamic-range mode, which is
what the W4A8 kernel consumes — and ``quantize_activations_int8(...,
per_tensor=True)`` gives the paper's per-tensor static-range behaviour.

All functions are functional and jittable; weights-side tables come from
``core.csd`` and are baked in as constants.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core import csd

__all__ = [
    "QuantizedLinear",
    "QuantizedLeaf",
    "KV_DTYPES",
    "KV_QMAX",
    "quantize_weights",
    "dequantize",
    "quantize_activations_int8",
    "w4a8_matmul_ref",
    "pruned_fraction",
]

INT4_MIN, INT4_MAX = -7, 7  # symmetric grid keeps the CSD tables balanced
DEFAULT_PRUNE_THRESHOLD = 2.0 ** -6  # §IV-C.3, fraction of full scale
DEFAULT_LAQ_SLACK = 0.35  # extra quant error allowed (in units of scale) to buy a cheaper CSD code


@jax.tree_util.register_pytree_with_keys_class
@dataclass
class QuantizedLinear:
    """An INT4 weight matrix plus per-channel scales — the 'hardwired' layer.

    ``codes`` is int8 storage of INT4 values in [-7, 7]; ``scales`` is
    float32 of shape ``codes.shape[-1]`` (per output channel).

    Registered WITH key paths so the sharding-rules engine sees
    ``.../w1/codes`` (sharded like the weight) and ``.../w1/scales``.
    """

    codes: jnp.ndarray
    scales: jnp.ndarray

    def tree_flatten_with_keys(self):
        return (((jax.tree_util.GetAttrKey("codes"), self.codes),
                 (jax.tree_util.GetAttrKey("scales"), self.scales)), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        return self.codes.shape


# KV-cache page quantization formats (serve-path paged pools, DESIGN.md §13).
# fp8 uses the e4m3 grid — the inference-standard format with the wider
# dynamic range per page (the per-page scale absorbs the exponent anyway).
KV_DTYPES = {"int8": jnp.int8, "fp8": jnp.float8_e4m3fn}
KV_QMAX = {"int8": 127.0, "fp8": 448.0}


@jax.tree_util.register_pytree_with_keys_class
class QuantizedLeaf:
    """A quantized page-pool cache leaf: integer/fp8 codes plus per-page,
    per-kv-head float32 scales riding beside the page table.

    ``codes`` has the pool leaf's layout ``(*lead, num_pages, page_size,
    *tail)``; ``scales`` drops the ``page_size`` axis and the trailing
    head_dim axis — one scale per (leading dims ×) page × kv-head.  The
    scale is a POWER OF TWO (``2^ceil(log2(amax/qmax))``), which makes the
    quantize→dequantize→requantize cycle idempotent: shared prefix pages
    quantize once and every re-encode of already-roundtripped content
    reproduces the same stored values (the prefix-cache identity contract,
    DESIGN.md §13).

    Registered WITH key paths so the sharding-rules engine sees
    ``.../k/0/codes`` (sharded like the pool leaf) and ``.../k/0/scales``.
    ``kv_dtype`` names the code format ("int8"/"fp8"); ``out_dtype`` the
    logical dense dtype dequantized views are produced in.
    """

    def __init__(self, codes, scales, kv_dtype: str = "int8",
                 out_dtype: str = "bfloat16"):
        self.codes = codes
        self.scales = scales
        self.kv_dtype = kv_dtype
        self.out_dtype = out_dtype

    def tree_flatten_with_keys(self):
        return (((jax.tree_util.GetAttrKey("codes"), self.codes),
                 (jax.tree_util.GetAttrKey("scales"), self.scales)),
                (self.kv_dtype, self.out_dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    def __getitem__(self, idx):
        """Index codes and scales together — leading (layer/group) axes are
        shared, so per-layer pool slices stay QuantizedLeaf."""
        return QuantizedLeaf(self.codes[idx], self.scales[idx],
                             self.kv_dtype, self.out_dtype)

    @property
    def shape(self):
        return self.codes.shape

    @property
    def dtype(self):
        return self.codes.dtype

    @property
    def nbytes(self):
        return int(self.codes.nbytes) + int(self.scales.nbytes)

    def __repr__(self):
        return (f"QuantizedLeaf({self.kv_dtype}, codes={self.codes.shape}, "
                f"scales={self.scales.shape})")


def _csd_cost_lut() -> jnp.ndarray:
    """cost[i] = CSD adder count of value (i-8), for int4 codes."""
    return jnp.asarray(csd.csd_cost_table(4), jnp.int32)


def quantize_weights(
    w: jnp.ndarray,
    *,
    prune_threshold: float = DEFAULT_PRUNE_THRESHOLD,
    laq_slack: float = DEFAULT_LAQ_SLACK,
    logic_aware: bool = True,
) -> QuantizedLinear:
    """Quantize a (in, out) weight matrix to LAQ INT4."""
    w = jnp.asarray(w, jnp.float32)
    scales = jnp.max(jnp.abs(w), axis=0, keepdims=True) / INT4_MAX
    scales = jnp.maximum(scales, 1e-12)
    x = w / scales

    lo = jnp.clip(jnp.floor(x), INT4_MIN, INT4_MAX)
    hi = jnp.clip(lo + 1, INT4_MIN, INT4_MAX)
    err_lo = jnp.abs(x - lo)
    err_hi = jnp.abs(x - hi)

    if logic_aware:
        cost = _csd_cost_lut()
        cost_lo = cost[(lo + 8).astype(jnp.int32)]
        cost_hi = cost[(hi + 8).astype(jnp.int32)]
        # Nearest code, unless the other code is CSD-cheaper and the error
        # penalty stays within the slack budget.
        nearest_is_lo = err_lo <= err_hi
        prefer_lo = (cost_lo < cost_hi) & (err_lo <= err_hi + laq_slack)
        prefer_hi = (cost_hi < cost_lo) & (err_hi <= err_lo + laq_slack)
        take_lo = jnp.where(prefer_lo, True, jnp.where(prefer_hi, False, nearest_is_lo))
    else:
        take_lo = err_lo <= err_hi
    q = jnp.where(take_lo, lo, hi).astype(jnp.int8)

    # Zero-weight pruning: synthesis deletes the MAC (§IV-C.3).  Threshold is
    # a fraction of the *full scale* range of the channel, matching the
    # paper's |w| < 2^-6 rule for weights normalized to [-1, 1].
    full_scale = scales * INT4_MAX
    q = jnp.where(jnp.abs(w) < prune_threshold * full_scale, 0, q).astype(jnp.int8)
    return QuantizedLinear(codes=q, scales=scales[0].astype(jnp.float32))


def dequantize(ql: QuantizedLinear, dtype=jnp.bfloat16) -> jnp.ndarray:
    return (ql.codes.astype(jnp.float32) * ql.scales).astype(dtype)


def quantize_activations_int8(x: jnp.ndarray, *, per_tensor: bool = False):
    """Symmetric INT8 activation quantization.

    Default is per-row (per-token) dynamic scaling — each row gets
    ``amax(row)/127`` — which is what the serving path and the W4A8 matmul
    use.  ``per_tensor=True`` collapses to a single ``amax(x)/127`` scale
    for the whole tensor, modelling the paper's §V-C device with one static
    calibrated activation range (the scale still broadcasts like the
    per-row one, so downstream rescaling code is shape-agnostic).
    """
    x = jnp.asarray(x, jnp.float32)
    if per_tensor:
        scale = jnp.broadcast_to(jnp.max(jnp.abs(x)) / 127.0,
                                 x.shape[:-1] + (1,))
    else:
        scale = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def w4a8_matmul_ref(x: jnp.ndarray, ql: QuantizedLinear, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Reference W4A8 matmul: int8 activations x int4 weights, int32 accum.

    This is the functional model of the ITA device datapath: activations are
    INT8, weights are the hardwired INT4 codes, accumulation is exact int32,
    and the result is rescaled by (act_scale * weight_scale).  The Pallas
    kernel in ``kernels/w4a8_matmul.py`` must match it bit-for-bit on the
    integer part.
    """
    qx, act_scale = quantize_activations_int8(x)
    acc = jax.lax.dot_general(
        qx, ql.codes,
        (((qx.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return (acc.astype(jnp.float32) * act_scale * ql.scales).astype(dtype)


def pruned_fraction(ql: QuantizedLinear) -> jnp.ndarray:
    return jnp.mean((ql.codes == 0).astype(jnp.float32))
