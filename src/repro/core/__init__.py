"""ITA core: CSD synthesis, logic-aware quantization, cost models, split-brain."""
from repro.core import costmodel, csd, fpga, quant, splitbrain  # noqa: F401
