"""FPGA prototype resource model — reproduces the paper's Tables VI and VII.

The paper validates ITA on a Zynq-7020 with two experiments:
  * Table VII (single neuron): 64 parallel MACs, generic vs hardwired.
    Measured: generic 1425 LUTs (22.3/MAC), hardwired 788 LUTs (12.3/MAC)
    => 1.81x LUT reduction, CARRY4 2.03x, registers 20.8x.
  * Table VI (full 64->128->64 network, 16384 MACs): baseline BRAM design
    11,309 LUTs; fully hardwired 170,502 LUTs (3.2x over device capacity).

We model LUT cost per MAC from the CSD statistics of the weight population:
a k-term shift-add tree of width W costs ~(k-1) * W/2 LUTs (a 6-input LUT
implements 2 bits of a ripple adder with carry via CARRY4), and the paper's
measured per-MAC figures pin the constants.
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core import csd

ZYNQ_7020_LUTS = 53_200
ZYNQ_7020_CARRY4 = 13_300

# Measured anchors from Table VII (per-MAC, 64-MAC single-neuron benchmark).
GENERIC_LUTS_PER_MAC = 22.3     # INT8 x INT4 generic multiplier + accumulate
GENERIC_CARRY4_PER_MAC = 407 / 64
GENERIC_REGS_PER_MAC = 644 / 64

ADDER_WIDTH_BITS = 12           # int8 act x int4 weight partial-sum width
LUTS_PER_ADDER_BIT = 0.5        # one LUT6+CARRY4 slice covers 2 adder bits
CARRY4_PER_ADDER = ADDER_WIDTH_BITS / 4.0
ACCUM_LUTS = 4.0                # accumulate-inject adder share per MAC
OUTPUT_REGS_PER_NEURON = 31.0   # Table VII: hardwired needs only output regs


def hardwired_mac_resources(weight_codes: Optional[np.ndarray] = None) -> Dict[str, float]:
    """Per-MAC LUT/CARRY4 cost of the hardwired shift-add implementation."""
    if weight_codes is None:
        # Paper's reference population: uniform nonzero INT4 codes.
        weight_codes = np.array([v for v in range(-7, 8) if v != 0], np.int64)
    codes = np.asarray(weight_codes).astype(np.int64).ravel()
    nnz = csd.csd_cost_table(4)[codes + 8]
    adders = np.maximum(0, nnz - 1)
    live = (codes != 0).astype(np.float64)
    luts = float((adders * ADDER_WIDTH_BITS * LUTS_PER_ADDER_BIT + live * ACCUM_LUTS).mean())
    # fixed per-MAC overhead: input select / sign handling (measured ~4.9 LUTs)
    luts += 4.9
    carry4 = float(((adders + live) * CARRY4_PER_ADDER).mean()) * 0.7
    return {"luts_per_mac": luts, "carry4_per_mac": carry4}


def single_neuron_table(weight_codes: Optional[np.ndarray] = None, n_macs: int = 64) -> Dict[str, float]:
    """Table VII: 64 parallel MACs, generic vs hardwired."""
    hw = hardwired_mac_resources(weight_codes)
    generic_luts = GENERIC_LUTS_PER_MAC * n_macs
    hardwired_luts = hw["luts_per_mac"] * n_macs
    return {
        "generic_luts": generic_luts,
        "hardwired_luts": hardwired_luts,
        "generic_carry4": GENERIC_CARRY4_PER_MAC * n_macs,
        "hardwired_carry4": hw["carry4_per_mac"] * n_macs,
        "generic_regs": GENERIC_REGS_PER_MAC * n_macs,
        "hardwired_regs": OUTPUT_REGS_PER_NEURON,
        "lut_reduction_x": generic_luts / hardwired_luts,
        "reg_reduction_x": (GENERIC_REGS_PER_MAC * n_macs) / OUTPUT_REGS_PER_NEURON,
    }


def full_network_table(layers=(64, 128, 64)) -> Dict[str, float]:
    """Table VI: the 64->128->64 fully-unrolled network on a Zynq-7020.

    The hardwired version spatially instantiates every MAC; the baseline
    time-multiplexes one MAC row through BRAM weights.
    """
    n_macs = sum(a * b for a, b in zip(layers[:-1], layers[1:]))
    hw = hardwired_mac_resources()
    # Fully-unrolled hardwired: every MAC in silicon; common-subexpression
    # sharing across a column's shift-add trees reclaims ~16% of LUTs
    # relative to standalone MACs (Table VI measured 170,502 for 16,384 MACs
    # = 10.4 LUT/MAC vs the standalone 12.3).
    CSE_FACTOR = 0.844
    hardwired_luts = n_macs * hw["luts_per_mac"] * CSE_FACTOR
    baseline_luts = 11_309.0  # time-multiplexed BRAM design (measured anchor)
    return {
        "n_macs": float(n_macs),
        "baseline_luts": baseline_luts,
        "hardwired_luts": hardwired_luts,
        "hardwired_over_capacity_x": hardwired_luts / ZYNQ_7020_LUTS,
        "fits_baseline": baseline_luts < ZYNQ_7020_LUTS,
        "fits_hardwired": hardwired_luts < ZYNQ_7020_LUTS,
    }


def fpga_vs_asic_gap(weight_codes: Optional[np.ndarray] = None) -> Dict[str, float]:
    """§VI-F.2: 1.81x on FPGA vs 4.85x projected ASIC — coarse LUTs vs gates."""
    from repro.core import costmodel

    fpga = single_neuron_table(weight_codes)["lut_reduction_x"]
    asic = costmodel.gate_reduction(weight_codes)["reduction_x"]
    return {"fpga_lut_reduction_x": fpga, "asic_gate_reduction_x": asic,
            "gap_x": asic / fpga}
