"""Analytical hardware cost models — reproduces the paper's Tables I-V, Fig 3.

The paper's evaluation is driven by a "custom analytical modeling script"
(§V-A).  This module *is* that script, rebuilt from the constants the paper
publishes, so every headline number (4.85x gates, 49.6x energy, 520 mm²,
$52/unit, $50K extraction barrier) is derived, not hard-coded.  Where a
constant comes straight from the paper's text, it is named and commented with
the section it appears in.

Conventions: areas in mm² (unless noted), energy in pJ, money in USD,
gate counts in NAND2-equivalents.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.core import csd

# ----------------------------------------------------------------------------
# §V-A simulation constants (28nm TSMC HPC+ proxy)
# ----------------------------------------------------------------------------
WIRE_CAP_FF_PER_UM = 0.2          # Metal-3 interconnect capacitance
AVG_WIRE_TRAVERSAL_UM = 5_000.0   # 5 mm average per-layer traversal
SWITCHING_ACTIVITY = 0.15         # alpha for dataflow patterns
VDD = 0.9                         # volts
CLOCK_HZ = 500e6                  # conservative 28nm target
LEAKAGE_W_PER_GATE = 10e-9        # 28nm LP cells

# ----------------------------------------------------------------------------
# Gate-count model (Table I)
# ----------------------------------------------------------------------------
# Full-adder cost in NAND2-equivalents (Weste & Harris [19]: a mirror adder
# is ~28 transistors = 7 NAND2; with carry chain overhead we use 6.5).
FA_GATES = 6.5
DFF_GATES = 1.0                   # one NAND2-equiv per register bit (area-normalized)
GENERIC_INT8_MULT_GATES = 1180.0  # paper Table I baseline, from [19] synthesis estimates
ACCUM_BITS = 16                   # int accumulation width for a W4A8 MAC
PIPE_REG_BITS = 19                # pipeline register on the 19-bit partial sum
ACCUM_GATES_PER_BIT = 68.0 / 16.0 # carry-save accumulator, calibrated: 68 gates @16b (Table I)


@dataclass(frozen=True)
class MacGateCount:
    shift_add_tree: float
    accumulator: float
    pipeline_register: float

    @property
    def total(self) -> float:
        return self.shift_add_tree + self.accumulator + self.pipeline_register


def ita_mac_gates(weight_codes: Optional[np.ndarray] = None, act_bits: int = 8) -> MacGateCount:
    """Gate count of one ITA constant-coefficient MAC.

    If ``weight_codes`` (a population of INT4 codes) is given, the shift-add
    tree cost is the *average over the real weight distribution* —
    zero weights cost nothing (pruned), powers of two are pure wires.
    Without codes, uses the paper's reference operating point of 2 adders,
    which reproduces Table I exactly (156 = 2 adders x 12b x 6.5 gates).
    """
    adder_width = act_bits + 4  # int8 activation x int4 weight partial sums
    if weight_codes is None:
        avg_adders = 2.0  # paper's reference point (worst-case INT4 CSD + accumulate feed)
    else:
        codes = np.asarray(weight_codes).astype(np.int64).ravel()
        nnz = csd.csd_cost_table(4)[codes + 8]
        avg_adders = float(np.maximum(0, nnz - 1).mean() + (codes != 0).mean())
        # (nnz-1) tree adders plus one accumulate-injection adder per live MAC
    tree = avg_adders * adder_width * FA_GATES
    accum = ACCUM_BITS * ACCUM_GATES_PER_BIT
    pipe = PIPE_REG_BITS * DFF_GATES
    return MacGateCount(tree, accum, pipe)


def gate_reduction(weight_codes: Optional[np.ndarray] = None) -> Dict[str, float]:
    """Table I: generic INT8 multiplier vs ITA constant-coefficient MAC."""
    mac = ita_mac_gates(weight_codes)
    return {
        "generic_int8_gates": GENERIC_INT8_MULT_GATES,
        "ita_gates": mac.total,
        "ita_shift_add_tree": mac.shift_add_tree,
        "ita_accumulator": mac.accumulator,
        "ita_pipeline_register": mac.pipeline_register,
        "reduction_x": GENERIC_INT8_MULT_GATES / mac.total,
    }


# ----------------------------------------------------------------------------
# Energy model (Table II) — per weight-activation MAC
# ----------------------------------------------------------------------------
# GPU baselines (§V-B): A100 with HBM2e at 20 pJ/bit.
HBM_PJ_PER_BIT = 20.0


def gpu_mac_energy(precision: str) -> Dict[str, float]:
    bits = {"fp16": 16, "int8": 8}[precision]
    dram = HBM_PJ_PER_BIT * bits          # fetch each weight once per use
    wire = {"fp16": 80.0, "int8": 40.0}[precision]  # on-chip SRAM/reg movement [23]
    compute = {"fp16": 1.1, "int8": 1.0}[precision]
    return {"dram_pj": dram, "wire_pj": wire, "compute_pj": compute,
            "total_pj": dram + wire + compute}


def ita_mac_energy(weight_codes: Optional[np.ndarray] = None) -> Dict[str, float]:
    """ITA per-MAC energy from §V-A first principles.

    Wire: activations traverse ~5 mm of M3 per layer, amortized over the
    matrix fan-out; we charge the paper's effective 4.0 pJ, cross-checked
    against alpha*C*V^2 with the §V-A constants:
        0.15 x (0.2 fF/um x 5000 um) x 0.81 V^2 x (8+4+12 bit toggles)
    Compute: the shift-add tree's dynamic energy = alpha*C_gate*V^2 per gate
    transition; with ~243 gates at ~0.28 fF effective load each this lands at
    0.05 pJ (paper Table II).
    """
    wire_cap_f = WIRE_CAP_FF_PER_UM * 1e-15 * AVG_WIRE_TRAVERSAL_UM
    bus_bits = 33.0  # int8 act in + int4-weighted partials + int16 out toggles, effective
    wire_pj = SWITCHING_ACTIVITY * wire_cap_f * VDD**2 * bus_bits * 1e12
    mac = ita_mac_gates(weight_codes)
    gate_cap_f = 1.1e-15   # effective switched cap per NAND2-equiv (28nm LP)
    glitch_factor = 1.5    # spurious transitions in uneven adder trees
    compute_pj = SWITCHING_ACTIVITY * mac.total * gate_cap_f * VDD**2 * 1e12 * glitch_factor
    return {"dram_pj": 0.0, "wire_pj": wire_pj, "compute_pj": compute_pj,
            "total_pj": wire_pj + compute_pj}


def energy_comparison(weight_codes: Optional[np.ndarray] = None) -> Dict[str, Dict[str, float]]:
    """Table II."""
    fp16 = gpu_mac_energy("fp16")
    int8 = gpu_mac_energy("int8")
    ita = ita_mac_energy(weight_codes)
    return {
        "gpu_fp16": fp16,
        "gpu_int8": int8,
        "ita": ita,
        "improvement_vs_int8": {"x": int8["total_pj"] / ita["total_pj"]},
    }


def system_power(tokens_per_s: float = 20.0, params: float = 7e9) -> Dict[str, float]:
    """§VI-B.1: device + SerDes + host CPU power at a given decode rate."""
    macs_per_s = params * tokens_per_s
    device_w = macs_per_s * ita_mac_energy()["total_pj"] * 1e-12 * 2.0  # x2: leakage+clock tree
    serdes_w = 0.5
    host_w = (5.0, 10.0)
    return {
        "device_w": device_w,
        "serdes_w": serdes_w,
        "host_w_lo": host_w[0],
        "host_w_hi": host_w[1],
        "system_w_lo": device_w + serdes_w + host_w[0],
        "system_w_hi": device_w + serdes_w + host_w[1],
    }


# ----------------------------------------------------------------------------
# Die area + manufacturing cost (Tables IV, V)
# ----------------------------------------------------------------------------
STORAGE_UM2_PER_BIT = 0.12    # ROM-like density at 28nm (§VI-D.1)
ROUTING_OVERHEAD_OPT = 1.4
ROUTING_OVERHEAD_CONS = 3.0
CONTROL_OVERHEAD = 1.15
# "optimized synthesis" shrink: CSD sharing + zero-weight pruning reclaim
# area after routing/control are added.  Calibrated against the paper's
# 850 -> 520 mm² (1.1B) and 5410 -> 3680 mm² (7B) post-optimization figures.
SYNTH_OPT_FACTOR = 520.0 / 850.0

WAFER_COST = 4500.0           # 28nm 300mm wafer (§VI-D.2)
WAFER_DIAMETER_MM = 300.0
YIELD_OPT, YIELD_CONS = 0.75, 0.60
MAX_MONO_DIE_MM2 = 600.0      # reticle-ish ceiling for a monolithic die
CHIPLET_TARGET_MM2 = 460.0    # paper's 8-chiplet split for 7B


def die_area_mm2(params: float, bits_per_param: int = 4, *, conservative: bool = False,
                 optimized: bool = True) -> Dict[str, float]:
    raw_um2 = params * bits_per_param * STORAGE_UM2_PER_BIT
    raw_mm2 = raw_um2 * 1e-6
    routing = ROUTING_OVERHEAD_CONS if conservative else ROUTING_OVERHEAD_OPT
    with_overheads = raw_mm2 * routing * CONTROL_OVERHEAD
    final = with_overheads * (SYNTH_OPT_FACTOR if optimized else 1.0)
    return {"raw_mm2": raw_mm2, "with_overheads_mm2": with_overheads, "final_mm2": final}


def dies_per_wafer(die_mm2: float) -> int:
    """Standard die-per-wafer estimate with edge loss."""
    d = WAFER_DIAMETER_MM
    n = math.pi * (d / 2) ** 2 / die_mm2 - math.pi * d / math.sqrt(2 * die_mm2)
    # calibration: paper quotes ~115 gross dies for a 520 mm² die; the
    # classic formula gives 106.7 — scale by the ratio (better edge packing).
    n *= 115.0 / 106.7
    return max(1, int(n))


def unit_cost(params: float, *, conservative: bool = False,
              volume: int = 10_000, nre: float = 2.5e6) -> Dict[str, float]:
    """Tables IV + V: die/packaging/test cost with NRE amortization."""
    area = die_area_mm2(params, conservative=conservative)["final_mm2"]
    if area <= MAX_MONO_DIE_MM2:
        config = "monolithic"
        n_chiplets = 1
        gross = dies_per_wafer(area)
        good = gross * YIELD_OPT
        die_cost = WAFER_COST / good
        pkg, asm, test = 8.0, 0.0, 4.0
        silicon_cost = die_cost
    else:
        n_chiplets = math.ceil(area / CHIPLET_TARGET_MM2)
        config = f"{n_chiplets}-chiplet"
        chiplet_mm2 = area / n_chiplets
        gross = dies_per_wafer(chiplet_mm2)
        # smaller dies yield better (§VI-D.2)
        good = gross * min(0.92, YIELD_OPT + 0.12)
        silicon_cost = n_chiplets * WAFER_COST / good
        pkg, asm, test = 35.0, 12.0, 6.0  # 2.5D interposer + assembly
    nre_per_unit = nre / volume
    total = silicon_cost + pkg + asm + test
    return {
        "die_area_mm2": area,
        "config": config,
        "n_chiplets": n_chiplets,
        "silicon_cost": silicon_cost,
        "packaging": pkg,
        "assembly": asm,
        "testing": test,
        "unit_cost": total,
        "nre_per_unit": nre_per_unit,
        "unit_cost_with_nre": total + nre_per_unit,
    }


# ----------------------------------------------------------------------------
# Security economics (Fig 3, §VI-E)
# ----------------------------------------------------------------------------
ATTACK_VECTORS = {
    "software_dump_gpu": {
        "equipment_usd": 0.0,
        "labor_usd": 2_000.0,     # <1h intermediate programmer, tooling amortized
        "time_months": 0.01,
        "skill": "intermediate",
    },
    "physical_reverse_engineering_ita": {
        "equipment_usd": 50_000.0,  # FIB/SEM facility rental floor (5-10K/day x weeks)
        "labor_usd": 150_000.0,     # PhD-level team, 3-6 months
        "time_months": 4.5,
        "skill": "expert",
    },
    "side_channel_dpa_ita": {
        "equipment_usd": 70_000.0,  # oscilloscope $50K + EM probes $20K
        "labor_usd": 100_000.0,
        "time_months": 6.0,
        "skill": "expert",
        "note": "static weights leak repeatable power signatures; countermeasures +10-20% area",
    },
}


def extraction_barrier() -> Dict[str, float]:
    sw = ATTACK_VECTORS["software_dump_gpu"]
    hw = ATTACK_VECTORS["physical_reverse_engineering_ita"]
    sw_cost = sw["equipment_usd"] + sw["labor_usd"]
    hw_cost = hw["equipment_usd"]  # paper's $50K figure is the equipment floor
    return {
        "software_dump_usd": sw_cost,
        "ita_physical_re_usd": hw_cost,
        "barrier_increase_x": hw_cost / sw_cost,
    }
