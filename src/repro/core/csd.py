"""Canonical Signed Digit (CSD) encoding and shift-add synthesis.

This is the heart of the paper's "Logic-Aware Quantization" (§IV-C): a
constant weight ``w`` multiplying an activation ``x`` is not a generic
multiplier but a shift-add tree

    y = sum_i c_i * (x << s_i),   c_i in {-1, +1}

where the (c_i, s_i) come from the CSD (non-adjacent form) encoding of the
integer weight.  CSD minimises the number of non-zero digits, which directly
sets the number of adders in the synthesized tree (adders = nnz - 1).

Everything here is bit-exact and pure-python/numpy at trace time; the
evaluation helpers are jittable so tests can verify the shift-add plan equals
ordinary integer multiplication on every representable input.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "csd_encode",
    "csd_nonzero_digits",
    "binary_nonzero_digits",
    "ShiftAddPlan",
    "shift_add_plan",
    "shift_add_eval",
    "csd_cost_table",
    "binary_cost_table",
    "adder_reduction",
]


def csd_encode(n: int) -> List[Tuple[int, int]]:
    """Encode integer ``n`` in canonical signed digit (non-adjacent) form.

    Returns a list of ``(sign, shift)`` with ``sign in {-1, +1}`` such that
    ``n == sum(sign * 2**shift)`` and no two non-zero digits are adjacent.
    """
    n = int(n)
    digits: List[Tuple[int, int]] = []
    shift = 0
    while n != 0:
        if n & 1:
            # r = 2 - (n mod 4): maps n%4==1 -> +1, n%4==3 -> -1
            r = 2 - (n & 3)
            digits.append((r, shift))
            n -= r
        n >>= 1
        shift += 1
    return digits


def csd_nonzero_digits(n: int) -> int:
    """Number of non-zero digits in the CSD encoding of ``n``."""
    return len(csd_encode(n))


def binary_nonzero_digits(n: int) -> int:
    """Number of non-zero digits in plain two's-complement binary.

    For negative numbers we count ``popcount(|n|) + 1`` (sign handling adds
    one subtractor), which matches the adder-count accounting used for
    unsigned shift-add trees.
    """
    n = int(n)
    if n < 0:
        return bin(-n).count("1") + 1
    return bin(n).count("1")


@dataclass(frozen=True)
class ShiftAddPlan:
    """A synthesized constant multiplier: ``y = sum_i signs[i]*(x << shifts[i])``."""

    weight: int
    signs: Tuple[int, ...]
    shifts: Tuple[int, ...]

    @property
    def num_terms(self) -> int:
        return len(self.signs)

    @property
    def num_adders(self) -> int:
        """Adders in the tree: combining k shifted terms needs k-1 adders.

        A weight of zero (pruned) or a single power of two (pure wire
        routing) needs zero adders — §IV-C.3, §IV-C.2.
        """
        return max(0, self.num_terms - 1)


@functools.lru_cache(maxsize=None)
def shift_add_plan(weight: int) -> ShiftAddPlan:
    digits = csd_encode(weight)
    signs = tuple(d[0] for d in digits)
    shifts = tuple(d[1] for d in digits)
    return ShiftAddPlan(weight=int(weight), signs=signs, shifts=shifts)


def shift_add_eval(plan: ShiftAddPlan, x):
    """Bit-exact evaluation of the shift-add tree on integer activations.

    ``x`` may be any integer jnp array.  Shifts are wire routing (§IV-C.2):
    implemented as multiplies by powers of two on int32 to avoid overflow.
    """
    x = jnp.asarray(x, jnp.int32)
    acc = jnp.zeros_like(x)
    for sign, shift in zip(plan.signs, plan.shifts):
        acc = acc + sign * (x << shift)
    return acc


@functools.lru_cache(maxsize=None)
def csd_cost_table(num_bits: int = 4) -> np.ndarray:
    """CSD non-zero-digit count for every signed ``num_bits`` integer.

    Index ``i`` holds the cost of the value ``i - 2**(num_bits-1)``
    (i.e. index 0 -> most negative).  Used to vectorize logic-aware rounding.
    """
    lo = -(2 ** (num_bits - 1))
    hi = 2 ** (num_bits - 1)
    return np.array([csd_nonzero_digits(v) for v in range(lo, hi)], np.int32)


@functools.lru_cache(maxsize=None)
def binary_cost_table(num_bits: int = 4) -> np.ndarray:
    lo = -(2 ** (num_bits - 1))
    hi = 2 ** (num_bits - 1)
    return np.array([binary_nonzero_digits(v) for v in range(lo, hi)], np.int32)


def adder_reduction(values: np.ndarray, num_bits: int = 4) -> dict:
    """CSD-vs-binary adder statistics over a population of integer weights.

    Reproduces the paper's claim that CSD reduces shift-add adders by
    30-40% on average (§IV-C.1, citing Gustafsson [21]).
    """
    values = np.asarray(values).astype(np.int64)
    offset = 2 ** (num_bits - 1)
    csd = csd_cost_table(num_bits)[values + offset]
    binary = binary_cost_table(num_bits)[values + offset]
    # adders = max(0, nnz - 1) per weight
    csd_adders = np.maximum(0, csd - 1)
    bin_adders = np.maximum(0, binary - 1)
    total_bin = float(bin_adders.sum())
    total_csd = float(csd_adders.sum())
    return {
        "mean_nnz_binary": float(binary.mean()),
        "mean_nnz_csd": float(csd.mean()),
        "total_adders_binary": total_bin,
        "total_adders_csd": total_csd,
        "adder_reduction_frac": 0.0 if total_bin == 0 else 1.0 - total_csd / total_bin,
    }
