"""The Split-Brain protocol (§IV-B, §VI-C): partition + traffic/latency model.

Two halves:
  * ``TrafficModel`` — the analytical bandwidth/latency model reproducing
    eq. 7-11 and Table III for any architecture config (not just Llama-2-7B).
  * ``TrafficMeter`` — runtime byte accounting used by the serving engine:
    every tensor that crosses the host<->device boundary is registered, so
    the *measured* per-token traffic can be checked against the analytical
    model (they must agree exactly — that is a test).

The device side is stateless (hardwired linear maps); the host side owns all
dynamic state (KV cache / SSM state), attention, normalization statistics,
and sampling.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Tuple

__all__ = ["Interface", "INTERFACES", "TrafficModel", "TrafficMeter"]

ACT_BYTES = 2  # INT16 activations on the wire (§VI-C.1)
DEVICE_COMPUTE_S = 64e-6      # 64 us linear-projection latency (§VI-C.2)
HOST_ATTENTION_S = 5e-3       # 5 ms host attention (NPU-offload scenario)
HOST_ATTENTION_CPU_S = 75e-3  # 50-100 ms realistic CPU scenario midpoint


@dataclass(frozen=True)
class Interface:
    name: str
    gbps: float                # marketing line rate
    effective_bytes_per_s: float  # sustained payload bandwidth used by the paper
    extra_cost_usd: float


INTERFACES: Dict[str, Interface] = {
    "pcie3x4": Interface("PCIe 3.0 x4", 32, 4e9, 15.0),
    "tb4": Interface("Thunderbolt 4", 40, 5e9, 30.0),
    "usb3": Interface("USB 3.0", 5, 300e6, 5.0),
    "usb4": Interface("USB 4.0", 40, 2e9, 10.0),
}


@dataclass(frozen=True)
class TrafficModel:
    """Per-token host<->device traffic for a decoder layer stack.

    Parameters describe the *backbone* that is split-brain partitioned.
    ``recurrent_state_dim`` covers attention-free blocks (RWKV/SSM): the
    recurrent update runs on the host, so the device ships the projected
    r/k/v/g vectors instead of K/V — same accounting, different width.
    """

    num_layers: int
    d_model: int
    kv_dim: int              # kv_heads * head_dim (= d_model for MHA)
    vocab_size: int
    act_bytes: int = ACT_BYTES
    cross_attn_layers: int = 0   # extra layers shipping cross-attn K/V (VLM/enc-dec)
    cross_kv_dim: int = 0
    recurrent_state_dim: int = 0  # extra per-layer host-bound projections (SSM/RWKV)

    # ---- eq. 7-9 ----
    def device_to_host_kv_bytes_per_layer(self) -> int:
        return 2 * self.kv_dim * self.act_bytes  # K and V projections

    def host_to_device_attn_bytes_per_layer(self) -> int:
        return self.d_model * self.act_bytes     # attention output

    def logits_bytes(self) -> int:
        return self.vocab_size * self.act_bytes

    # ---- eq. 10 ----
    def bytes_per_token(self) -> int:
        per_layer = (self.device_to_host_kv_bytes_per_layer()
                     + self.host_to_device_attn_bytes_per_layer()
                     + 2 * self.recurrent_state_dim * self.act_bytes)
        cross = self.cross_attn_layers * 2 * self.cross_kv_dim * self.act_bytes
        # cross-attn K/V are per-request (prefill), amortized ~0 per decode
        # token; counted separately via prefill_bytes().
        del cross
        return per_layer * self.num_layers + self.logits_bytes()

    def prefill_bytes(self, prompt_tokens: int, image_or_enc_tokens: int = 0) -> int:
        per_tok_body = self.bytes_per_token() - self.logits_bytes()
        cross = (self.cross_attn_layers * 2 * self.cross_kv_dim * self.act_bytes
                 * image_or_enc_tokens)
        return per_tok_body * prompt_tokens + self.logits_bytes() + cross

    # ---- eq. 11 ----
    def bandwidth_bytes_per_s(self, tokens_per_s: float = 20.0) -> float:
        return self.bytes_per_token() * tokens_per_s

    # ---- Table III ----
    def interface_latency(self, iface: Interface, host_attention_s: float = HOST_ATTENTION_S) -> Dict[str, float]:
        transfer_s = self.bytes_per_token() / iface.effective_bytes_per_s
        total_s = transfer_s + DEVICE_COMPUTE_S + host_attention_s
        return {
            "interface": iface.name,
            "transfer_ms": transfer_s * 1e3,
            "total_ms": total_s * 1e3,
            "tokens_per_s": 1.0 / total_s,
            "extra_cost_usd": iface.extra_cost_usd,
        }

    def interface_table(self) -> List[Dict[str, float]]:
        return [self.interface_latency(i) for i in INTERFACES.values()]

    @staticmethod
    def llama2_7b() -> "TrafficModel":
        """The paper's reference config (32L, d=4096, MHA, 32K vocab)."""
        return TrafficModel(num_layers=32, d_model=4096, kv_dim=4096, vocab_size=32000)

    @classmethod
    def for_config(cls, cfg) -> "TrafficModel":
        """Traffic model for any backbone config (eq. 7-10 abstraction).

        ``kv_dim`` is the per-layer dynamic-state projection width the device
        ships to the host each token: K/V for attention families, the
        K/V-equivalent recurrence inputs for attention-free blocks (both are
        ``num_kv_heads * head_dim`` wide in our configs).  This is the single
        accounting rule the serving engines and the continuous-batching
        scheduler replay per *active* token (DESIGN.md §4).
        """
        return cls(num_layers=cfg.num_layers, d_model=cfg.d_model,
                   kv_dim=cfg.kv_dim, vocab_size=cfg.vocab_size)


class TrafficMeter:
    """Runtime byte counter for tensors crossing the host/device boundary.

    A third, separately-tracked channel — ``host_read`` — counts HOST-LOCAL
    memory reads that never cross the interface (the KV-cache bytes host
    attention touches per decode step).  Like the rest of the meter these
    are replayed accounting entries, not hardware counters: each serve
    discipline logs its read MODEL (see
    ``serve/pages.py::PagedEngineMixin.kv_read_bytes_step``).  Eq. 7-10 do
    not include them, so they are excluded from :meth:`measured_bytes` and
    the exactness assertions; they exist so the paged serve path can report
    that its kernel reads only LIVE-page KV bytes per token, where the
    gather (dense-view) discipline reads ``max_slots x max_len`` worth
    regardless of occupancy.
    """

    def __init__(self) -> None:
        self.device_to_host = 0
        self.host_to_device = 0
        self.host_read_bytes = 0
        self.log: List[Tuple[str, str, int]] = []
        self.host_log: List[Tuple[str, int]] = []

    @staticmethod
    def _nbytes(shape, act_bytes: int = ACT_BYTES) -> int:
        return int(math.prod(shape)) * act_bytes

    def d2h(self, name: str, shape, act_bytes: int = ACT_BYTES) -> None:
        n = self._nbytes(shape, act_bytes)
        self.device_to_host += n
        self.log.append(("d2h", name, n))

    def h2d(self, name: str, shape, act_bytes: int = ACT_BYTES) -> None:
        n = self._nbytes(shape, act_bytes)
        self.host_to_device += n
        self.log.append(("h2d", name, n))

    def host_read(self, name: str, nbytes: int) -> None:
        """Log host-local bytes read (no boundary crossing; see class doc).
        Takes a byte count directly — these are real cache-dtype bytes, not
        eq. 7-10 wire widths."""
        n = int(nbytes)
        self.host_read_bytes += n
        self.host_log.append((name, n))

    def host_channel_bytes(self, name: str) -> int:
        """Total host-local bytes logged under ONE channel name.  The host
        channels are heterogeneous (KV reads, prefix-cache savings, CoW
        copies), so consumers comparing a specific quantity must filter by
        channel instead of using the ``host_read_bytes`` aggregate."""
        return sum(n for ch, n in self.host_log if ch == name)

    @property
    def total(self) -> int:
        return self.device_to_host + self.host_to_device

    def measured_bytes(self, count_q: bool = False) -> Dict[str, int]:
        """Summed boundary bytes under the paper's accounting.

        Eq. 7-10 count K/V out, attention in, logits out; the engines
        additionally log the QKV input activation under the name
        ``x_qkv_in``, which ``count_q=False`` (the paper's rule) excludes.
        The single accounting filter both serving engines share.
        """
        d2h = h2d = 0
        for direction, name, nbytes in self.log:
            if not count_q and name == "x_qkv_in":
                continue
            if direction == "d2h":
                d2h += nbytes
            else:
                h2d += nbytes
        return {"d2h": d2h, "h2d": h2d, "total": d2h + h2d}

    def reset(self) -> None:
        self.device_to_host = 0
        self.host_to_device = 0
        self.host_read_bytes = 0
        self.log.clear()
        self.host_log.clear()
