"""Decode-path benchmark: eager vs per-token jit vs fused generation.

Measures, per reduced config on CPU:
  * tokens/s of the three SplitBrainEngine decode paths
      eager — the per-layer Python reference loop (hundreds of op
              dispatches per token),
      jit   — one jitted scan-over-layers dispatch per token,
      fused — ONE dispatch for the whole generation (multi-token lax.scan),
  * XLA dispatches per token (eager: counted by patching the primitive
    dispatch entry point; jit/fused: structural — 1 per token / 1 per
    generation),
  * the per-token boundary bytes, asserted identical between the eager
    runtime meter and the jit trace-time replay (eq. 7-10 stay exact).

Plus the ServeEngine fused-prefill/fused-loop path vs its stepwise
reference on one production config.

Emits BENCH_decode.json so future PRs have a tokens/s trajectory:

  PYTHONPATH=src python benchmarks/decode_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve.engine import ServeEngine
from repro.serve.splitbrain_engine import SplitBrainEngine, traffic_model_for


def _count_eager_dispatches(fn) -> Optional[int]:
    """Count un-jitted primitive executions during fn() by patching JAX's
    eager dispatch entry point.  Returns None if the internal API moved."""
    try:
        from jax._src import dispatch as _dsp
        orig = _dsp.apply_primitive
    except (ImportError, AttributeError):
        fn()
        return None
    count = 0

    def counting(*args, **kwargs):
        nonlocal count
        count += 1
        return orig(*args, **kwargs)

    _dsp.apply_primitive = counting
    try:
        fn()
    finally:
        _dsp.apply_primitive = orig
    return count


def _bench_splitbrain(arch: str, batch: int, max_new: int,
                      quantize: bool) -> List[Dict[str, Any]]:
    cfg = get_config(arch).reduced(vocab_size=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(1, cfg.vocab_size, (batch, 4)).astype(np.int32)
    max_len = prompts.shape[1] + max_new + 1
    rows = []

    # ---- eager reference: per-layer Python loop --------------------------
    eng_e = SplitBrainEngine(cfg, params, max_len=max_len, quantize=quantize,
                             jit=False)
    eng_e.generate(prompts, max_new=2)  # warm op caches
    disp = _count_eager_dispatches(
        lambda: eng_e.decode_token_eager(eng_e.init_cache(batch),
                                         jnp.zeros((batch,), jnp.int32)))
    eng_e.meter.reset()
    eng_e.decode_token_eager(eng_e.init_cache(batch),
                             jnp.zeros((batch,), jnp.int32))
    eager_traffic = eng_e.measured_bytes_per_token(batch)
    out_e = eng_e.generate(prompts, max_new=max_new)
    rows.append({"config": cfg.name, "engine": "splitbrain", "mode": "eager",
                 "batch": batch, "new_tokens": max_new,
                 "tokens_per_s": out_e["tokens_per_s"],
                 "dispatches_per_token": disp})

    # ---- per-token jit: one scan-over-layers dispatch per token ----------
    eng_j = SplitBrainEngine(cfg, params, max_len=max_len, quantize=quantize,
                             jit=True)
    tok = jnp.asarray(prompts[:, 0])
    _, _, _ = eng_j.decode_token(eng_j.init_cache(batch), tok)  # compile
    eng_j.meter.reset()
    eng_j.decode_token(eng_j.init_cache(batch), tok)
    jit_traffic = eng_j.measured_bytes_per_token(batch)
    # eq. 7-10 equality must survive the refactor, byte for byte
    assert jit_traffic["total"] == traffic_model_for(cfg).bytes_per_token()
    cache = eng_j.init_cache(batch)
    t0 = time.perf_counter()
    for _ in range(max_new):
        tok, _, cache = eng_j.decode_token(cache, tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    rows.append({"config": cfg.name, "engine": "splitbrain", "mode": "jit",
                 "batch": batch, "new_tokens": max_new,
                 "tokens_per_s": batch * max_new / dt,
                 "dispatches_per_token": 1})

    # ---- fused: ONE dispatch for the whole generation --------------------
    eng_j.generate(prompts, max_new=max_new)  # compile
    out_f = eng_j.generate(prompts, max_new=max_new)
    rows.append({"config": cfg.name, "engine": "splitbrain", "mode": "fused",
                 "batch": batch, "new_tokens": max_new,
                 "tokens_per_s": out_f["tokens_per_s"],
                 "dispatches_per_token": 1.0 / (prompts.shape[1] - 1 + max_new)})

    traffic_identical = eager_traffic == jit_traffic
    for r in rows:
        r["bytes_per_token"] = jit_traffic["total"]
        r["traffic_identical_eager_vs_jit"] = traffic_identical
    return rows


def _bench_serve(arch: str, batch: int, max_new: int) -> List[Dict[str, Any]]:
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=4 + max_new + 1)
    prompts = np.random.default_rng(0).integers(
        1, cfg.vocab_size, (batch, 4)).astype(np.int32)
    rows = []
    for mode, fused in (("stepwise", False), ("fused", True)):
        eng.generate(prompts, max_new=max_new, fused=fused)  # compile
        out = eng.generate(prompts, max_new=max_new, fused=fused)
        rows.append({"config": cfg.name, "engine": "serve", "mode": mode,
                     "batch": batch, "new_tokens": max_new,
                     "tokens_per_s": out["tokens_per_s"],
                     "dispatches_per_token":
                         1 if not fused else 1.0 / max_new})
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="one config, few tokens (CI smoke)")
    ap.add_argument("--tokens", type=int, default=None,
                    help="generated tokens per measurement")
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args(argv)

    max_new = args.tokens or (8 if args.quick else 32)
    batch = 2
    sb_archs = ["tinyllama-1.1b"] if args.quick else \
        ["tinyllama-1.1b", "llama2-7b"]

    results: List[Dict[str, Any]] = []
    for arch in sb_archs:
        results += _bench_splitbrain(arch, batch, max_new, quantize=False)
    if not args.quick:
        results += _bench_serve("granite-8b", batch, max_new)

    summary: Dict[str, Any] = {}
    for arch in {r["config"] for r in results if r["engine"] == "splitbrain"}:
        by_mode = {r["mode"]: r for r in results if r["config"] == arch
                   and r["engine"] == "splitbrain"}
        summary[arch] = {
            "fused_vs_eager_speedup": round(
                by_mode["fused"]["tokens_per_s"]
                / by_mode["eager"]["tokens_per_s"], 2),
            "jit_vs_eager_speedup": round(
                by_mode["jit"]["tokens_per_s"]
                / by_mode["eager"]["tokens_per_s"], 2),
            "traffic_identical": by_mode["jit"]["traffic_identical_eager_vs_jit"],
        }

    report = {
        "schema": "decode_bench/v1",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "quick": args.quick,
        "results": results,
        "summary": summary,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(json.dumps(report["summary"], indent=2))
    print(f"wrote {args.out}")

    ok = all(s["fused_vs_eager_speedup"] >= 5.0 and s["traffic_identical"]
             for s in summary.values())
    if not ok:
        print("FAIL: fused decode < 5x eager or traffic mismatch",
              file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
