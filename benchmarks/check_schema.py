"""Validate BENCH_serve.json artifacts against the current bench schema.

CI runs this over the checked-in full-run artifact (and any freshly
generated --quick one), so a schema bump that forgets to regenerate
(or a bench edit that silently drops a gated field) fails the build:

  PYTHONPATH=src python benchmarks/check_schema.py BENCH_serve.json
"""
from __future__ import annotations

import json
import sys

SCHEMA = "serve_bench/v8"

# every per-arch result of the four slot-cache disciplines
RESULT_KEYS = {
    "config", "sequential", "continuous", "paged", "paged_gather",
    "requests_per_s_speedup", "paged_memory_saving",
    "steady_state_recompiles", "paged_steady_state_recompiles",
    "traffic_exact",
}
# the shared-prefix discipline (off/on pair)
PREFIX_KEYS = {
    "config", "off", "on", "token_identical", "prefix_overlap",
    "cached_prompt_tokens", "prefill_tokens_per_s_uplift",
    "kv_pages_stored_reduction", "zero_steady_state_recompiles",
    "traffic_exact",
}
# per-run latency percentiles (serve_bench/v4)
RUN_KEYS = {"latency_s", "ttft_s", "queue_wait_s", "cached_prompt_tokens"}
# the online-overload discipline (serve_bench/v5): unloaded vs 2x-overload
# with SLA preemption, per-priority percentiles, cancel SLO probe
OVERLOAD_KEYS = {
    "config", "unloaded", "overload", "overload_no_preemption",
    "high_prio_p95_ttft_ratio", "high_priority_frac", "preemptions",
    "cancel_pages_freed_one_iteration", "steady_state_recompiles",
    "traffic_exact",
}
OVERLOAD_RUN_KEYS = {"ttft_s_by_priority", "latency_s_by_priority",
                     "preemptions", "by_state"}
# the tensor-parallel discipline (serve_bench/v6): tp=1 vs tp=N forced-
# host-device subprocess runs, token identity + per-shard traffic gates
TP_KEYS = {
    "config", "tp", "tp1", "tpN", "token_identical", "traffic_exact",
    "kv_shards", "traffic_shards", "zero_steady_state_recompiles",
    "decode_tokens_per_s_speedup",
}
TP_RUN_KEYS = {"decode_tokens_per_s", "measured_bytes", "analytic_bytes",
               "traffic_exact", "steady_state_recompiles", "kv_shards",
               "traffic_shards"}
# the chaos-recovery discipline (serve_bench/v7): seeded device faults
# (NaN corruption, step error, device loss) vs the uninterrupted run
CHAOS_KEYS = {
    "config", "plan", "reference", "chaos", "recovery_log", "fired",
    "all_faults_fired", "token_identical", "all_done", "quarantines",
    "failed", "recoveries", "last_recovery_s", "recovery_bounded",
    "pool_baseline_restored", "zero_steady_state_recompiles",
}
CHAOS_RUN_KEYS = {"by_state", "decoded_tokens", "iterations", "quarantines",
                  "recoveries", "last_recovery_s"}
# the quantized-KV-pages discipline (serve_bench/v8): bf16 vs int8 page
# pools of identical geometry — storage uplift, divergence, byte-exactness
KV_QUANT_KEYS = {
    "config", "kv_dtype", "bf16", "quant",
    "resident_tokens_per_byte_uplift", "kv_read_bytes_shrink",
    "pool_bytes_bf16", "pool_bytes_quant", "token_divergence_frac",
    "token_flip_rate", "boundary_bytes_identical", "traffic_exact",
    "zero_steady_state_recompiles",
}
KV_QUANT_RUN_KEYS = {"steady_state_recompiles", "traffic",
                     "measured_boundary_bytes", "kv_read_bytes", "cache"}


def check(path: str) -> None:
    with open(path) as f:
        report = json.load(f)
    assert report.get("schema") == SCHEMA, (
        f"{path}: schema {report.get('schema')!r} != {SCHEMA!r} — "
        f"regenerate the artifact with benchmarks/serve_bench.py")
    assert report["results"], f"{path}: no results"
    for r in report["results"]:
        missing = RESULT_KEYS - r.keys()
        assert not missing, f"{path}: result {r['config']} missing {missing}"
        for run in ("continuous", "paged"):
            miss = RUN_KEYS - r[run].keys()
            assert not miss, f"{path}: {r['config']}.{run} missing {miss}"
            for k in ("latency_s", "ttft_s", "queue_wait_s"):
                assert {"p50", "p95"} <= r[run][k].keys(), (path, run, k)
    assert report.get("prefix_results"), f"{path}: no prefix_results"
    for r in report["prefix_results"]:
        missing = PREFIX_KEYS - r.keys()
        assert not missing, f"{path}: prefix {r['config']} missing {missing}"
        assert r["prefix_overlap"] >= 0.5, (
            f"{path}: prefix discipline must run at >= 50% overlap")
    assert report.get("overload_results"), f"{path}: no overload_results"
    for r in report["overload_results"]:
        missing = OVERLOAD_KEYS - r.keys()
        assert not missing, (
            f"{path}: overload {r['config']} missing {missing}")
        for run in ("unloaded", "overload", "overload_no_preemption"):
            miss = OVERLOAD_RUN_KEYS - r[run].keys()
            assert not miss, f"{path}: {r['config']}.{run} missing {miss}"
            for pct in r[run]["ttft_s_by_priority"].values():
                assert {"p50", "p95"} <= pct.keys(), (path, run)
        assert "1" in r["overload"]["ttft_s_by_priority"], (
            f"{path}: overload run has no high-priority tier")
    assert report.get("tp_results"), f"{path}: no tp_results"
    for r in report["tp_results"]:
        missing = TP_KEYS - r.keys()
        assert not missing, f"{path}: tp {r['config']} missing {missing}"
        for run in ("tp1", "tpN"):
            miss = TP_RUN_KEYS - r[run].keys()
            assert not miss, f"{path}: {r['config']}.{run} missing {miss}"
        assert r["tp"] >= 2, f"{path}: tp discipline must shard (tp >= 2)"
    assert report.get("chaos_results"), f"{path}: no chaos_results"
    for r in report["chaos_results"]:
        missing = CHAOS_KEYS - r.keys()
        assert not missing, f"{path}: chaos {r['config']} missing {missing}"
        for run in ("reference", "chaos"):
            miss = CHAOS_RUN_KEYS - r[run].keys()
            assert not miss, f"{path}: {r['config']}.{run} missing {miss}"
        assert set(r["fired"]) == {"step_corrupt", "step_error",
                                   "device_loss"}, (
            f"{path}: chaos must plan all three device fault classes")
    assert report.get("kv_quant_results"), f"{path}: no kv_quant_results"
    for r in report["kv_quant_results"]:
        missing = KV_QUANT_KEYS - r.keys()
        assert not missing, (
            f"{path}: kv_quant {r['config']} missing {missing}")
        for run in ("bf16", "quant"):
            miss = KV_QUANT_RUN_KEYS - r[run].keys()
            assert not miss, f"{path}: {r['config']}.{run} missing {miss}"
            assert {"kv_dtype", "kv_token_bytes_stored",
                    "pool_bytes"} <= r[run]["cache"].keys(), (path, run)
        assert r["kv_dtype"] in ("int8", "fp8"), (
            f"{path}: kv_quant must exercise a sub-byte-scale pool dtype")
    # the serve-discipline registry pin: the artifact must declare every
    # registered discipline (repro/serve/disciplines.py)
    names = report.get("disciplines")
    assert names, f"{path}: no disciplines list"
    assert "tp" in names, f"{path}: registry missing the tp discipline"
    assert "chaos" in names, f"{path}: registry missing the chaos discipline"
    assert "kv_quant" in names, (
        f"{path}: registry missing the kv_quant discipline")
    print(f"{path}: ok ({SCHEMA})")


def main(argv) -> int:
    if not argv:
        print("usage: check_schema.py BENCH_serve.json [...]",
              file=sys.stderr)
        return 2
    for path in argv:
        check(path)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
