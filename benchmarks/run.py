"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived,paper_claim`` CSV.  Run with
``PYTHONPATH=src python -m benchmarks.run``.
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks.tables import ALL_TABLES

    print("name,us_per_call,derived,paper_claim")
    failures = 0
    for fn in ALL_TABLES:
        try:
            for name, us, derived, claim in fn():
                d = f"{derived:.6g}" if isinstance(derived, float) else derived
                print(f'{name},{us:.1f},{d},"{claim}"')
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f'{fn.__name__},0,ERROR,"{e}"', file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
