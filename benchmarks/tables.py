"""One benchmark per paper table/figure.  Each returns rows of
(name, us_per_call, derived-value, paper-claim) and run.py prints the CSV.

"us_per_call" times the underlying computation (model evaluation / kernel /
quantizer) on this host; the "derived" column is the reproduced quantity
that should be compared against the paper's claim.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Row = Tuple[str, float, float, str]


def _timeit(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn()
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / repeats * 1e6, out


def _real_codes(seed: int = 0, shape=(512, 256)):
    from repro.core import quant
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=shape).astype(np.float32) * 0.1)
    ql = quant.quantize_weights(w)
    return np.asarray(ql.codes)


def table1_gates() -> List[Row]:
    """Table I: gate count per MAC unit (generic INT8 vs ITA)."""
    from repro.core import costmodel, csd

    us, g = _timeit(lambda: costmodel.gate_reduction())
    rows = [
        ("table1.generic_int8_gates", us, g["generic_int8_gates"], "1180"),
        ("table1.ita_gates", us, g["ita_gates"], "243"),
        ("table1.shift_add_tree", us, g["ita_shift_add_tree"], "156"),
        ("table1.accumulator", us, g["ita_accumulator"], "68"),
        ("table1.pipeline_register", us, g["ita_pipeline_register"], "19"),
        ("table1.reduction_x", us, g["reduction_x"], "4.85"),
    ]
    codes = _real_codes()
    us2, g2 = _timeit(lambda: costmodel.gate_reduction(codes))
    rows.append(("table1.reduction_x_real_laq_weights", us2,
                 g2["reduction_x"], ">4.85 (pruning+LAQ)"))
    us3, st = _timeit(lambda: csd.adder_reduction(
        np.random.default_rng(0).integers(-127, 128, 100_000), 8))
    rows.append(("table1.csd_adder_reduction_frac_int8", us3,
                 st["adder_reduction_frac"], "0.30-0.40 (§IV-C.1)"))
    from repro.core import quant
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(1024, 512)).astype(np.float32) * 0.05)
    us4, ql = _timeit(lambda: quant.quantize_weights(w))
    rows.append(("table1.pruned_weight_frac", us4,
                 float(quant.pruned_fraction(ql)), "0.15-0.25 (§IV-C.3)"))
    return rows


def table2_energy() -> List[Row]:
    """Table II: energy per MAC operation."""
    from repro.core import costmodel

    us, e = _timeit(costmodel.energy_comparison)
    p = costmodel.system_power()
    return [
        ("table2.gpu_fp16_pj", us, e["gpu_fp16"]["total_pj"], "401.1"),
        ("table2.gpu_int8_pj", us, e["gpu_int8"]["total_pj"], "201.0"),
        ("table2.ita_pj", us, e["ita"]["total_pj"], "4.05"),
        ("table2.ita_dram_pj", us, e["ita"]["dram_pj"], "0"),
        ("table2.improvement_vs_int8_x", us, e["improvement_vs_int8"]["x"], "49.6"),
        ("table2.device_power_w", us, p["device_w"], "1.13"),
        ("table2.system_power_lo_w", us, p["system_w_lo"], "7"),
        ("table2.system_power_hi_w", us, p["system_w_hi"], "12"),
    ]


def table3_interface() -> List[Row]:
    """Table III + eq. 7-11: split-brain traffic and interface latency."""
    from repro.core.splitbrain import (HOST_ATTENTION_CPU_S, INTERFACES,
                                       TrafficModel)

    tm = TrafficModel.llama2_7b()
    us, bpt = _timeit(tm.bytes_per_token)
    rows = [
        ("table3.bytes_per_token_kib", us, bpt / 1024, "832 KB (eq. 10)"),
        ("table3.bandwidth_mb_s_at_20tok", us,
         tm.bandwidth_bytes_per_s(20) / 1e6, "16.64 (eq. 11)"),
    ]
    paper = {"pcie3x4": (5.3, 188), "tb4": (5.2, 192), "usb3": (7.9, 126),
             "usb4": (5.5, 182)}
    for key, iface in INTERFACES.items():
        r = tm.interface_latency(iface)
        rows.append((f"table3.{key}.total_ms", us, r["total_ms"],
                     str(paper[key][0])))
        rows.append((f"table3.{key}.tok_s", us, r["tokens_per_s"],
                     str(paper[key][1])))
    cpu = tm.interface_latency(INTERFACES["pcie3x4"],
                               host_attention_s=HOST_ATTENTION_CPU_S)
    rows.append(("table3.cpu_attention_tok_s", us, cpu["tokens_per_s"],
                 "10-20 (§VI-C.2)"))
    # measured-vs-analytical cross-check on the executable engine
    from repro.configs import get_config
    from repro.models import api
    from repro.serve.splitbrain_engine import SplitBrainEngine, traffic_model_for
    cfg = get_config("llama2-7b").reduced(vocab_size=128)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = SplitBrainEngine(cfg, params, max_len=8, quantize=False)
    # decode_token donates the cache buffers, so each call gets a fresh cache
    us2, _ = _timeit(lambda: eng.decode_token(eng.init_cache(1),
                                              jnp.zeros((1,), jnp.int32)),
                     repeats=1)
    eng.meter.reset()
    eng.decode_token(eng.init_cache(1), jnp.zeros((1,), jnp.int32))
    measured = eng.measured_bytes_per_token(1)["total"]
    rows.append(("table3.engine_measured_eq_model", us2,
                 float(measured == traffic_model_for(cfg).bytes_per_token()),
                 "1.0 (exact)"))
    return rows


def table4_area_cost() -> List[Row]:
    """Tables IV: die area and unit cost."""
    from repro.core import costmodel

    rows: List[Row] = []
    us, a11 = _timeit(lambda: costmodel.die_area_mm2(1.1e9))
    rows.append(("table4.tinyllama_die_mm2", us, a11["final_mm2"], "520"))
    a7 = costmodel.die_area_mm2(7e9)
    rows.append(("table4.llama7b_silicon_mm2", us, a7["final_mm2"], "3680"))
    a7c = costmodel.die_area_mm2(7e9, conservative=True)
    rows.append(("table4.llama7b_conservative_mm2", us, a7c["final_mm2"], "7885"))
    c11 = costmodel.unit_cost(1.1e9)
    rows.append(("table4.tinyllama_die_cost_usd", us, c11["silicon_cost"], "52"))
    rows.append(("table4.tinyllama_unit_usd", us, c11["unit_cost"], "64-77"))
    c7 = costmodel.unit_cost(7e9)
    rows.append(("table4.llama7b_chiplets", us, c7["n_chiplets"], "8"))
    rows.append(("table4.llama7b_unit_usd", us, c7["unit_cost"],
                 "165 (NOT reproducible; see EXPERIMENTS.md finding F1)"))
    c13 = costmodel.unit_cost(13e9)
    rows.append(("table4.llama13b_chiplets", us, c13["n_chiplets"], "15"))
    return rows


def table5_volume() -> List[Row]:
    """Table V: cost sensitivity to production volume."""
    from repro.core import costmodel

    rows: List[Row] = []
    paper = {10_000: (250, 314, 415), 100_000: (25, 89, 190),
             1_000_000: (2.5, 66, 167)}
    for vol, (nre, c11_paper, c7_paper) in paper.items():
        us, c11 = _timeit(lambda v=vol: costmodel.unit_cost(1.1e9, volume=v))
        c7 = costmodel.unit_cost(7e9, volume=vol)
        rows.append((f"table5.nre_per_unit_{vol}", us, c11["nre_per_unit"],
                     str(nre)))
        rows.append((f"table5.cost_1b_{vol}", us, c11["unit_cost_with_nre"],
                     str(c11_paper)))
        rows.append((f"table5.cost_7b_{vol}", us, c7["unit_cost_with_nre"],
                     f"{c7_paper} (chiplet-cost finding F1)"))
    return rows


def tables67_fpga() -> List[Row]:
    """Tables VI + VII: FPGA prototype resource model."""
    from repro.core import fpga

    us, n = _timeit(fpga.single_neuron_table)
    f = fpga.full_network_table()
    gap = fpga.fpga_vs_asic_gap()
    return [
        ("table7.generic_luts", us, n["generic_luts"], "1425"),
        ("table7.hardwired_luts", us, n["hardwired_luts"], "788"),
        ("table7.lut_reduction_x", us, n["lut_reduction_x"], "1.81"),
        ("table7.reg_reduction_x", us, n["reg_reduction_x"], "20.8"),
        ("table6.baseline_luts", us, f["baseline_luts"], "11309"),
        ("table6.hardwired_luts", us, f["hardwired_luts"], "170502"),
        ("table6.over_capacity_x", us, f["hardwired_over_capacity_x"], "3.2"),
        ("table67.fpga_vs_asic_gap_x", us, gap["gap_x"], "~2.7 (4.85/1.81)"),
    ]


def fig3_security() -> List[Row]:
    """Fig. 3: economic barrier to model extraction."""
    from repro.core import costmodel

    us, b = _timeit(costmodel.extraction_barrier)
    return [
        ("fig3.software_dump_usd", us, b["software_dump_usd"], "~2000"),
        ("fig3.ita_physical_re_usd", us, b["ita_physical_re_usd"], "50000+"),
        ("fig3.barrier_increase_x", us, b["barrier_increase_x"], "25x"),
    ]


def kernel_bench() -> List[Row]:
    """Microbenchmarks of the three Pallas kernels vs their oracles (CPU
    interpret mode — correctness + relative cost only, not TPU perf)."""
    from repro.kernels import ref
    from repro.kernels.w4a8_matmul import w4a8_matmul

    rng = np.random.default_rng(0)
    M = K = N = 256
    qx = jnp.asarray(rng.integers(-127, 128, (M, K)).astype(np.int8))
    xs = jnp.asarray(rng.uniform(0.01, 0.1, (M, 1)).astype(np.float32))
    codes = jnp.asarray(rng.integers(-7, 8, (K, N)).astype(np.int8))
    ws = jnp.asarray(rng.uniform(0.01, 0.1, (N,)).astype(np.float32))
    us_ref, want = _timeit(
        lambda: jax.block_until_ready(ref.w4a8_matmul(qx, xs, codes, ws)))
    us_pal, got = _timeit(
        lambda: jax.block_until_ready(w4a8_matmul(qx, xs, codes, ws,
                                                  bm=128, bn=128, bk=128)))
    err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    return [
        ("kernels.w4a8_ref_us", us_ref, 0.0, "-"),
        ("kernels.w4a8_pallas_interpret_us", us_pal, err, "max|err| ~0"),
    ]




def serve_disciplines() -> List[Row]:
    """Serve-discipline registry (repro/serve/disciplines.py): one row per
    registered discipline so the CSV report enumerates exactly what
    serve_bench gates.  The derived value counts registered disciplines
    (cross-checked against the BENCH_serve.json `disciplines` list by
    check_schema.py); the claim column carries each headline gate."""
    from repro.serve.disciplines import DISCIPLINES, markdown_table

    us, _ = _timeit(markdown_table)
    rows: List[Row] = [
        (f"serve.discipline.{d.name}", us, float(i + 1), d.gate)
        for i, d in enumerate(DISCIPLINES)
    ]
    rows.append(("serve.disciplines_registered", us, float(len(DISCIPLINES)),
                 "9 (serve_bench/v8)"))
    return rows


def ablation_laq_slack() -> List[Row]:
    """Beyond-paper ablation: the LAQ error-vs-adders trade-off.

    The paper asserts logic-aware rounding is 'compatible' with quantization
    (§III-E) but never quantifies the knob.  Sweep the slack budget and report
    (quant RMSE in units of scale, mean CSD adders per weight, Table-I gate
    reduction): the default slack=0.35 buys 33% fewer adders for +12% RMSE
    (monotone trade-off, 46% fewer at slack=0.5).
    """
    from repro.core import costmodel, csd, quant

    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(1024, 512)).astype(np.float32) * 0.08)
    rows: List[Row] = []
    table = csd.csd_cost_table(4)
    for slack in (0.0, 0.15, 0.35, 0.5):
        us, ql = _timeit(lambda s=slack: quant.quantize_weights(
            w, laq_slack=s, logic_aware=s > 0))
        deq = quant.dequantize(ql, jnp.float32)
        scale = np.asarray(ql.scales)[None, :]
        rmse = float(np.sqrt(np.mean((np.asarray(deq) - np.asarray(w)) ** 2
                                     / scale ** 2)))
        codes = np.asarray(ql.codes).astype(np.int64)
        adders = float(np.maximum(0, table[codes + 8] - 1).mean())
        gates = costmodel.gate_reduction(codes)["reduction_x"]
        rows.append((f"ablation.laq.slack_{slack}.rmse_scale", us, rmse, "-"))
        rows.append((f"ablation.laq.slack_{slack}.adders_per_w", us, adders, "-"))
        rows.append((f"ablation.laq.slack_{slack}.gate_reduction_x", us, gates,
                     ">4.85 grows with slack"))
    return rows


ALL_TABLES = [table1_gates, table2_energy, table3_interface, table4_area_cost,
              table5_volume, tables67_fpga, fig3_security, kernel_bench,
              serve_disciplines, ablation_laq_slack]
