"""Continuous-batching serve benchmark: slot scheduler vs sequential fused.

Replays the same Poisson-arrival request trace through two serving
disciplines on one ServeEngine:

  sequential — the PR-1 baseline: requests served one at a time, each as a
               fused prefill + one-dispatch decode loop (fast per request,
               but concurrent arrivals queue behind the running one),
  continuous — serve/scheduler.py: slot-based KV cache, bucketed B=1
               prefill admits requests mid-flight, ONE persistent masked
               batched decode step advances every active stream per
               dispatch.

Measures tokens/s, requests/s and mean per-request latency for both, and
asserts the two structural invariants of the steady state:

  * zero recompiles after warmup — counted with the XLA backend-compile
    monitoring listener (serve/slots.py::CompileCounter), not assumed,
  * interface-traffic exactness — measured meter bytes over the whole
    continuous run == (sum over requests of T0-1+gen) * the analytical
    eq. 7-10 bytes/token.

Emits BENCH_serve.json so future PRs have a throughput trajectory:

  PYTHONPATH=src python benchmarks/serve_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, List

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve import slots
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import ContinuousBatchingScheduler, Request
from repro.serve.splitbrain_engine import traffic_model_for


def _workload(cfg, n_requests: int, max_new: int, mean_gap_s: float,
              seed: int = 0) -> List[Request]:
    """Poisson arrivals, prompt lengths uniform in [2, 16]."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mean_gap_s, n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]
    return [
        Request(uid=i,
                prompt=rng.integers(1, cfg.vocab_size,
                                    (int(rng.integers(2, 17)),)
                                    ).astype(np.int32),
                max_new=max_new,
                arrival_s=float(arrivals[i]))
        for i in range(n_requests)
    ]


def _run_sequential(eng: ServeEngine, reqs: List[Request]) -> Dict[str, Any]:
    """One at a time, in arrival order, each request fully fused."""
    t_start = time.perf_counter()
    latency, decoded = [], 0
    for r in sorted(reqs, key=lambda r: (r.arrival_s, r.uid)):
        now = time.perf_counter() - t_start
        if now < r.arrival_s:
            time.sleep(r.arrival_s - now)
            now = r.arrival_s
        out = eng.generate(r.prompt[None, :], max_new=r.max_new)
        decoded += int(out["gen_len"].sum())
        latency.append(time.perf_counter() - t_start - r.arrival_s)
    wall = time.perf_counter() - t_start
    return {"wall_s": wall, "decoded_tokens": decoded,
            "tokens_per_s": decoded / wall,
            "requests_per_s": len(reqs) / wall,
            "mean_latency_s": float(np.mean(latency))}


def _run_continuous(eng: ServeEngine, reqs: List[Request],
                    max_slots: int) -> Dict[str, Any]:
    sched = ContinuousBatchingScheduler(eng, max_slots=max_slots)
    out = sched.run(list(reqs), realtime=True)
    lat = [res.finished_s - req.arrival_s
           for res, req in zip(out["results"],
                               sorted(reqs, key=lambda r: r.uid))]
    return {"wall_s": out["wall_s"],
            "decoded_tokens": out["decoded_tokens"],
            "tokens_per_s": out["tokens_per_s"],
            "requests_per_s": out["requests_per_s"],
            "mean_latency_s": float(np.mean(lat)),
            "steps": out["steps"]}


def bench_arch(arch: str, n_requests: int, max_new: int, max_slots: int,
               mean_gap_s: float, overrides: Dict[str, Any]) -> Dict[str, Any]:
    cfg = get_config(arch).reduced(**overrides)
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_len=16 + max_new + 1)
    reqs = _workload(cfg, n_requests, max_new, mean_gap_s)

    # warm every bucket both disciplines touch (compiles excluded from timing)
    warm = [Request(uid=-1 - i, prompt=r.prompt, max_new=r.max_new)
            for i, r in enumerate(reqs)]
    _run_sequential(eng, [dataclasses.replace(w, arrival_s=0.0) for w in warm])
    ContinuousBatchingScheduler(eng, max_slots=max_slots).run(
        [dataclasses.replace(w, arrival_s=0.0) for w in warm])

    counter = slots.CompileCounter.instance()
    seq = _run_sequential(eng, reqs)
    c0 = counter.count
    eng.meter.reset()
    cont = _run_continuous(eng, reqs, max_slots)
    steady_recompiles = counter.count - c0

    n_tok = sum(len(r.prompt) - 1 + r.max_new for r in reqs)
    analytic = n_tok * traffic_model_for(cfg).bytes_per_token()
    measured = eng.measured_bytes()["total"]

    return {
        "config": cfg.name,
        "n_requests": n_requests,
        "max_new": max_new,
        "max_slots": max_slots,
        "mean_gap_s": mean_gap_s,
        "sequential": seq,
        "continuous": cont,
        "requests_per_s_speedup": cont["requests_per_s"] / seq["requests_per_s"],
        "tokens_per_s_speedup": cont["tokens_per_s"] / seq["tokens_per_s"],
        "steady_state_recompiles": steady_recompiles,
        "compile_counter_available": counter.available,
        "traffic_measured_bytes": measured,
        "traffic_analytical_bytes": analytic,
        "traffic_exact": measured == analytic,
        "jit_caches": eng.jit_cache_sizes(),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small workload, >=1x gate (CI smoke)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--mean-gap-ms", type=float, default=2.0,
                    help="mean Poisson inter-arrival gap (saturating default)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    n_requests = args.requests or (8 if args.quick else 32)
    max_new = args.max_new or (8 if args.quick else 32)
    # d_model=128 keeps the reduced model decode GEMV-bound enough that
    # batching the slots is a real win, CPU or not
    overrides = dict(vocab_size=256, d_model=128, d_ff=384)
    archs = ["llama2-7b"] if args.quick else ["llama2-7b", "rwkv6-7b"]

    results = [bench_arch(a, n_requests, max_new, args.slots,
                          args.mean_gap_ms / 1e3, overrides) for a in archs]

    gate = 1.0 if args.quick else 2.0
    summary = {
        r["config"]: {
            "requests_per_s_speedup": round(r["requests_per_s_speedup"], 2),
            "tokens_per_s_speedup": round(r["tokens_per_s_speedup"], 2),
            "zero_steady_state_recompiles": r["steady_state_recompiles"] == 0,
            "traffic_exact": r["traffic_exact"],
        } for r in results
    }
    report = {
        "schema": "serve_bench/v1",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "quick": args.quick,
        "gate_requests_per_s_speedup": gate,
        "results": results,
        "summary": summary,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(json.dumps(summary, indent=2))
    print(f"wrote {args.out}")

    ok = all(r["requests_per_s_speedup"] >= gate
             and r["steady_state_recompiles"] == 0
             and r["traffic_exact"] for r in results)
    if not ok:
        print(f"FAIL: continuous < {gate}x sequential requests/s, steady-state"
              " recompile, or traffic mismatch", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
