"""Continuous-batching serve benchmark: paged (in-place vs gather) vs dense
slot cache, vs the sequential-fused baseline.

Replays the same Poisson-arrival request trace (ragged prompt lengths AND
ragged ``max_new``) through four serving disciplines:

  sequential   — the PR-1 baseline: requests served one at a time, each as
                 a fused prefill + one-dispatch decode loop (fast per
                 request, but concurrent arrivals queue behind the running
                 one),
  continuous   — serve/scheduler.py over the DENSE slot cache: every slot
                 pins max_len positions whether the request uses them or
                 not,
  paged_gather — the scheduler over the paged slot cache with the PR-3
                 reference decode discipline: gather the dense view
                 through the page table, run the family decode step,
                 scatter one token back — an O(max_slots x max_len)
                 dense-view TRANSIENT per step,
  paged        — the same pool with the gather-free in-place discipline
                 (DESIGN.md §6): attention walks pool[table] page-block-
                 wise, ZERO transient bytes, O(live tokens) KV reads.

A fifth, separately-traced discipline exercises shared-prefix KV reuse
(DESIGN.md §7): a shared-system-prompt workload (>= 50% prompt overlap)
replayed through the paged scheduler with ``prefix_cache`` off vs on.
Gates: per-request token identity, prefill tokens/s uplift >= 1.3x (the
cache maps the shared pages and computes only the unmatched tails),
reduced KV pages stored (cumulative pool draws — the shared prefix is
stored once, not per request; the instantaneous peak is reported but not
gated because the cache also unthrottles admission and so legitimately
raises concurrency), zero steady-state recompiles, and eq. 7-10 traffic
exactness under the cached-token accounting.

A sixth discipline measures the ONLINE serving semantics (DESIGN.md §8)
under overload: a priority-split Poisson trace (~25% high-priority) is
served unloaded (arrivals well under the measured service rate), then at
2x overload with SLA-aware preemption on, and per-priority TTFT
percentiles are compared.  Gates: high-priority p95 TTFT under 2x overload
stays within 1.5x of its unloaded value (preemption evicts low-priority
victims, publishing their full pages first so resume is near-free); a
cancelled mid-decode request returns its pages within ONE scheduler
iteration (asserted with a live probe); zero steady-state recompiles and
meter-exact traffic with preemption ON (every token that crossed — prefill,
decode, re-prefill after eviction — at exactly eq. 7-10 bytes).

A seventh discipline benches tensor-parallel serving (DESIGN.md §11): the
same persistent masked decode step over a forced-host-device ``(1, tp)``
mesh, in fresh subprocesses (the device count is a process-level XLA
flag).  Gates: tp=2 greedy tokens IDENTICAL to tp=1, byte-exact traffic on
both (the per-shard entries sum to the single-device analytical model),
zero steady-state recompiles, the pool actually cut on KV heads
(kv_shards == tp), and — on hosts with >= 2 cores — decode tokens/s at
tp=2 >= the gate x tp=1 (a 1-core host can't parallelize anything, so
only the structural gates apply there).

An eighth discipline gates crash tolerance (DESIGN.md §12): the same
shared-prefix trace is replayed through the paged + prefix scheduler with
a seeded fault plan combining per-slot NaN logit corruption, a raised
decode step and wholesale device loss.  Gates: every request still
reaches DONE token-identical to the uninterrupted run (the device is
stateless — recovery replays from the host-authoritative copy), each
fault class actually fired, the page pool returns to baseline, recovery
completes under a wall-clock bound, and a SECOND identical chaos cycle
compiles nothing (device loss kills buffers, not compiled programs).

A ninth discipline gates the quantized KV page pool (DESIGN.md §13): the
same ragged trace is served from a bf16 pool vs an int8 pool of identical
page geometry (1-byte codes + per-page, per-kv-head f32 scales beside the
page table; quantize-on-write, dequant fused into the decode kernel's page
fetch).  Gates: >= 1.8x resident tokens at fixed pool bytes (the per-token
STORAGE figure from cache_stats, timing-free), bounded greedy-token
divergence vs the bf16 run (quantization legitimately flips near-tie
argmaxes; it must stay a small fraction), eq. 7-10 traffic byte-IDENTICAL
to the bf16 run (quantization changes host-local storage, never boundary
bytes), the host KV-read channel shrunk by >= 1.5x, and zero steady-state
recompiles.

The discipline list itself is pinned to the serve-discipline registry
(repro/serve/disciplines.py): a report that misses a registered
discipline FAILS, so the bench, the README table, and benchmarks/tables.py
cannot silently drift apart.

Measures tokens/s, requests/s (wall AND busy — arrival sleeps are reported
separately so idle-heavy traces can't inflate apparent efficiency), mean
per-request latency, the paged-memory claim (peak resident KV bytes of the
PERSISTENT cache state vs the dense slot cache, gated >= 2x on the ragged
workload), and the per-step copy the in-place kernel eliminates:
``gather_transient_bytes_per_step`` (gated == 0 for paged in-place) plus
the metered host KV-read bytes per discipline (live pages only on the
in-place path).  Also asserts the structural invariants:

  * zero recompiles after warmup for ALL slot-cache disciplines — counted
    with the XLA backend-compile listener (serve/slots.py::CompileCounter),
  * interface-traffic exactness — measured meter bytes over each continuous
    run == (sum over requests of T0-1+gen) * the analytical eq. 7-10
    bytes/token, for the dense AND both paged disciplines,
  * paged in-place throughput >= paged gather (the copy was pure waste),
    and paged within 10% of the dense scheduler.

Emits BENCH_serve.json so future PRs have a throughput trajectory:

  PYTHONPATH=src python benchmarks/serve_bench.py [--quick] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro.configs import get_config
from repro.models import api
from repro.serve import pages
from repro.serve import slots
from repro.serve.disciplines import NAMES as DISCIPLINE_NAMES
from repro.serve.engine import ServeEngine
from repro.serve.faults import FaultInjector, FaultPlan
from repro.serve.scheduler import ContinuousBatchingScheduler, Request
from repro.serve.splitbrain_engine import traffic_model_for


def _workload(cfg, n_requests: int, max_new: int, mean_gap_s: float,
              seed: int = 0) -> List[Request]:
    """Poisson arrivals; prompt lengths uniform in [2, 16] and max_new
    uniform in [min(4, max_new), max_new] — the raggedness the paged pool
    exploits."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mean_gap_s, n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]
    lo = min(4, max_new)
    return [
        Request(uid=i,
                prompt=rng.integers(1, cfg.vocab_size,
                                    (int(rng.integers(2, 17)),)
                                    ).astype(np.int32),
                max_new=int(rng.integers(lo, max_new + 1)),
                arrival_s=float(arrivals[i]))
        for i in range(n_requests)
    ]


def _run_sequential(eng: ServeEngine, reqs: List[Request]) -> Dict[str, Any]:
    """One at a time, in arrival order, each request fully fused."""
    t_start = time.perf_counter()
    latency, decoded = [], 0
    for r in sorted(reqs, key=lambda r: (r.arrival_s, r.uid)):
        now = time.perf_counter() - t_start
        if now < r.arrival_s:
            time.sleep(r.arrival_s - now)
            now = r.arrival_s
        out = eng.generate(r.prompt[None, :], max_new=r.max_new)
        decoded += int(out["gen_len"].sum())
        latency.append(time.perf_counter() - t_start - r.arrival_s)
    wall = time.perf_counter() - t_start
    return {"wall_s": wall, "decoded_tokens": decoded,
            "tokens_per_s": decoded / wall,
            "requests_per_s": len(reqs) / wall,
            "mean_latency_s": float(np.mean(latency))}


def _pctiles(xs: List[float]) -> Dict[str, float]:
    """p50/p95 summary of a per-request latency series (serve_bench/v4)."""
    if not xs:
        return {"p50": 0.0, "p95": 0.0}
    return {"p50": float(np.percentile(xs, 50)),
            "p95": float(np.percentile(xs, 95))}


def _run_continuous(eng: ServeEngine, reqs: List[Request], max_slots: int,
                    prefill_chunk: Optional[int] = None) -> Dict[str, Any]:
    sched = ContinuousBatchingScheduler(eng, max_slots=max_slots,
                                        prefill_chunk=prefill_chunk)
    # the host meter carries heterogeneous channels (prefix savings, CoW
    # copies): count ONLY the decode KV-read channel, or a prefix run
    # would book its SAVED prefill bytes as extra reads
    kv0 = eng.meter.host_channel_bytes("kv_cache_read")
    out = sched.run(list(reqs), realtime=True)
    assert not out["rejected"], out["rejected"]
    lat = [res.finished_s - req.arrival_s
           for res, req in zip(out["results"],
                               sorted(reqs, key=lambda r: r.uid))]
    return {"wall_s": out["wall_s"],
            "busy_s": out["busy_s"],
            "decoded_tokens": out["decoded_tokens"],
            "prefill_tokens": out["prefill_tokens"],
            "cached_prompt_tokens": out["cached_prompt_tokens"],
            "tokens_per_s": out["tokens_per_s"],
            "tokens_per_s_busy": out["tokens_per_s_busy"],
            "requests_per_s": out["requests_per_s"],
            "requests_per_s_busy": out["requests_per_s_busy"],
            "mean_latency_s": float(np.mean(lat)),
            "latency_s": _pctiles(lat),
            "ttft_s": _pctiles([r.ttft_s for r in out["results"]]),
            "queue_wait_s": _pctiles([r.queue_wait_s
                                      for r in out["results"]]),
            "steps": out["steps"],
            # the per-step dense-view copy the in-place kernel eliminates,
            # and the discipline's modeled host KV reads over the run
            # (replayed accounting — kv_read_bytes_step — not a hw counter)
            "gather_transient_bytes_per_step":
                eng.gather_transient_bytes_per_step(),
            "kv_read_bytes":
                eng.meter.host_channel_bytes("kv_cache_read") - kv0,
            "cache": eng.cache_stats(sched.cache),
            "results": out["results"]}


def _check_traffic(eng: ServeEngine, reqs: List[Request], cfg,
                   cached_tokens: int = 0) -> Dict[str, Any]:
    """eq. 7-10 exactness: measured boundary bytes == analytical bytes per
    ACTIVE token.  Prefix-cached prompt tokens never cross the boundary
    (their K/V is shared, not recomputed), so they subtract from the
    analytical count — the same rule the scheduler's meter replay uses."""
    n_tok = sum(len(r.prompt) - 1 + r.max_new for r in reqs) - cached_tokens
    analytic = n_tok * traffic_model_for(cfg).bytes_per_token()
    measured = eng.measured_bytes()["total"]
    return {"measured": measured, "analytical": analytic,
            "cached_tokens": cached_tokens,
            "exact": measured == analytic}


def bench_arch(arch: str, n_requests: int, max_new: int, max_slots: int,
               mean_gap_s: float, overrides: Dict[str, Any],
               page_size: int = 8, prefill_chunk: int = 8,
               repeats: int = 1) -> Dict[str, Any]:
    cfg = get_config(arch).reduced(**overrides)
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    # room for the longest request, rounded so pages AND prefill chunks
    # both tile the cache exactly
    max_len = pages.round_len(16 - 1 + max_new, page_size, prefill_chunk)
    slot_pages = max_len // page_size
    # pool sized at HALF the dense token capacity (raggedness means most
    # slots never approach max_len), floored so one worst-case request
    # always fits even with --slots 1
    num_pages = max(max_slots * slot_pages // 2, slot_pages) + 1
    dense = ServeEngine(cfg, params, max_len=max_len)
    gather = ServeEngine(cfg, params, max_len=max_len, page_size=page_size,
                         num_pages=num_pages, paged_attn="gather")
    paged = ServeEngine(cfg, params, max_len=max_len, page_size=page_size,
                        num_pages=num_pages)          # in-place (default)
    reqs = _workload(cfg, n_requests, max_new, mean_gap_s)

    # a family with no sequence-scaling leaves (rwkv) demotes BOTH paged
    # engines to the identical dense fallback: measuring "gather" there
    # would just re-time the same discipline and publish a noise ratio
    will_page = paged.will_page()

    # warm every bucket all disciplines touch (compiles excluded from timing)
    warm = [dataclasses.replace(r, uid=-1 - i, arrival_s=0.0)
            for i, r in enumerate(reqs)]
    _run_sequential(dense, warm)
    _run_continuous(dense, warm, max_slots)
    if will_page:
        _run_continuous(gather, warm, max_slots, prefill_chunk)
    _run_continuous(paged, warm, max_slots, prefill_chunk)

    # each discipline is measured ``repeats`` times and the best steady-state
    # run is reported (sub-second walls make single runs noisy on a shared
    # machine); the structural invariants — zero recompiles, byte-exact
    # traffic — must hold on EVERY repeat, not just the best one.
    counter = slots.CompileCounter.instance()
    seq = max((_run_sequential(dense, reqs) for _ in range(repeats)),
              key=lambda r: r["requests_per_s"])

    def measure(eng, chunk):
        best, recompiles, traffic = None, 0, None
        for _ in range(repeats):
            c0 = counter.count
            eng.meter.reset()
            r = _run_continuous(eng, reqs, max_slots, chunk)
            r.pop("results")
            recompiles += counter.count - c0
            traffic = _check_traffic(eng, reqs, cfg)
            assert traffic["exact"], traffic
            if best is None or r["requests_per_s"] > best["requests_per_s"]:
                best = r
        return best, recompiles, traffic

    cont, dense_recompiles, dense_traffic = measure(dense, None)
    if will_page:
        gat, gather_recompiles, gather_traffic = measure(gather,
                                                         prefill_chunk)
    else:
        gat, gather_recompiles, gather_traffic = None, 0, {"exact": True}
    pag, paged_recompiles, paged_traffic = measure(paged, prefill_chunk)

    # structural gates on the eliminated copy (checked via the FAIL/exit-1
    # path in main(), not asserts, so a regression still writes the
    # artifact): the in-place discipline must have NO dense-view transient
    # and must read fewer host KV bytes than gather on a ragged workload
    transient_ok = (pag["gather_transient_bytes_per_step"] == 0
                    and (not will_page
                         or gat["gather_transient_bytes_per_step"] > 0))
    reads_ok = (not will_page
                or pag["kv_read_bytes"] < gat["kv_read_bytes"])

    dense_bytes = cont["cache"]["cache_bytes"]
    paged_peak = pag["cache"]["peak_kv_bytes_in_use"]
    return {
        "config": cfg.name,
        "n_requests": n_requests,
        "max_new": max_new,
        "max_slots": max_slots,
        "max_len": max_len,
        "mean_gap_s": mean_gap_s,
        "page_size": page_size,
        "num_pages": num_pages,
        "prefill_chunk": prefill_chunk,
        "sequential": seq,
        "continuous": cont,
        "paged_gather": gat,
        "paged": pag,
        "requests_per_s_speedup": cont["requests_per_s"] / seq["requests_per_s"],
        "tokens_per_s_speedup": cont["tokens_per_s"] / seq["tokens_per_s"],
        "paged_vs_dense_requests_per_s":
            pag["requests_per_s"] / cont["requests_per_s"],
        "paged_inplace_vs_gather_tokens_per_s":
            (pag["tokens_per_s_busy"] / gat["tokens_per_s_busy"]
             if will_page else None),
        "paged_transient_eliminated": transient_ok,
        "paged_inplace_reads_less": reads_ok,
        "dense_cache_bytes": dense_bytes,
        "paged_pool_bytes": pag["cache"]["cache_bytes"],
        "paged_peak_bytes_in_use": paged_peak,
        "paged_memory_saving": dense_bytes / paged_peak,
        "steady_state_recompiles": dense_recompiles,
        "paged_steady_state_recompiles": paged_recompiles,
        "gather_steady_state_recompiles": gather_recompiles,
        "compile_counter_available": counter.available,
        "traffic_dense": dense_traffic,
        "traffic_paged": paged_traffic,
        "traffic_exact": (dense_traffic["exact"] and paged_traffic["exact"]
                          and gather_traffic["exact"]),
        "jit_caches": {"dense": dense.jit_cache_sizes(),
                       "paged": paged.jit_cache_sizes()},
    }


def _prefix_workload(cfg, n_requests: int, max_new: int, mean_gap_s: float,
                     prefix_len: int, tail_max: int,
                     seed: int = 0) -> List[Request]:
    """Shared-system-prompt traffic: every request opens with the SAME
    ``prefix_len``-token prompt and diverges into a short unique tail —
    the workload shape that dominates production serving (system prompts,
    few-shot templates) and that the prefix cache exists for.  With
    ``tail_max <= prefix_len`` the pairwise prompt overlap is >= 50%."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(1, cfg.vocab_size, (prefix_len,)).astype(np.int32)
    gaps = rng.exponential(mean_gap_s, n_requests)
    arrivals = np.cumsum(gaps) - gaps[0]
    return [
        Request(uid=i,
                prompt=np.concatenate(
                    [shared,
                     rng.integers(1, cfg.vocab_size,
                                  (int(rng.integers(1, tail_max + 1)),)
                                  ).astype(np.int32)]),
                max_new=max_new,
                arrival_s=float(arrivals[i]))
        for i in range(n_requests)
    ]


def bench_prefix(arch: str, n_requests: int, max_slots: int,
                 mean_gap_s: float, overrides: Dict[str, Any],
                 page_size: int = 8, prefill_chunk: int = 8,
                 prefix_len: int = 32, tail_max: int = 8,
                 max_new: int = 4, repeats: int = 1) -> Dict[str, Any]:
    """The shared-prefix serve discipline: the SAME shared-system-prompt
    trace through the paged scheduler with the prefix cache off vs on.

    Gates (via main()'s FAIL path): token identity on == off per request,
    prefill tokens/s uplift >= the gate at >= 50% prompt overlap, reduced
    peak resident KV pages, zero steady-state recompiles either way, and
    eq. 7-10 traffic exactness under the cached-token accounting."""
    cfg = get_config(arch).reduced(**overrides)
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    max_len = pages.round_len(prefix_len + tail_max + max_new,
                              page_size, prefill_chunk)
    slot_pages = max_len // page_size
    num_pages = max_slots * slot_pages + 1     # roomy: sharing is the story
    engines = {
        "off": ServeEngine(cfg, params, max_len=max_len, page_size=page_size,
                           num_pages=num_pages, prefix_cache="off"),
        "on": ServeEngine(cfg, params, max_len=max_len, page_size=page_size,
                          num_pages=num_pages, prefix_cache="on"),
    }
    reqs = _prefix_workload(cfg, n_requests, max_new, mean_gap_s,
                            prefix_len, tail_max)
    body_tokens = sum(len(r.prompt) - 1 for r in reqs)
    overlap = prefix_len * n_requests / sum(len(r.prompt) for r in reqs)

    warm = [dataclasses.replace(r, uid=-1 - i, arrival_s=0.0)
            for i, r in enumerate(reqs)]
    for eng in engines.values():
        ContinuousBatchingScheduler(eng, max_slots=max_slots,
                                    prefill_chunk=prefill_chunk).warmup()
        _run_continuous(eng, warm, max_slots, prefill_chunk)

    counter = slots.CompileCounter.instance()
    out: Dict[str, Any] = {}
    tokens_by_uid: Dict[str, Any] = {}
    for name, eng in engines.items():
        best, recompiles, traffic = None, 0, None
        for _ in range(repeats):
            c0 = counter.count
            eng.meter.reset()
            r = _run_continuous(eng, reqs, max_slots, prefill_chunk)
            results = r.pop("results")
            recompiles += counter.count - c0
            traffic = _check_traffic(eng, reqs, cfg,
                                     cached_tokens=r["cached_prompt_tokens"])
            assert traffic["exact"], traffic
            # prefill throughput: submitted prompt tokens per busy second —
            # the cache serves the same prompts while COMPUTING only the
            # unmatched tails, so the uplift shows up here
            r["prefill_tokens_per_s_busy"] = body_tokens / r["busy_s"]
            if best is None or (r["prefill_tokens_per_s_busy"]
                                > best["prefill_tokens_per_s_busy"]):
                best = r
            tokens_by_uid[name] = {res.uid: res.tokens for res in results}
        best["steady_state_recompiles"] = recompiles
        best["traffic"] = traffic
        out[name] = best

    token_identical = all(
        np.array_equal(tokens_by_uid["on"][uid], toks)
        for uid, toks in tokens_by_uid["off"].items())
    on, off = out["on"], out["off"]
    return {
        "config": cfg.name,
        "n_requests": n_requests,
        "max_slots": max_slots,
        "max_len": max_len,
        "page_size": page_size,
        "num_pages": num_pages,
        "prefill_chunk": prefill_chunk,
        "prefix_len": prefix_len,
        "tail_max": tail_max,
        "max_new": max_new,
        "prefix_overlap": overlap,
        "submitted_prefill_tokens": body_tokens,
        "off": off,
        "on": on,
        "token_identical": token_identical,
        "cached_prompt_tokens": on["cached_prompt_tokens"],
        "prefill_tokens_per_s_uplift":
            on["prefill_tokens_per_s_busy"] / off["prefill_tokens_per_s_busy"],
        # the resident-KV claim, measured timing-free: cumulative pages
        # DRAWN over the run — the shared prefix is stored once instead of
        # per request.  (peak_pages_in_use is reported per side in "cache"
        # but not gated: the cache also RAISES achievable concurrency by
        # unthrottling admission, which legitimately lifts the
        # instantaneous peak while every request's own footprint shrinks.)
        "kv_pages_stored_reduction":
            off["cache"]["pages_allocated"]
            / max(on["cache"]["pages_allocated"], 1),
        "zero_steady_state_recompiles":
            on["steady_state_recompiles"] == 0
            and off["steady_state_recompiles"] == 0,
        "traffic_exact": (on["traffic"]["exact"] and off["traffic"]["exact"]),
    }


def _priority_workload(cfg, n_requests: int, max_new: int, mean_gap_s: float,
                       high_frac: float = 0.25,
                       seed: int = 0) -> List[Request]:
    """The ragged Poisson trace with an SLA split: every 1/high_frac-th
    request is priority 1 (interactive tier), the rest priority 0 (batch
    tier) — the mix the overload discipline protects."""
    reqs = _workload(cfg, n_requests, max_new, mean_gap_s, seed=seed)
    period = max(int(round(1.0 / high_frac)), 1)
    return [dataclasses.replace(r, priority=1 if i % period == 0 else 0)
            for i, r in enumerate(reqs)]


def _run_online(eng: ServeEngine, reqs: List[Request], max_slots: int,
                prefill_chunk: Optional[int],
                preemption: bool) -> Dict[str, Any]:
    """One realtime pass with the online scheduler; returns per-priority
    TTFT/latency percentiles plus the loop counters."""
    sched = ContinuousBatchingScheduler(eng, max_slots=max_slots,
                                        prefill_chunk=prefill_chunk,
                                        preemption=preemption)
    out = sched.run(list(reqs), realtime=True)
    assert not out["rejected"], out["rejected"]
    prio = {r.uid: r.priority for r in reqs}
    ttft_by: Dict[int, List[float]] = {}
    lat_by: Dict[int, List[float]] = {}
    arrival = {r.uid: r.arrival_s for r in reqs}
    for res in out["results"]:
        ttft_by.setdefault(prio[res.uid], []).append(res.ttft_s)
        lat_by.setdefault(prio[res.uid], []).append(
            res.finished_s - arrival[res.uid])
    return {"wall_s": out["wall_s"],
            "busy_s": out["busy_s"],
            "decoded_tokens": out["decoded_tokens"],
            "prefill_tokens": out["prefill_tokens"],
            "cached_prompt_tokens": out["cached_prompt_tokens"],
            "preemptions": out["preemptions"],
            "by_state": out["by_state"],
            "tokens_per_s": out["tokens_per_s"],
            "requests_per_s": out["requests_per_s"],
            "ttft_s_by_priority": {str(p): _pctiles(v)
                                   for p, v in sorted(ttft_by.items())},
            "latency_s_by_priority": {str(p): _pctiles(v)
                                      for p, v in sorted(lat_by.items())}}


def _cancel_probe(eng: ServeEngine, cfg, prefill_chunk: int) -> bool:
    """Live assertion of the cancellation SLO: drive a mid-decode request
    through the open-loop api, cancel it, and check the pool occupancy is
    back to baseline after ONE ``step()``."""
    rng = np.random.default_rng(11)
    sched = ContinuousBatchingScheduler(eng, max_slots=2,
                                        prefill_chunk=prefill_chunk)
    sched.begin()
    base = eng.cache_stats(sched.cache).get("pages_in_use", 0)
    prompt = rng.integers(1, cfg.vocab_size, (12,)).astype(np.int32)
    sched.submit(Request(uid=0, prompt=prompt, max_new=eng.max_len - 12))
    for _ in range(16):
        sched.step()
        if sched.decoding_uids():
            break
    mid = eng.cache_stats(sched.cache).get("pages_in_use", 0)
    sched.cancel(0)
    fin = sched.step()           # ONE iteration
    after = eng.cache_stats(sched.cache).get("pages_in_use", 0)
    sched.poll()                 # flush the meter replay
    return (len(fin) == 1 and fin[0].state == "CANCELLED"
            and mid > base and after == base)


def bench_overload(arch: str, n_requests: int, max_slots: int,
                   overrides: Dict[str, Any], page_size: int = 8,
                   prefill_chunk: int = 8, max_new: int = 16,
                   high_frac: float = 0.25) -> Dict[str, Any]:
    """The online-serving discipline: priority-split traffic unloaded vs at
    2x overload with SLA-aware preemption, plus the cancellation probe.

    Gates (via main()'s FAIL path): high-priority p95 TTFT under overload
    <= 1.5x its unloaded value, cancelled pages returned within one
    iteration, zero steady-state recompiles, meter-exact traffic with
    preemption ON."""
    cfg = get_config(arch).reduced(**overrides)
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    max_len = pages.round_len(16 - 1 + max_new, page_size, prefill_chunk)
    slot_pages = max_len // page_size
    # tight pool (half the dense capacity): overload pressure must be real
    num_pages = max(max_slots * slot_pages // 2, slot_pages) + 1
    eng = ServeEngine(cfg, params, max_len=max_len, page_size=page_size,
                      num_pages=num_pages, prefix_cache="on")
    bpt = traffic_model_for(cfg).bytes_per_token()

    # calibrate the service rate with a saturated closed run (also the warm
    # pass: compiles every steady-state program, including the preemption
    # paths — publish, seed, re-prefill — via the prefix-armed warmup)
    ContinuousBatchingScheduler(eng, max_slots=max_slots,
                                prefill_chunk=prefill_chunk).warmup()
    warm_reqs = _priority_workload(cfg, n_requests, max_new, 0.0,
                                   high_frac, seed=1)
    warm_reqs = [dataclasses.replace(r, uid=-1 - i, arrival_s=0.0)
                 for i, r in enumerate(warm_reqs)]
    warm = _run_online(eng, warm_reqs, max_slots, prefill_chunk,
                       preemption=True)
    svc = warm["busy_s"] / n_requests      # seconds of service per request

    counter = slots.CompileCounter.instance()
    c0 = counter.count

    def run(mean_gap_s, preemption, seed):
        reqs = _priority_workload(cfg, n_requests, max_new, mean_gap_s,
                                  high_frac, seed=seed)
        eng.meter.reset()
        r = _run_online(eng, reqs, max_slots, prefill_chunk, preemption)
        # meter exactness under eviction/resume: every token the loop
        # counted as crossing — prefill, decode, re-prefill after eviction
        # — was metered at exactly eq. 7-10 bytes, nothing more
        measured = eng.measured_bytes()["total"]
        analytic = (r["prefill_tokens"] + r["decoded_tokens"]) * bpt
        r["traffic"] = {"measured": measured, "analytical": analytic,
                        "exact": measured == analytic}
        return r

    unloaded = run(4.0 * svc, preemption=True, seed=2)
    overload = run(0.5 * svc, preemption=True, seed=2)
    baseline = run(0.5 * svc, preemption=False, seed=2)
    recompiles = counter.count - c0
    cancel_ok = _cancel_probe(eng, cfg, prefill_chunk)

    hi = str(1)
    ratio = (overload["ttft_s_by_priority"][hi]["p95"]
             / max(unloaded["ttft_s_by_priority"][hi]["p95"], 1e-9))
    return {
        "config": cfg.name,
        "n_requests": n_requests,
        "max_slots": max_slots,
        "max_len": max_len,
        "page_size": page_size,
        "num_pages": num_pages,
        "prefill_chunk": prefill_chunk,
        "max_new": max_new,
        "high_priority_frac": high_frac,
        "svc_s_per_request": svc,
        "unloaded_gap_s": 4.0 * svc,
        "overload_gap_s": 0.5 * svc,
        "unloaded": unloaded,
        "overload": overload,
        "overload_no_preemption": baseline,
        "high_prio_p95_ttft_ratio": ratio,
        "preemptions": overload["preemptions"],
        "cancel_pages_freed_one_iteration": cancel_ok,
        "steady_state_recompiles": recompiles,
        "traffic_exact": (unloaded["traffic"]["exact"]
                          and overload["traffic"]["exact"]
                          and baseline["traffic"]["exact"]),
    }


# The tensor-parallel worker: ONE (tp) configuration per fresh subprocess —
# the forced host device count is a process-level XLA flag, so tp=1 and
# tp=2 cannot share a process.  Prints one "TPBENCH {json}" line.
_TP_WORKER = r"""
import dataclasses, json, sys, time
import numpy as np
import jax

from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
from repro.models import api
from repro.serve import slots as slots_mod
from repro.serve.engine import ServeEngine
from repro.serve.splitbrain_engine import traffic_model_for

spec = json.loads(sys.argv[1])
tp = spec["tp"]
assert jax.device_count() >= tp, jax.devices()
cfg = get_config(spec["arch"]).reduced(**spec["overrides"])
cfg = dataclasses.replace(
    cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
params = api.init_params(cfg, jax.random.PRNGKey(0))
mesh = (make_test_mesh(shape=(1, tp)) if tp > 1
        else make_test_mesh(devices=jax.devices()[:1]))
eng = ServeEngine(cfg, params, mesh=mesh, max_len=spec["max_len"],
                  page_size=spec["page_size"], paged_attn="inplace")

rng = np.random.default_rng(0)
B, steps = spec["slots"], spec["steps"]
prompts = [rng.integers(1, cfg.vocab_size, (int(rng.integers(2, 17)),)
                        ).astype(np.int32) for _ in range(B)]
cache = eng.init_slot_cache(B)
toks = np.zeros((B,), np.int32)
for i, p in enumerate(prompts):
    assert eng.reserve_slot(i, len(p), steps + 2)
    c1, t = eng.prefill_slot(p)
    cache = eng.insert_slot(cache, c1, i)
    eng.meter_tokens(len(p) - 1)   # prefill crossings (T0-1 convention)
    toks[i] = t
active = np.ones((B,), bool)
counter = slots_mod.CompileCounter.instance()
outs, t0, c0 = [], None, None
for k in range(steps):
    if k == 2:              # steps 0-1 may compile; steady state after that
        c0 = counter.count
        t0 = time.perf_counter()
    nxt, ok, cache = eng.decode_slots(cache, toks, active)
    assert bool(np.asarray(ok).all()), "finite-logits sentinel"
    eng.meter_tokens(B)
    toks = np.asarray(nxt)  # host sync every step, like the serve loop
    outs.append(toks.tolist())
dt = time.perf_counter() - t0
measured = eng.measured_bytes()["total"]
analytic = ((sum(len(p) - 1 for p in prompts) + B * steps)
            * traffic_model_for(cfg).bytes_per_token())
print("TPBENCH " + json.dumps({
    "tp": tp,
    "devices": jax.device_count(),
    "tokens": outs,
    "decode_tokens_per_s": B * (steps - 2) / dt,
    "measured_bytes": measured,
    "analytic_bytes": analytic,
    "traffic_exact": measured == analytic,
    "steady_state_recompiles": counter.count - c0,
    "compile_counter_available": counter.available,
    "kv_shards": eng.cache_stats(cache).get("kv_shards", 1),
    "traffic_shards": eng.traffic_shards,
}))
"""


def _tp_worker(tp: int, spec: Dict[str, Any],
               timeout: int = 1800) -> Dict[str, Any]:
    """Run one TP configuration in a subprocess with ``tp`` forced host
    devices (mirrors tests/conftest.py::run_multidev)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={tp} "
                        + env.get("XLA_FLAGS", ""))
    env["JAX_PLATFORMS"] = "cpu"     # the TPU probe can hang headless runs
    src = str(Path(__file__).resolve().parent.parent / "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (env.get("PYTHONPATH", ""), src) if p)
    proc = subprocess.run(
        [sys.executable, "-c", _TP_WORKER, json.dumps({**spec, "tp": tp})],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, (tp, proc.stdout[-2000:],
                                  proc.stderr[-2000:])
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("TPBENCH ")][-1]
    return json.loads(line[len("TPBENCH "):])


def bench_tp(arch: str, max_new: int, max_slots: int,
             overrides: Dict[str, Any], page_size: int = 8,
             tp: int = 2) -> Dict[str, Any]:
    """The tensor-parallel serve discipline: the slot-decode workload at
    tp=1 vs tp=``tp`` on forced host devices, in fresh subprocesses.

    Gates (via main()'s FAIL path): greedy token identity, byte-exact
    traffic on BOTH sides with equal totals (per-shard meter entries sum to
    the single-device analytical model), zero steady-state recompiles, the
    pool cut on KV heads (kv_shards == tp); the decode tokens/s speedup is
    additionally gated on hosts with >= 2 cores."""
    cfg = get_config(arch).reduced(**overrides)
    spec = {
        "arch": arch,
        "overrides": overrides,
        "max_len": pages.round_len(16 + max_new + 1, page_size, None),
        "page_size": page_size,
        "slots": max_slots,
        "steps": max_new,
    }
    w1 = _tp_worker(1, spec)
    wN = _tp_worker(tp, spec)
    return {
        "config": cfg.name,
        "tp": tp,
        "slots": max_slots,
        "steps": max_new,
        "page_size": page_size,
        "max_len": spec["max_len"],
        "host_cpus": os.cpu_count() or 1,
        "tp1": w1,
        "tpN": wN,
        "token_identical": w1["tokens"] == wN["tokens"],
        "traffic_exact": (w1["traffic_exact"] and wN["traffic_exact"]
                          and w1["measured_bytes"] == wN["measured_bytes"]),
        "kv_shards": wN["kv_shards"],
        "traffic_shards": wN["traffic_shards"],
        "zero_steady_state_recompiles":
            (w1["steady_state_recompiles"] == 0
             and wN["steady_state_recompiles"] == 0),
        "decode_tokens_per_s_speedup":
            wN["decode_tokens_per_s"] / w1["decode_tokens_per_s"],
    }


def _chaos_stats(out: Dict[str, Any]) -> Dict[str, Any]:
    """The JSON-safe slice of a scheduler run the chaos report keeps."""
    return {k: out[k] for k in
            ("wall_s", "busy_s", "steps", "iterations", "decoded_tokens",
             "prefill_tokens", "cached_prompt_tokens", "by_state",
             "quarantines", "failed", "recoveries", "last_recovery_s")}


def bench_chaos(arch: str, n_requests: int, max_slots: int,
                overrides: Dict[str, Any], page_size: int = 8,
                prefill_chunk: int = 8, prefix_len: int = 16,
                tail_max: int = 8, max_new: int = 8, seed: int = 0,
                recovery_s_bound: float = 5.0) -> Dict[str, Any]:
    """The crash-tolerance serve discipline (DESIGN.md §12): one shared-
    prefix trace served three times on the SAME paged + prefix engine —
    uninterrupted (the reference), then through two identical chaos cycles
    whose seeded plan combines all three device-level injection points
    (per-slot NaN corruption, a raised decode step, wholesale device loss).

    Gates (via main()'s FAIL path): every request still reaches DONE with
    tokens IDENTICAL to the uninterrupted run (recovery replays from the
    host-authoritative state, greedy decode makes that bitwise-checkable);
    each fault class actually fired (a chaos bench that injects nothing
    proves nothing); the page pool returns to (0 in-use, 0 reserved,
    0 drawn-held) after the run; recovery completes under the bound; and
    the SECOND chaos cycle — recovery paths already warm — compiles
    NOTHING (rebuild() keeps the jit caches: device loss kills buffers,
    not compiled host programs)."""
    cfg = get_config(arch).reduced(**overrides)
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    max_len = pages.round_len(prefix_len + tail_max + max_new,
                              page_size, prefill_chunk)
    slot_pages = max_len // page_size
    num_pages = max_slots * slot_pages + 1
    eng = ServeEngine(cfg, params, max_len=max_len, page_size=page_size,
                      num_pages=num_pages, prefix_cache="on")
    reqs = _prefix_workload(cfg, n_requests, max_new, 0.0,
                            prefix_len, tail_max)
    plan = FaultPlan(step_corrupt_at=4, step_corrupt_iters=2,
                     step_corrupt_frac=0.5,
                     step_error_at=8, step_error_count=1,
                     device_loss_at=14)

    def run_once(faults):
        sched = ContinuousBatchingScheduler(eng, max_slots=max_slots,
                                            prefill_chunk=prefill_chunk,
                                            faults=faults)
        out = sched.run(list(reqs))
        assert not out["rejected"], out["rejected"]
        out["recovery_log"] = list(sched.recovery_log)
        return out

    ref = run_once(None)
    ref_tokens = {r.uid: r.tokens for r in ref.pop("results")}
    # cycle 1 warms every recovery-path shape; cycle 2 (same plan, same
    # seed -> same fault sequence) is the measured one and must not compile
    run_once(FaultInjector(plan, seed=seed))
    counter = slots.CompileCounter.instance()
    c0 = counter.count
    inj = FaultInjector(plan, seed=seed)
    out = run_once(inj)
    recompiles = counter.count - c0
    results = out.pop("results")
    pool = eng._pager.pool
    pool_state = (pool.pages_in_use, pool.total_reserved, pool.total_drawn)
    token_identical = (
        len(results) == len(ref_tokens)
        and all(np.array_equal(r.tokens, ref_tokens[r.uid])
                for r in results))
    fired = {k: inj.fired(k)
             for k in ("step_corrupt", "step_error", "device_loss")}
    return {
        "config": cfg.name,
        "n_requests": n_requests,
        "max_slots": max_slots,
        "max_len": max_len,
        "page_size": page_size,
        "num_pages": num_pages,
        "prefill_chunk": prefill_chunk,
        "max_new": max_new,
        "seed": seed,
        "plan": dataclasses.asdict(plan),
        "reference": _chaos_stats(ref),
        "chaos": _chaos_stats(out),
        "recovery_log": out["recovery_log"],
        "fired": fired,
        "all_faults_fired": all(v > 0 for v in fired.values()),
        "token_identical": token_identical,
        "all_done": out["by_state"] == {"DONE": len(reqs)},
        "quarantines": out["quarantines"],
        "failed": out["failed"],
        "recoveries": out["recoveries"],
        "last_recovery_s": out["last_recovery_s"],
        "recovery_s_bound": recovery_s_bound,
        "recovery_bounded": 0.0 < out["last_recovery_s"] <= recovery_s_bound,
        "pool_state_after": pool_state,
        "pool_baseline_restored": pool_state == (0, 0, 0),
        "steady_state_recompiles": recompiles,
        "zero_steady_state_recompiles": recompiles == 0,
    }


def bench_kv_quant(arch: str, n_requests: int, max_new: int, max_slots: int,
                   mean_gap_s: float, overrides: Dict[str, Any],
                   page_size: int = 8, prefill_chunk: int = 8,
                   kv_dtype: str = "int8") -> Dict[str, Any]:
    """The quantized-KV-pages serve discipline (DESIGN.md §13): the ragged
    trace through the in-place paged scheduler with a bf16 pool vs a
    ``kv_dtype`` pool of the same page geometry.

    Gates (via main()'s FAIL path): resident tokens per pool byte up by
    >= the gate (pure storage accounting, timing-free), per-step greedy
    argmax flip rate vs the bf16 run within budget, eq. 7-10 boundary
    bytes byte-IDENTICAL between the two pools, host KV reads shrunk
    >= 1.5x, zero steady-state recompiles on the quantized engine."""
    cfg = get_config(arch).reduced(**overrides)
    cfg = dataclasses.replace(
        cfg, parallel=dataclasses.replace(cfg.parallel, remat="none"))
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    max_len = pages.round_len(16 - 1 + max_new, page_size, prefill_chunk)
    slot_pages = max_len // page_size
    num_pages = max(max_slots * slot_pages // 2, slot_pages) + 1
    engines = {
        "bf16": ServeEngine(cfg, params, max_len=max_len,
                            page_size=page_size, num_pages=num_pages),
        kv_dtype: ServeEngine(cfg, params, max_len=max_len,
                              page_size=page_size, num_pages=num_pages,
                              kv_dtype=kv_dtype),
    }
    reqs = _workload(cfg, n_requests, max_new, mean_gap_s)
    warm = [dataclasses.replace(r, uid=-1 - i, arrival_s=0.0)
            for i, r in enumerate(reqs)]
    for eng in engines.values():
        _run_continuous(eng, warm, max_slots, prefill_chunk)

    counter = slots.CompileCounter.instance()
    out: Dict[str, Any] = {}
    tokens_by_uid: Dict[str, Any] = {}
    for name, eng in engines.items():
        c0 = counter.count
        eng.meter.reset()
        r = _run_continuous(eng, reqs, max_slots, prefill_chunk)
        results = r.pop("results")
        r["steady_state_recompiles"] = counter.count - c0
        r["traffic"] = _check_traffic(eng, reqs, cfg)
        assert r["traffic"]["exact"], r["traffic"]
        r["measured_boundary_bytes"] = eng.measured_bytes()["total"]
        tokens_by_uid[name] = {res.uid: res.tokens for res in results}
        out[name] = r

    base, quant = out["bf16"], out[kv_dtype]
    # greedy-token divergence, two figures.  token_divergence_frac counts
    # every differing aligned token (informational): after ONE near-tie
    # argmax flip the remaining greedy path legitimately differs, so a
    # single flip late in a long trace cascades through the tail.  The
    # GATED figure is token_flip_rate: first-flip EVENTS per aligned token
    # compared (tokens up to and including each sequence's first mismatch)
    # — the per-step probability that quantization flips the argmax, which
    # is what the KV representation actually controls.
    total = diverged = flips = compared = 0
    for uid, toks in tokens_by_uid["bf16"].items():
        q = tokens_by_uid[kv_dtype][uid]
        n = min(len(toks), len(q))
        total += max(len(toks), len(q))
        neq = np.asarray(toks[:n]) != np.asarray(q[:n])
        diverged += int(neq.sum()) + max(len(toks), len(q)) - n
        flips += int(neq.any())
        compared += (int(np.argmax(neq)) + 1) if neq.any() else n
    divergence = diverged / max(total, 1)
    flip_rate = flips / max(compared, 1)
    # the capacity claim, timing-free: same pool GEOMETRY (num_pages), so
    # resident tokens per byte scale inversely with the per-token STORAGE
    # figure — bf16 bytes/token over quantized bytes/token IS the uplift
    stored_ratio = (base["cache"]["kv_token_bytes_stored"]
                    / quant["cache"]["kv_token_bytes_stored"])
    read_ratio = base["kv_read_bytes"] / max(quant["kv_read_bytes"], 1)
    return {
        "config": cfg.name,
        "kv_dtype": kv_dtype,
        "n_requests": n_requests,
        "max_new": max_new,
        "max_slots": max_slots,
        "max_len": max_len,
        "page_size": page_size,
        "num_pages": num_pages,
        "prefill_chunk": prefill_chunk,
        "bf16": base,
        "quant": quant,
        "resident_tokens_per_byte_uplift": stored_ratio,
        "pool_bytes_bf16": base["cache"]["pool_bytes"],
        "pool_bytes_quant": quant["cache"]["pool_bytes"],
        "kv_read_bytes_shrink": read_ratio,
        "token_divergence_frac": divergence,
        "token_flip_rate": flip_rate,
        "boundary_bytes_identical":
            base["measured_boundary_bytes"]
            == quant["measured_boundary_bytes"],
        "traffic_exact": (base["traffic"]["exact"]
                          and quant["traffic"]["exact"]),
        "zero_steady_state_recompiles":
            quant["steady_state_recompiles"] == 0
            and base["steady_state_recompiles"] == 0,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small workload, loose gates (CI smoke)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--mean-gap-ms", type=float, default=2.0,
                    help="mean Poisson inter-arrival gap (saturating default)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    # 64 full-run requests: sub-second walls make the discipline ratios
    # noisy on a shared machine; a longer trace stabilizes the gates
    n_requests = args.requests or (8 if args.quick else 64)
    max_new = args.max_new or (8 if args.quick else 32)
    # d_model=128 keeps the reduced model decode GEMV-bound enough that
    # batching the slots is a real win, CPU or not
    overrides = dict(vocab_size=256, d_model=128, d_ff=384)
    archs = ["llama2-7b"] if args.quick else ["llama2-7b", "rwkv6-7b"]

    results = [bench_arch(a, n_requests, max_new, args.slots,
                          args.mean_gap_ms / 1e3, overrides,
                          page_size=args.page_size,
                          prefill_chunk=args.prefill_chunk,
                          repeats=1 if args.quick else 3) for a in archs]
    # the shared-prefix discipline: same trace with the prefix cache off/on
    prefix_results = [bench_prefix(
        "llama2-7b", max(n_requests // 2, 8), args.slots,
        args.mean_gap_ms / 1e3, overrides, page_size=args.page_size,
        prefill_chunk=args.prefill_chunk,
        repeats=1 if args.quick else 3)]
    # the online-overload discipline: priority-split traffic unloaded vs at
    # 2x overload with SLA-aware preemption, plus the cancellation probe.
    # FEW slots relative to the trace: at 2x the service rate the queue
    # must actually build, or there is no pressure to preempt under
    overload_results = [bench_overload(
        "llama2-7b", max(n_requests // 2, 16),
        max(args.slots // 4, 2), overrides, page_size=args.page_size,
        prefill_chunk=args.prefill_chunk,
        max_new=max(max_new // 2, 8))]
    # the tensor-parallel discipline: tp=1 vs tp=2 in fresh forced-host-
    # device subprocesses (the device count is a process-level XLA flag)
    tp_results = [bench_tp("llama2-7b", max_new, max(args.slots // 2, 4),
                           overrides, page_size=args.page_size)]
    # the chaos-recovery discipline: the same shared-prefix trace with a
    # seeded plan firing all three device-level injection points, gated on
    # token identity vs the uninterrupted run of the same engine.  The
    # recovery bound is wall-clock generous (loaded CI box); every other
    # chaos gate is absolute correctness
    chaos_recovery_s = 5.0
    chaos_results = [bench_chaos(
        "llama2-7b", max(n_requests // 4, 8), max(args.slots // 4, 4),
        overrides, page_size=args.page_size,
        prefill_chunk=args.prefill_chunk, max_new=max(max_new // 2, 8),
        recovery_s_bound=chaos_recovery_s)]
    # the quantized-KV-pages discipline: the ragged trace from a bf16 vs an
    # int8 pool of identical page geometry — capacity and divergence gates
    # are storage/token accounting, so quick mode keeps them in full
    kv_quant_results = [bench_kv_quant(
        "llama2-7b", max(n_requests // 2, 8), max_new, args.slots,
        args.mean_gap_ms / 1e3, overrides, page_size=args.page_size,
        prefill_chunk=args.prefill_chunk)]

    # rwkv keeps dense recurrent state (no-op page table): the memory gate
    # only applies where the pool actually pages KV
    gate = 1.0 if args.quick else 2.0
    mem_gate = 1.0 if args.quick else 2.0
    rps_gate = 0.75 if args.quick else 0.9
    # the in-place discipline does strictly less work than gather (no dense
    # view copy, no scatter), and the gate only applies to configs that
    # actually page, where that structural margin measures >10% (1.14x in
    # the shipped artifact; the oracle's page loop is unrolled precisely so
    # scan dispatch overhead can't eat it) — best-of-repeats absorbs the
    # remaining noise; quick mode (sub-second walls) gets slack instead
    inplace_gate = 0.9 if args.quick else 1.0
    # shared-prefix gates: >= 50% prompt overlap must buy >= 1.3x prefill
    # tokens/s (the cache computes only the unmatched tails) and fewer
    # peak resident KV pages (the shared prefix is stored once); quick
    # mode keeps the structural gates (identity, traffic, recompiles) but
    # relaxes the timing one (sub-second walls are noise-dominated)
    prefix_gate = 1.0 if args.quick else 1.3
    prefix_pages_gate = 1.0 if args.quick else 1.5
    # overload gate: high-priority p95 TTFT at 2x overload within 1.5x of
    # unloaded (the SLA preemption is FOR this); quick mode's sub-second
    # TTFTs are scheduler-noise-dominated, so it gets headroom while the
    # structural gates (cancel SLO, recompiles, traffic) stay strict
    overload_gate = 4.0 if args.quick else 1.5
    # tp timing gate: tp=2 must beat tp=1 decode tokens/s by this factor —
    # but ONLY on a host that can actually run two shards concurrently; on
    # a 1-core box (or in quick mode's sub-second walls) the structural
    # gates (token identity, byte-exact traffic, recompiles, kv_shards)
    # still apply in full while the wall-clock one is moot
    tp_gate = 1.6
    tp_timing_gated = (not args.quick) and (os.cpu_count() or 1) >= 2
    # kv_quant gates: int8 codes + page-amortized scales must buy >= 1.8x
    # resident tokens per pool byte (hd=32 pages at ps=8 measure ~1.94x);
    # the per-step argmax flip rate vs bf16 stays a small fraction
    # (near-tie flips only — one flip cascades the tail, which
    # token_divergence_frac reports but the flip-rate gate does not
    # double-count); the host KV-read channel shrinks >= 1.5x.  All
    # storage/token accounting, so quick mode keeps every kv_quant gate
    # in full
    kv_quant_gate = 1.8
    kv_quant_flip_budget = 0.05
    kv_quant_read_gate = 1.5
    summary = {
        r["config"]: {
            "requests_per_s_speedup": round(r["requests_per_s_speedup"], 2),
            "tokens_per_s_speedup": round(r["tokens_per_s_speedup"], 2),
            "paged_vs_dense_requests_per_s":
                round(r["paged_vs_dense_requests_per_s"], 2),
            "paged_inplace_vs_gather_tokens_per_s":
                (round(r["paged_inplace_vs_gather_tokens_per_s"], 2)
                 if r["paged_inplace_vs_gather_tokens_per_s"] is not None
                 else None),   # None: family never pages (dense fallback)
            "paged_memory_saving": round(r["paged_memory_saving"], 2),
            "gather_transient_bytes_per_step":
                r["paged"]["gather_transient_bytes_per_step"],
            "zero_steady_state_recompiles":
                r["steady_state_recompiles"] == 0
                and r["paged_steady_state_recompiles"] == 0
                and r["gather_steady_state_recompiles"] == 0,
            "traffic_exact": r["traffic_exact"],
        } for r in results
    }
    summary["overload"] = {
        r["config"]: {
            "high_prio_p95_ttft_ratio": round(r["high_prio_p95_ttft_ratio"],
                                              2),
            "preemptions": r["preemptions"],
            "cancel_pages_freed_one_iteration":
                r["cancel_pages_freed_one_iteration"],
            "zero_steady_state_recompiles":
                r["steady_state_recompiles"] == 0,
            "traffic_exact": r["traffic_exact"],
        } for r in overload_results
    }
    summary["tp"] = {
        r["config"]: {
            "tp": r["tp"],
            "decode_tokens_per_s_speedup":
                round(r["decode_tokens_per_s_speedup"], 2),
            "token_identical": r["token_identical"],
            "traffic_exact": r["traffic_exact"],
            "kv_shards": r["kv_shards"],
            "traffic_shards": r["traffic_shards"],
            "zero_steady_state_recompiles":
                r["zero_steady_state_recompiles"],
            "timing_gated": tp_timing_gated,
        } for r in tp_results
    }
    summary["chaos"] = {
        r["config"]: {
            "token_identical": r["token_identical"],
            "all_done": r["all_done"],
            "fired": r["fired"],
            "quarantines": r["quarantines"],
            "failed": r["failed"],
            "recoveries": r["recoveries"],
            "last_recovery_s": round(r["last_recovery_s"], 4),
            "pool_baseline_restored": r["pool_baseline_restored"],
            "zero_steady_state_recompiles":
                r["zero_steady_state_recompiles"],
        } for r in chaos_results
    }
    summary["kv_quant"] = {
        r["config"]: {
            "kv_dtype": r["kv_dtype"],
            "resident_tokens_per_byte_uplift":
                round(r["resident_tokens_per_byte_uplift"], 2),
            "kv_read_bytes_shrink": round(r["kv_read_bytes_shrink"], 2),
            "token_divergence_frac": round(r["token_divergence_frac"], 4),
            "token_flip_rate": round(r["token_flip_rate"], 4),
            "boundary_bytes_identical": r["boundary_bytes_identical"],
            "traffic_exact": r["traffic_exact"],
            "zero_steady_state_recompiles":
                r["zero_steady_state_recompiles"],
        } for r in kv_quant_results
    }
    summary["prefix"] = {
        r["config"]: {
            "prefix_overlap": round(r["prefix_overlap"], 2),
            "prefill_tokens_per_s_uplift":
                round(r["prefill_tokens_per_s_uplift"], 2),
            "kv_pages_stored_reduction":
                round(r["kv_pages_stored_reduction"], 2),
            "cached_prompt_tokens": r["cached_prompt_tokens"],
            "token_identical": r["token_identical"],
            "zero_steady_state_recompiles":
                r["zero_steady_state_recompiles"],
            "traffic_exact": r["traffic_exact"],
            "ttft_p50_on_vs_off": (
                round(r["on"]["ttft_s"]["p50"]
                      / max(r["off"]["ttft_s"]["p50"], 1e-9), 2)),
        } for r in prefix_results
    }
    report = {
        "schema": "serve_bench/v8",
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "quick": args.quick,
        "disciplines": list(DISCIPLINE_NAMES),
        "gate_requests_per_s_speedup": gate,
        "gate_paged_memory_saving": mem_gate,
        "gate_paged_vs_dense_requests_per_s": rps_gate,
        "gate_paged_inplace_vs_gather_tokens_per_s": inplace_gate,
        "gate_paged_transient_bytes": 0,
        "gate_prefix_prefill_uplift": prefix_gate,
        "gate_prefix_pages_reduction": prefix_pages_gate,
        "gate_overload_ttft_ratio": overload_gate,
        "gate_tp_decode_speedup": tp_gate,
        "tp_timing_gated": tp_timing_gated,
        "gate_chaos_recovery_s": chaos_recovery_s,
        "gate_kv_quant_capacity_uplift": kv_quant_gate,
        "gate_kv_quant_flip_rate": kv_quant_flip_budget,
        "gate_kv_quant_read_shrink": kv_quant_read_gate,
        "results": results,
        "prefix_results": prefix_results,
        "overload_results": overload_results,
        "tp_results": tp_results,
        "chaos_results": chaos_results,
        "kv_quant_results": kv_quant_results,
        "summary": summary,
    }
    # registry cross-check: every discipline in the registry must have a
    # section in this report — a bench that forgets one FAILS, it doesn't
    # silently drift (repro/serve/disciplines.py)
    covered = set()
    for r in results:
        covered |= {d for d in ("sequential", "continuous", "paged_gather",
                                "paged") if r.get(d) is not None}
    covered |= {"prefix"} if prefix_results else set()
    covered |= {"overload"} if overload_results else set()
    covered |= {"tp"} if tp_results else set()
    covered |= {"chaos"} if chaos_results else set()
    covered |= {"kv_quant"} if kv_quant_results else set()
    missing_disciplines = [n for n in DISCIPLINE_NAMES if n not in covered]
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print(json.dumps(summary, indent=2))
    print(f"wrote {args.out}")

    def paged_ok(r):
        if not (r["paged_transient_eliminated"]
                and r["paged_inplace_reads_less"]):
            return False
        if "num_pages" not in r["paged"]["cache"]:
            return True               # family never paged (dense fallback)
        return (r["paged_memory_saving"] >= mem_gate
                and r["paged_vs_dense_requests_per_s"] >= rps_gate
                and r["paged_inplace_vs_gather_tokens_per_s"] >= inplace_gate)

    def prefix_ok(r):
        return (r["token_identical"]
                and r["zero_steady_state_recompiles"]
                and r["traffic_exact"]
                and r["cached_prompt_tokens"] > 0
                and r["prefill_tokens_per_s_uplift"] >= prefix_gate
                and r["kv_pages_stored_reduction"] >= prefix_pages_gate)

    def overload_ok(r):
        return (r["high_prio_p95_ttft_ratio"] <= overload_gate
                and r["preemptions"] > 0
                and r["cancel_pages_freed_one_iteration"]
                and r["steady_state_recompiles"] == 0
                and r["traffic_exact"])

    def tp_ok(r):
        return (r["token_identical"]
                and r["traffic_exact"]
                and r["zero_steady_state_recompiles"]
                and r["kv_shards"] == r["tp"]
                and (not tp_timing_gated
                     or r["decode_tokens_per_s_speedup"] >= tp_gate))

    def kv_quant_ok(r):
        return (r["resident_tokens_per_byte_uplift"] >= kv_quant_gate
                and r["token_flip_rate"] <= kv_quant_flip_budget
                and r["kv_read_bytes_shrink"] >= kv_quant_read_gate
                and r["boundary_bytes_identical"]
                and r["traffic_exact"]
                and r["zero_steady_state_recompiles"])

    def chaos_ok(r):
        return (r["token_identical"]
                and r["all_done"]
                and r["all_faults_fired"]
                and r["recoveries"] > 0
                and r["quarantines"] > 0
                and r["failed"] == 0
                and r["pool_baseline_restored"]
                and r["recovery_bounded"]
                and r["zero_steady_state_recompiles"])

    ok = all(r["requests_per_s_speedup"] >= gate
             and r["steady_state_recompiles"] == 0
             and r["paged_steady_state_recompiles"] == 0
             and r["gather_steady_state_recompiles"] == 0
             and r["traffic_exact"]
             and paged_ok(r) for r in results) \
        and all(prefix_ok(r) for r in prefix_results) \
        and all(overload_ok(r) for r in overload_results) \
        and all(tp_ok(r) for r in tp_results) \
        and all(chaos_ok(r) for r in chaos_results) \
        and all(kv_quant_ok(r) for r in kv_quant_results) \
        and not missing_disciplines
    if not ok:
        print(f"FAIL: continuous < {gate}x sequential requests/s, paged < "
              f"{mem_gate}x memory saving, paged < {rps_gate}x dense "
              f"requests/s, paged in-place < {inplace_gate}x gather "
              "tokens/s, nonzero dense-view transient, in-place KV reads "
              ">= gather, steady-state recompile, traffic mismatch, a "
              f"prefix-cache gate (token identity, < {prefix_gate}x "
              f"prefill tokens/s, < {prefix_pages_gate}x page reduction, "
              f"no hits), an overload gate (high-prio p95 TTFT > "
              f"{overload_gate}x unloaded, no preemptions, cancelled pages "
              "not freed in one iteration), a tp gate (tp tokens differ "
              "from tp=1, traffic inexact, recompile, pool not head-cut"
              + (f", decode speedup < {tp_gate}x" if tp_timing_gated
                 else "")
              + "), a chaos gate (recovered tokens differ from the "
              "uninterrupted run, a request not DONE, a fault class never "
              "fired, no recovery/quarantine, pool not back to baseline, "
              f"recovery > {chaos_recovery_s}s, recompile on the repeat "
              "cycle), a kv_quant gate (resident tokens/byte < "
              f"{kv_quant_gate}x, argmax flip rate > "
              f"{kv_quant_flip_budget}, KV reads shrunk < "
              f"{kv_quant_read_gate}x, boundary bytes differ from bf16), "
              f"or registry coverage ({missing_disciplines})",
              file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
